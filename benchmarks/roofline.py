"""Roofline analysis from the dry-run artifacts (TPU v5e targets).

Per (arch x shape x mesh):
    compute term    = HLO_flops_per_device / 197 TF/s (bf16)
    memory term     = HLO_bytes_per_device / 819 GB/s
    collective term = collective_bytes_per_device / 50 GB/s-link
    bottleneck      = argmax of the three
    MODEL_FLOPS     = 6*N*D (train) / 2*N*D (prefill/decode), N = active params
    useful fraction = (MODEL_FLOPS/chips/peak) / max(term)

The HLO numbers come from launch/hlo_analysis.py (dot FLOPs + post-fusion
bytes + collective bytes, while-bodies multiplied by parsed trip counts).
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_cells(mesh: str = "single") -> list[dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(RESULTS, f"*_{mesh}.json"))):
        r = json.load(open(f))
        if "error" in r or "skipped" in r:
            cells.append(r)
            continue
        cells.append(compute_terms(r))
    return cells


def model_flops(rec: dict) -> float:
    """Useful model FLOPs per device per step."""
    n = rec["active_params"]
    if rec["kind"] == "train":
        tokens = rec["global_batch"] * rec["seq_len"]
        total = 6.0 * n * tokens
    elif rec["kind"] == "prefill":
        tokens = rec["global_batch"] * rec["seq_len"]
        total = 2.0 * n * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n * rec["global_batch"]
    return total / rec["n_devices"]


def compute_terms(rec: dict) -> dict:
    coll = sum(rec["collective_bytes_per_device"].values())
    t_c = rec["flops_per_device"] / PEAK_FLOPS
    t_m = rec["hbm_bytes_per_device"] / HBM_BW
    t_x = coll / ICI_BW
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec)
    useful_s = mf / PEAK_FLOPS
    bound = max(terms.values())
    rec = dict(rec)
    rec.update(terms)
    rec["dominant"] = dom.replace("_s", "")
    rec["model_flops_per_device"] = mf
    rec["model_over_hlo_flops"] = (mf / rec["flops_per_device"]
                                   if rec["flops_per_device"] else 0.0)
    rec["roofline_fraction"] = useful_s / bound if bound else 0.0
    rec["lever"] = _lever(rec)
    return rec


def _lever(r: dict) -> str:
    if r["dominant"] == "collective":
        return ("shrink/overlap collectives: reshard to cut all-reduce "
                "volume, chunked AG-matmul overlap, int8 gradient compression")
    if r["dominant"] == "memory":
        if r["kind"] == "decode":
            return ("KV-cache traffic bound: quantize cache, batch more "
                    "sequences per step, fuse attention (flash-decode)")
        return ("cut HBM traffic: fuse via Pallas kernels, reduce remat "
                "recompute, bf16 intermediates")
    return ("raise MXU utilization: remove redundant/replicated compute, "
            "reduce remat recompute, fold fp32 upcasts")


def report(emit) -> None:
    rows = []
    for mesh in ("single",):
        for r in load_cells(mesh):
            tag = f"{r['arch']}.{r['shape']}.{mesh}"
            if "skipped" in r:
                rows.append((f"roofline.{tag}.skipped", 0.0, 0))
                continue
            if "error" in r:
                rows.append((f"roofline.{tag}.ERROR", 0.0, 0))
                continue
            rows.append((f"roofline.{tag}.compute_s", 0.0,
                         round(r["compute_s"], 4)))
            rows.append((f"roofline.{tag}.memory_s", 0.0,
                         round(r["memory_s"], 4)))
            rows.append((f"roofline.{tag}.collective_s", 0.0,
                         round(r["collective_s"], 4)))
            rows.append((f"roofline.{tag}.dominant", 0.0, r["dominant"]))
            rows.append((f"roofline.{tag}.fraction", 0.0,
                         round(r["roofline_fraction"], 4)))
    emit(rows)


def table(mesh: str = "single") -> str:
    """Markdown table for EXPERIMENTS.md."""
    out = ["| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL/HLO flops | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for r in load_cells(mesh):
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — |")
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | ERROR | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"**{r['dominant']}** | {r['model_over_hlo_flops']:.2f} | "
            f"{r['roofline_fraction']:.3f} |")
    return "\n".join(out)


if __name__ == "__main__":
    print(table("single"))

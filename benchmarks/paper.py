"""Paper-table benchmarks (Figs. 7-10 of the paper), computed from the ILP
scheduler + the Vitis-dataflow model.  Results are cached as JSON because the
optical-flow scheduling ILPs take ~1 min on this 1-core container."""
from __future__ import annotations

import json
import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
CACHE = os.path.join(RESULTS_DIR, "paper_results.json")


def compute(storage: str = "reg", force: bool = False) -> dict:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    cache = {}
    if os.path.exists(CACHE) and not force:
        cache = json.load(open(CACHE))
    if storage in cache:
        return cache[storage]

    from repro.core import compile_program
    from repro.core.dataflow import (analyze_dataflow, resources, to_spsc,
                                     vitis_dataflow_latency)
    from repro.core.programs import BENCHMARKS

    out = {}
    for name, mk in BENCHMARKS.items():
        t0 = time.time()
        p = mk(storage=storage)
        s = compile_program(p)
        sp = to_spsc(p)
        ss = compile_program(sp)
        vitis_df, info = vitis_dataflow_latency(sp, ss)
        rec = {
            "ours_orig": s.completion_time(),
            "loop_only_orig": s.sequential_nests_latency(),
            "ours_spsc": ss.completion_time(),
            "loop_only_spsc": ss.sequential_nests_latency(),
            "vitis_dataflow_spsc": vitis_df,
            "dataflow_applicable": info.applicable,
            "channels": [(c.array, c.kind) for c in info.channels]
            if info.applicable else info.reason,
            "iis": {l.ivname: s.iis[l.uid] for l in p.loops()},
            "resources_ours": resources(sp, ss, "ours"),
            "resources_vitis_seq": resources(sp, ss, "vitis_seq"),
            "resources_vitis_df": resources(sp, ss, "vitis_dataflow"),
            "delay_reg_bits": ss.delay_register_bits(),
            "schedule_seconds": round(time.time() - t0, 2),
        }
        out[name] = rec
    cache[storage] = out
    json.dump(cache, open(CACHE, "w"), indent=1)
    return out


def fig7(res: dict) -> list[tuple]:
    """Speedup of multi-dimensional pipelining over loop-only pipelining
    (paper: 1.7x-3.7x, avg 2.42x)."""
    rows = []
    for name, r in res.items():
        rows.append((name, r["schedule_seconds"] * 1e6,
                     round(r["loop_only_orig"] / r["ours_orig"], 3)))
    return rows


def fig8(res: dict) -> list[tuple]:
    """SPSC workloads: ours and Vitis-dataflow vs Vitis-no-dataflow
    (paper: ours avg 1.30x over Vitis dataflow)."""
    rows = []
    for name, r in res.items():
        if not r["dataflow_applicable"]:
            continue  # the paper also dropped 2mm here
        base = r["loop_only_spsc"]
        rows.append((f"{name}.vitis_df", 0.0, round(base / r["vitis_dataflow_spsc"], 3)))
        rows.append((f"{name}.ours", 0.0, round(base / r["ours_spsc"], 3)))
        rows.append((f"{name}.ours_over_df", 0.0,
                     round(r["vitis_dataflow_spsc"] / r["ours_spsc"], 3)))
    return rows


def fig9(res: dict) -> list[tuple]:
    """Resource usage relative to Vitis-no-dataflow (model)."""
    rows = []
    for name, r in res.items():
        if not r["dataflow_applicable"]:
            continue
        for metric in ("bram_bytes", "ff_bits", "lut", "dsp"):
            base = max(r["resources_vitis_seq"][metric], 1.0)
            rows.append((f"{name}.{metric}.vitis_df", 0.0,
                         round(r["resources_vitis_df"][metric] / base, 3)))
            rows.append((f"{name}.{metric}.ours", 0.0,
                         round(r["resources_ours"][metric] / base, 3)))
    return rows


def fig10(res: dict) -> list[tuple]:
    """Unmodified (non-SPSC) workloads: ours vs Vitis-no-dataflow
    (paper: 2x-2.9x)."""
    rows = []
    for name, r in res.items():
        rows.append((name, 0.0,
                     round(r["loop_only_orig"] / r["ours_orig"], 3)))
    return rows

"""Paper-table benchmarks (Figs. 7-10 of the paper), computed from the ILP
scheduler + the Vitis-dataflow model.  Results are cached as JSON because the
optical-flow scheduling ILPs take ~1 min on this 1-core container."""
from __future__ import annotations

import json
import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
CACHE = os.path.join(RESULTS_DIR, "paper_results.json")
# DSE snapshot lives at the repo root next to BENCH_sched_compile.json so
# the transform/DSE win trajectory is visible across PRs.
DSE_JSON = os.path.join(os.path.dirname(__file__), os.pardir, "BENCH_dse.json")

# Fused-vs-unfused latency snapshot for the mismatched-bounds stencil
# chains (shift-and-peel fusion), next to BENCH_dse.json for the same reason.
FUSION_JSON = os.path.join(os.path.dirname(__file__), os.pardir,
                           "BENCH_fusion.json")

# Reduced benchmark sizes for the DSE sweep (explore() compiles ~a dozen
# candidates per program and validates the winner with the brute-force
# oracles, so full-size optical flow would take minutes on this container).
_DSE_SIZES = {"unsharp": 16, "harris": 8, "dus": 16, "optical_flow": 8,
              "two_mm": 8}

_FUSION_SIZES = {"blur_chain": 16, "conv_pool": 16, "gradient_harris": 12,
                 "correlated_chain": 16}

# Pareto-frontier DSE snapshot (hls.compile): frontier sizes + hypervolume
# vs the old greedy explore() winner, next to the other BENCH_*.json files.
PARETO_JSON = os.path.join(os.path.dirname(__file__), os.pardir,
                           "BENCH_pareto.json")

_PARETO_SIZES = {"blur_chain": 8, "conv_pool": 8, "gradient_harris": 6,
                 "correlated_chain": 8, "harris": 6, "optical_flow": 6,
                 "two_mm": 6}

# Codegen modeled-vs-measured snapshot (DESIGN.md §10), next to the other
# BENCH_*.json files.
CODEGEN_JSON = os.path.join(os.path.dirname(__file__), os.pardir,
                            "BENCH_codegen.json")

# The DSE runs at a small n (the sweep compiles ~a dozen candidates on this
# 1-core container); the winning pipeline is re-applied and lowered at the
# bench size, where the tile stream is long enough for the double-buffer
# refill/compute overlap to show up in interpret-mode wall-clock.
_CODEGEN_DSE_SIZES = {"blur_chain": 8, "conv_pool": 8,
                      "gradient_harris": 6, "correlated_chain": 8}
_CODEGEN_BENCH_SIZES = {"blur_chain": 128, "conv_pool": 128,
                        "gradient_harris": 96, "correlated_chain": 128}

# Drift gate: measured us (double-buffered) / modeled cycles per chain,
# normalized by the run's geometric mean — the absolute us-per-cycle scale
# depends on the host, but a chain whose NORMALIZED ratio leaves this band
# means the cost model and the generated kernel disagree in a
# chain-specific way.  Pinned from the first recorded runs on this
# container (normalized ratios 0.67-1.40 across the four chains) with
# headroom for interpret-mode timing noise.
CODEGEN_DRIFT_BAND = (0.4, 2.5)


def compute(storage: str = "reg", force: bool = False) -> dict:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    cache = {}
    if os.path.exists(CACHE) and not force:
        cache = json.load(open(CACHE))
    if storage in cache:
        return cache[storage]

    from repro.core.autotune import compile_program
    from repro.core.dataflow import (resources, to_spsc,
                                     vitis_dataflow_latency)
    from repro.core.programs import BENCHMARKS

    out = {}
    for name, mk in BENCHMARKS.items():
        t0 = time.time()
        p = mk(storage=storage)
        s = compile_program(p)
        sp = to_spsc(p)
        ss = compile_program(sp)
        vitis_df, info = vitis_dataflow_latency(sp, ss)
        rec = {
            "ours_orig": s.completion_time(),
            "loop_only_orig": s.sequential_nests_latency(),
            "ours_spsc": ss.completion_time(),
            "loop_only_spsc": ss.sequential_nests_latency(),
            "vitis_dataflow_spsc": vitis_df,
            "dataflow_applicable": info.applicable,
            "channels": [(c.array, c.kind) for c in info.channels]
            if info.applicable else info.reason,
            "iis": {l.ivname: s.iis[l.uid] for l in p.loops()},
            "resources_ours": resources(sp, ss, "ours"),
            "resources_vitis_seq": resources(sp, ss, "vitis_seq"),
            "resources_vitis_df": resources(sp, ss, "vitis_dataflow"),
            "delay_reg_bits": ss.delay_register_bits(),
            "schedule_seconds": round(time.time() - t0, 2),
        }
        out[name] = rec
    cache[storage] = out
    json.dump(cache, open(CACHE, "w"), indent=1)
    return out


def compute_dse(storage: str = "bram", force: bool = False) -> dict:
    """Resource-aware DSE sweep (DESIGN.md §6): for every benchmark, search
    transform pipelines under the iso-resource budget (baseline BRAM/DSP as
    the ceiling) and record the winner.  Results go to ``BENCH_dse.json``."""
    cache = {}
    if os.path.exists(DSE_JSON):
        cache = json.load(open(DSE_JSON))
    if storage in cache and not force:
        return cache[storage]

    from repro.core.api import explore
    from repro.core.programs import BENCHMARKS

    out = {}
    for name, mk in BENCHMARKS.items():
        n = _DSE_SIZES.get(name, 8)
        p = mk(n, storage=storage)
        t0 = time.time()
        r = explore(p, verify=True, validate=True, max_candidates=16)
        out[name] = {
            "n": n,
            "baseline_latency": r.baseline.latency,
            "best_latency": r.best.latency,
            "best_pipeline": r.best.desc,
            "speedup": round(r.speedup, 3),
            "budget": r.budget,
            "baseline_resources": r.baseline.res,
            "best_resources": r.best.res,
            "verified": True,   # explore(verify=True, validate=True) raised on
                                # any differential / validate_schedule failure
            "candidates": [
                {"pipeline": d, "latency": lat, "bram_bytes": bram,
                 "dsp": dsp, "within_budget": ok}
                for d, lat, bram, dsp, ok in r.table()],
            "dse_seconds": round(time.time() - t0, 2),
        }
    cache[storage] = out
    json.dump(cache, open(DSE_JSON, "w"), indent=1)
    return out


def compute_fusion(storage: str = "bram", force: bool = False) -> dict:
    """Shift-and-peel fusion sweep over the mismatched-bounds stencil chains
    (``programs.CHAIN_BENCHMARKS``): for every chain, compare the unfused
    ``compile_program`` schedule against the best explore() candidate whose
    pipeline actually fused the chain (nonzero shift / peels recorded in the
    program's ``_fusion_log``).  Results go to ``BENCH_fusion.json``."""
    cache = {}
    if os.path.exists(FUSION_JSON):
        cache = json.load(open(FUSION_JSON))
    if storage in cache and not force:
        return cache[storage]

    from repro.core.api import explore
    from repro.core.programs import CHAIN_BENCHMARKS

    out = {}
    for name, mk in CHAIN_BENCHMARKS.items():
        n = _FUSION_SIZES.get(name, 8)
        p = mk(n, storage=storage)
        t0 = time.time()
        r = explore(p, verify=True, validate=True, max_candidates=10,
                    unroll_factors=(), tile_sizes=(4,))
        fused = [c for c in r.candidates
                 if getattr(c.program, "_fusion_log", [])]
        if not fused:
            raise RuntimeError(
                f"fusion sweep: no fused candidate for chain '{name}' "
                f"(n={n}, storage={storage}) — candidates: "
                f"{[c.desc for c in r.candidates]}")
        in_budget = [c for c in fused if c.within_budget]
        best_fused = min(in_budget or fused, key=lambda c: c.latency)
        log = best_fused.program._fusion_log
        out[name] = {
            "n": n,
            "unfused_latency": r.baseline.latency,
            "fused_latency": best_fused.latency,
            "fused_pipeline": best_fused.desc,
            "loop_only_latency":
                r.baseline.schedule.sequential_nests_latency(),
            "shift": log[0]["shift"],
            "peels": sum(e["peels"] for e in log),
            "speedup": round(r.baseline.latency / best_fused.latency, 3),
            "within_budget": best_fused.within_budget,
            "budget": r.budget,
            "fused_resources": best_fused.res,
            "baseline_resources": r.baseline.res,
            "verified": True,   # explore(verify=True, validate=True) raised
                                # on any differential/validator failure
            "fusion_seconds": round(time.time() - t0, 2),
        }
    cache[storage] = out
    json.dump(cache, open(FUSION_JSON, "w"), indent=1)
    return cache[storage]


def compute_codegen(storage: str = "bram", force: bool = False) -> dict:
    """Close the modeled-vs-measured loop (DESIGN.md §10): for every
    mismatched-bounds chain, run the DSE at a small size, re-apply the
    latency x BRAM knee point's pipeline at the bench size, lower it with
    ``codegen.lower_program`` in both bufferings, and record measured
    interpret-mode wall-clock next to the modeled latency.  Gates (raise):

    * the double-buffered lowering beats the single-buffered one on >= 2
      chains (the ping-pong overlap must be real, not just modeled),
    * double and single outputs are bit-identical (buffering is a schedule
      choice, never a numerics choice),
    * the generated kernel matches ``sim.sequential_exec``,
    * every chain's normalized measured/modeled ratio stays inside
      ``CODEGEN_DRIFT_BAND``.

    Results go to ``BENCH_codegen.json``."""
    cache = {}
    if os.path.exists(CODEGEN_JSON):
        cache = json.load(open(CODEGEN_JSON))
    if storage in cache and not force:
        return cache[storage]

    import functools
    import math

    import jax
    import numpy as np

    from repro.core import hls, sim
    from repro.core.autotune import measure_candidate
    from repro.core.codegen import _point_block_rows, lower_program
    from repro.core.dataflow import tile_window_elems
    from repro.core.programs import CHAIN_BENCHMARKS

    def time_us(fn, arrays, iters=20):
        jax.block_until_ready(fn(arrays))  # compile + warm
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn(arrays))
        return (time.perf_counter() - t0) / iters * 1e6

    out = {}
    for name, mk in CHAIN_BENCHMARKS.items():
        nd = _CODEGEN_DSE_SIZES.get(name, 8)
        nb = _CODEGEN_BENCH_SIZES.get(name, 96)
        t0 = time.time()
        r = hls.compile(
            mk(nd, storage=storage),
            objectives=(hls.minimize("latency"), hls.minimize("bram")),
            search=hls.SearchConfig(moves=("fuse", "tile"),
                                    unroll_factors=(), tile_sizes=(2, 4),
                                    max_candidates=8))
        knee = r.knee("latency", "bram")
        p_big = mk(nb, storage=storage)
        # modeled latency: the knee's pipeline re-applied at the bench size
        big = measure_candidate(p_big, knee.desc, list(knee.passes),
                                verify=False, incremental=False)
        if big is None:  # the knee was the baseline / pipeline no-op'd
            big = measure_candidate(p_big, "baseline", [], verify=False)
        # the kernel lowers the ORIGINAL program: the tile pass maps to
        # block_rows (the Pallas grid), the fusion shift to the window halo
        bw = _point_block_rows(knee)
        kd = lower_program(p_big, block_rows=bw, buffering="double")
        ks = lower_program(p_big, block_rows=bw, buffering="single")
        inputs = sim.make_inputs(p_big, seed=0)
        fd = jax.jit(functools.partial(kd.fn, interpret=True))
        fs = jax.jit(functools.partial(ks.fn, interpret=True))
        od, os_ = fd(inputs), fs(inputs)
        bitexact = all(np.array_equal(np.asarray(od[a]), np.asarray(os_[a]))
                       for a in kd.outputs)
        if not bitexact:
            raise RuntimeError(
                "codegen bench: double- and single-buffered lowerings of "
                f"'{name}' (n={nb}) disagree bitwise")
        ref = sim.sequential_exec(p_big, inputs)
        for a in kd.outputs:
            np.testing.assert_allclose(
                np.asarray(od[a], np.float64), ref[a], rtol=2e-3, atol=1e-4,
                err_msg=f"codegen bench: generated kernel for '{name}' "
                        f"(n={nb}) diverges from sequential_exec")
        us_d, us_s = time_us(fd, inputs), time_us(fs, inputs)
        out[name] = {
            "dse_n": nd, "bench_n": nb,
            "pipeline": knee.desc,
            "mode": kd.mode, "buffered_grid": list(kd.grid or ()),
            "block_rows": kd.block_rows, "halo": kd.halo,
            "modeled_latency": big.latency,
            "measured_us_double": round(us_d, 2),
            "measured_us_single": round(us_s, 2),
            "double_speedup": round(us_s / us_d, 3),
            "bitexact_double_vs_single": bitexact,
            "vmem_window_elems_double":
                tile_window_elems(big.program, buffers=2),
            "codegen_seconds": round(time.time() - t0, 2),
        }
    wins = [n for n, rec in out.items() if rec["double_speedup"] > 1.0]
    if len(wins) < 2:
        raise RuntimeError(
            "codegen bench: double-buffering beats single-buffering only "
            f"on {wins} — need >= 2 chains")
    ratios = {n: rec["measured_us_double"] / max(rec["modeled_latency"], 1)
              for n, rec in out.items()}
    gm = math.exp(sum(math.log(v) for v in ratios.values()) / len(ratios))
    for n, rec in out.items():
        rec["drift_normalized"] = round(ratios[n] / gm, 3)
        lo, hi = CODEGEN_DRIFT_BAND
        if not (lo <= rec["drift_normalized"] <= hi):
            raise RuntimeError(
                f"codegen bench: modeled-vs-measured drift on '{n}': "
                f"normalized ratio {rec['drift_normalized']} outside "
                f"[{lo}, {hi}]")
    cache[storage] = out
    json.dump(cache, open(CODEGEN_JSON, "w"), indent=1)
    return out


def codegen_table(res: dict) -> list[tuple]:
    """Measured wall-clock (interpret) next to modeled latency, per chain."""
    rows = []
    for name, r in res.items():
        rows.append((f"{name}.measured_double", r["measured_us_double"],
                     f"modeled={r['modeled_latency']}"))
        rows.append((f"{name}.measured_single", r["measured_us_single"],
                     f"double_speedup={r['double_speedup']}"))
        rows.append((f"{name}.drift_normalized", 0.0,
                     r["drift_normalized"]))
        rows.append((f"{name}.config", 0.0,
                     f"block_rows={r['block_rows']};grid="
                     + "x".join(map(str, r["buffered_grid"]))))
    return rows


# Tracing-frontend snapshot (DESIGN.md §11), next to the other
# BENCH_*.json files: frontier size + modeled speedup for the traced
# kernels, proving real JAX functions flow through trace -> DSE end-to-end.
TRACE_JSON = os.path.join(os.path.dirname(__file__), os.pardir,
                          "BENCH_trace.json")


def compute_trace(storage: str = "bram", force: bool = False) -> dict:
    """Trace the bundled JAX kernels (wkv6 scan, separable conv block,
    softmax attention) into Program IR, differentially validate each traced
    program against its source function, and run the Pareto DSE on the
    result.  Gates (raise):

    * every traced program matches its source kernel under
      ``sequential_exec`` at rtol=1e-12 (the differential contract),
    * every traced program's frontier has >= 2 points (a single-point
      frontier means the DSE found no latency/BRAM tradeoff on the traced
      IR — the generalized nest contract regressed).

    Results go to ``BENCH_trace.json``.  ``storage`` is recorded for cache
    symmetry with the other suites; traced arrays always use the frontend's
    dual-read BRAM preset."""
    cache = {}
    if os.path.exists(TRACE_JSON):
        cache = json.load(open(TRACE_JSON))
    if storage in cache and not force:
        return cache[storage]

    from repro.core import frontend, hls
    from repro.core.autotune import measure_candidate
    from repro.core.ir import nest_shape

    traced = {
        "wkv6": frontend.wkv6_program,
        "conv_block": frontend.conv_block_program,
        "attention": frontend.attention_program,
    }
    out = {}
    for name, mk in traced.items():
        t0 = time.time()
        tp = mk()
        err = tp.validate(seed=0, rtol=1e-12)  # raises past rtol
        r = hls.compile(tp.program,
                        objectives=(hls.minimize("latency"),
                                    hls.minimize("bram")))
        if len(r.frontier) < 2:
            raise RuntimeError(
                f"trace bench: '{name}' ({tp.program.name}) produced a "
                "single-point frontier — the traced IR stopped being "
                "DSE-searchable")
        base = measure_candidate(tp.program, "baseline", [], verify=False)
        best = min(c.latency for c in r.frontier)
        out[name] = {
            "program": tp.program.name,
            "nest_kinds": list(nest_shape(tp.program).kinds),
            "validate_max_rel_err": float(err),
            "frontier_size": len(r.frontier),
            "baseline_latency": int(base.latency),
            "best_latency": int(best),
            "modeled_speedup": round(base.latency / max(best, 1), 3),
            "trace_seconds": round(time.time() - t0, 2),
        }
    cache[storage] = out
    json.dump(cache, open(TRACE_JSON, "w"), indent=1)
    return out


def trace_table(res: dict) -> list[tuple]:
    """Frontier size + modeled speedup per traced kernel."""
    rows = []
    for name, r in res.items():
        rows.append((f"{name}.frontier_size", 0.0, r["frontier_size"]))
        rows.append((f"{name}.modeled_speedup", 0.0,
                     f"{r['modeled_speedup']} "
                     f"(base={r['baseline_latency']},"
                     f"best={r['best_latency']})"))
        rows.append((f"{name}.validate", 0.0,
                     f"max_rel_err={r['validate_max_rel_err']:.2e};"
                     f"kinds={'+'.join(r['nest_kinds'])}"))
    return rows


def _hypervolume2d(points: list[tuple], ref: tuple) -> float:
    """Dominated 2D hypervolume (minimization) of ``points`` w.r.t. the
    reference corner ``ref``: the area between the non-dominated staircase
    and ``ref``.  Points beyond the reference contribute nothing."""
    pts = sorted({(min(x, ref[0]), min(y, ref[1])) for x, y in points})
    hv = 0.0
    last_y = ref[1]
    for x, y in pts:
        if y < last_y:
            hv += (ref[0] - x) * (last_y - y)
            last_y = y
    return hv


def compute_pareto(storage: str = "bram", force: bool = False) -> dict:
    """Pareto-frontier DSE sweep (hls.compile, DESIGN.md §6): for every
    mismatched-bounds chain plus harris/optical_flow/two_mm, record the
    frontier (pipelines + objective vectors), its latency x BRAM
    hypervolume normalized to the baseline design, and the comparison
    against the old greedy single-frontier explore() winner — the frontier
    must contain a point dominating-or-equal to it (no regression).
    Results go to ``BENCH_pareto.json``."""
    cache = {}
    if os.path.exists(PARETO_JSON):
        cache = json.load(open(PARETO_JSON))
    if storage in cache and not force:
        return cache[storage]

    from repro.core import hls
    from repro.core.autotune import _greedy_explore, dominates
    from repro.core.programs import (CHAIN_BENCHMARKS, harris, optical_flow,
                                     two_mm)

    progs = {**CHAIN_BENCHMARKS, "harris": harris,
             "optical_flow": optical_flow, "two_mm": two_mm}
    out = {}
    for name, mk in progs.items():
        n = _PARETO_SIZES.get(name, 8)
        p = mk(n, storage=storage)
        t0 = time.time()
        greedy = _greedy_explore(p, max_candidates=16)
        r = hls.compile(p, search=hls.SearchConfig(max_candidates=16))
        base = r.baseline

        def norm(c):
            return (c.latency / max(base.latency, 1),
                    c.res["bram_bytes"] / max(base.res["bram_bytes"], 1.0))

        ref = (1.05, 1.05)  # just beyond the baseline corner
        gv = greedy.best.objectives()
        out[name] = {
            "n": n,
            "baseline": {"latency": base.latency, **base.res},
            "frontier_size": len(r.frontier),
            "frontier": [
                {"pipeline": r.pipeline_of(c), "latency": c.latency, **c.res}
                for c in r.frontier],
            "hypervolume": round(
                _hypervolume2d([norm(c) for c in r.frontier], ref), 5),
            "greedy_hypervolume": round(
                _hypervolume2d([norm(greedy.best)], ref), 5),
            "greedy_winner": {"pipeline": greedy.best.desc,
                              "latency": greedy.best.latency,
                              **greedy.best.res},
            "dominates_greedy": bool(any(
                dominates(c.objectives(), gv) or c.objectives() == gv
                for c in r.frontier)),
            "best_pipeline": r.pipeline_of(),
            "knee_pipeline": r.pipeline_of(r.knee("latency", "bram")),
            "pareto_seconds": round(time.time() - t0, 2),
        }
        if not out[name]["dominates_greedy"]:
            raise RuntimeError(
                f"pareto sweep: frontier of '{name}' (n={n}) contains no "
                f"point dominating-or-equal the greedy winner {gv}")
    cache[storage] = out
    json.dump(cache, open(PARETO_JSON, "w"), indent=1)
    return out


def pareto_table(res: dict) -> list[tuple]:
    """Frontier size + hypervolume vs the greedy winner, per program."""
    rows = []
    for name, r in res.items():
        rows.append((f"{name}.frontier_size", r["pareto_seconds"] * 1e6,
                     r["frontier_size"]))
        rows.append((f"{name}.hypervolume", 0.0, r["hypervolume"]))
        rows.append((f"{name}.greedy_hypervolume", 0.0,
                     r["greedy_hypervolume"]))
        rows.append((f"{name}.dominates_greedy", 0.0,
                     int(r["dominates_greedy"])))
        rows.append((f"{name}.knee", 0.0,
                     r["knee_pipeline"].replace(",", ";") or "baseline"))
    return rows


def fusion_table(res: dict) -> list[tuple]:
    """Fused-vs-unfused latency of the mismatched-bounds stencil chains."""
    rows = []
    for name, r in res.items():
        rows.append((f"{name}.speedup", r["fusion_seconds"] * 1e6,
                     r["speedup"]))
        rows.append((f"{name}.fused_latency", 0.0, r["fused_latency"]))
        rows.append((f"{name}.unfused_latency", 0.0, r["unfused_latency"]))
        rows.append((f"{name}.shift", 0.0,
                     "x".join(map(str, r["shift"]))))
    return rows


def dse_table(res: dict) -> list[tuple]:
    """The DSE column: latency speedup of the explored winner over the
    untransformed compile_program schedule, at equal-or-lower BRAM/DSP."""
    rows = []
    for name, r in res.items():
        rows.append((f"{name}.speedup", r["dse_seconds"] * 1e6, r["speedup"]))
        rows.append((f"{name}.winner", 0.0,
                     r["best_pipeline"].replace(",", ";")))
        rows.append((f"{name}.bram_ratio", 0.0, round(
            r["best_resources"]["bram_bytes"] /
            max(r["baseline_resources"]["bram_bytes"], 1.0), 3)))
    return rows


def fig7(res: dict) -> list[tuple]:
    """Speedup of multi-dimensional pipelining over loop-only pipelining
    (paper: 1.7x-3.7x, avg 2.42x)."""
    rows = []
    for name, r in res.items():
        rows.append((name, r["schedule_seconds"] * 1e6,
                     round(r["loop_only_orig"] / r["ours_orig"], 3)))
    return rows


def fig8(res: dict) -> list[tuple]:
    """SPSC workloads: ours and Vitis-dataflow vs Vitis-no-dataflow
    (paper: ours avg 1.30x over Vitis dataflow)."""
    rows = []
    for name, r in res.items():
        if not r["dataflow_applicable"]:
            continue  # the paper also dropped 2mm here
        base = r["loop_only_spsc"]
        rows.append((f"{name}.vitis_df", 0.0, round(base / r["vitis_dataflow_spsc"], 3)))
        rows.append((f"{name}.ours", 0.0, round(base / r["ours_spsc"], 3)))
        rows.append((f"{name}.ours_over_df", 0.0,
                     round(r["vitis_dataflow_spsc"] / r["ours_spsc"], 3)))
    return rows


def fig9(res: dict) -> list[tuple]:
    """Resource usage relative to Vitis-no-dataflow (model)."""
    rows = []
    for name, r in res.items():
        if not r["dataflow_applicable"]:
            continue
        for metric in ("bram_bytes", "ff_bits", "lut", "dsp"):
            base = max(r["resources_vitis_seq"][metric], 1.0)
            rows.append((f"{name}.{metric}.vitis_df", 0.0,
                         round(r["resources_vitis_df"][metric] / base, 3)))
            rows.append((f"{name}.{metric}.ours", 0.0,
                         round(r["resources_ours"][metric] / base, 3)))
    return rows


def fig10(res: dict) -> list[tuple]:
    """Unmodified (non-SPSC) workloads: ours vs Vitis-no-dataflow
    (paper: 2x-2.9x)."""
    rows = []
    for name, r in res.items():
        rows.append((name, 0.0,
                     round(r["loop_only_orig"] / r["ours_orig"], 3)))
    return rows


# ---------------------------------------------------------------------------
# Serving-scale DSE perf: persistent cache + parallel expansion (DESIGN.md §8)
# ---------------------------------------------------------------------------

# Cold/warm/parallel wall-clock of the hls.compile Pareto search over a
# fresh persistent store, next to the other BENCH_*.json snapshots.
DSE_PERF_JSON = os.path.join(os.path.dirname(__file__), os.pardir,
                             "BENCH_dse_perf.json")

# The CI gate (weekly job): warm-over-cold speedup floor per program, and
# the frontier must keep dominating the greedy explore() winner.
WARM_SPEEDUP_FLOOR = 5.0
PARALLEL_SPEEDUP_FLOOR = 2.0   # enforced only on machines with >= 4 cores


def _frontier_sig(r) -> list:
    """Everything observable about a frontier point, schedule included —
    cold, warm and parallel runs must agree on this exactly."""
    return [(c.desc, c.latency,
             {k: c.res[k] for k in ("bram_bytes", "dsp", "ff_bits")},
             sorted(c.schedule.iis.values()),
             sorted(c.schedule.theta.values()))
            for c in r.frontier]


def compute_dse_perf(storage: str = "bram", force: bool = False,
                     jobs: int = 4) -> dict:
    """Serving-scale DSE benchmark (DESIGN.md §8): for every
    mismatched-bounds chain plus harris/optical_flow/two_mm, time the
    hls.compile Pareto search (a) cold against a fresh persistent store,
    (b) warm against the store the cold run just filled, and (c) with the
    expansion waves fanned across ``jobs`` worker processes (store off, so
    it measures parallel compile, not cache hits).  Frontiers must be
    byte-identical across all three runs and must keep dominating the
    greedy explore() oracle; the warm run must clear
    ``WARM_SPEEDUP_FLOOR``.  Results go to ``BENCH_dse_perf.json``."""
    cache = {}
    if os.path.exists(DSE_PERF_JSON):
        cache = json.load(open(DSE_PERF_JSON))
    if storage in cache and not force:
        return cache[storage]

    import shutil
    import tempfile

    from repro.core import hls
    from repro.core.autotune import _greedy_explore, dominates
    from repro.core.programs import (CHAIN_BENCHMARKS, harris, optical_flow,
                                     two_mm)

    progs = {**CHAIN_BENCHMARKS, "harris": harris,
             "optical_flow": optical_flow, "two_mm": two_mm}
    # hermetic: the bench always starts from an empty store in a tmpdir —
    # a warm ~/.cache/repro-hls must not fake the cold numbers
    saved = {k: os.environ.get(k)
             for k in ("REPRO_HLS_CACHE", "REPRO_HLS_CACHE_DIR")}
    tmp = tempfile.mkdtemp(prefix="repro-hls-bench-")
    os.environ["REPRO_HLS_CACHE"] = "1"
    os.environ["REPRO_HLS_CACHE_DIR"] = tmp
    out = {}
    try:
        for name, mk in progs.items():
            n = _PARETO_SIZES.get(name, 8)

            def run(use_cache: bool, use_jobs: int = 1):
                t0 = time.time()
                r = hls.compile(mk(n, storage=storage),
                                search=hls.SearchConfig(
                                    max_candidates=16, jobs=use_jobs,
                                    cache=use_cache))
                return r, time.time() - t0

            cold_r, cold_s = run(True)
            warm_r, warm_s = run(True)
            par_r, par_s = run(False, use_jobs=jobs)
            greedy = _greedy_explore(mk(n, storage=storage),
                                     max_candidates=16)
            gv = greedy.best.objectives()

            sig = _frontier_sig(cold_r)
            rec = {
                "n": n,
                "cold_seconds": round(cold_s, 3),
                "warm_seconds": round(warm_s, 3),
                "parallel_seconds": round(par_s, 3),
                "warm_speedup": round(cold_s / max(warm_s, 1e-9), 1),
                "parallel_speedup": round(cold_s / max(par_s, 1e-9), 2),
                "parallel_jobs": jobs,
                "cpu_count": os.cpu_count(),
                "compiles_to_frontier": cold_r.compiles,
                "frontier_size": len(cold_r.frontier),
                "warm_cache_hits": sum(c.cached for c in warm_r.candidates),
                "frontier_identical_warm": _frontier_sig(warm_r) == sig,
                "frontier_identical_parallel": _frontier_sig(par_r) == sig,
                "dominates_greedy": bool(any(
                    dominates(c.objectives(), gv) or c.objectives() == gv
                    for c in cold_r.frontier)),
            }
            out[name] = rec
            if not (rec["frontier_identical_warm"]
                    and rec["frontier_identical_parallel"]):
                raise RuntimeError(
                    f"dse-perf: '{name}' frontier differs across "
                    "cold/warm/parallel runs — the cache or the parallel "
                    "merge broke determinism")
            if rec["warm_speedup"] < WARM_SPEEDUP_FLOOR:
                raise RuntimeError(
                    f"dse-perf: '{name}' warm-cache speedup "
                    f"{rec['warm_speedup']}x is under the "
                    f"{WARM_SPEEDUP_FLOOR}x floor "
                    f"(cold {cold_s:.2f}s, warm {warm_s:.2f}s)")
            if not rec["dominates_greedy"]:
                raise RuntimeError(
                    f"dse-perf: frontier of '{name}' no longer contains a "
                    f"point dominating-or-equal the greedy winner {gv}")
            if ((os.cpu_count() or 1) >= 4
                    and rec["parallel_speedup"] < PARALLEL_SPEEDUP_FLOOR):
                raise RuntimeError(
                    f"dse-perf: '{name}' jobs={jobs} speedup "
                    f"{rec['parallel_speedup']}x is under the "
                    f"{PARALLEL_SPEEDUP_FLOOR}x floor on a "
                    f"{os.cpu_count()}-core machine")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v
    cache[storage] = out
    json.dump(cache, open(DSE_PERF_JSON, "w"), indent=1)
    return out


def dse_perf_table(res: dict) -> list[tuple]:
    """Warm/parallel speedups + search effort, per program."""
    rows = []
    for name, r in res.items():
        rows.append((f"{name}.warm_speedup", r["cold_seconds"] * 1e6,
                     r["warm_speedup"]))
        rows.append((f"{name}.parallel_speedup", r["parallel_seconds"] * 1e6,
                     r["parallel_speedup"]))
        rows.append((f"{name}.compiles_to_frontier", 0.0,
                     r["compiles_to_frontier"]))
        rows.append((f"{name}.frontier_identical", 0.0,
                     int(r["frontier_identical_warm"]
                         and r["frontier_identical_parallel"])))
        rows.append((f"{name}.dominates_greedy", 0.0,
                     int(r["dominates_greedy"])))
    return rows


# ---------------------------------------------------------------------------
# Fault-tolerance benchmark (DESIGN.md §9): chaos runs vs the clean frontier
# ---------------------------------------------------------------------------

FAULTS_JSON = os.path.join(os.path.dirname(__file__), os.pardir,
                           "BENCH_faults.json")

# CI gate: recovered-fault runs must reproduce the clean frontier exactly,
# and a divergent frontier without provenance="degraded" fails the bench.
RECOVERY_OVERHEAD_CEIL = 25.0  # recovered-run wall-clock vs clean, max ratio


def _frontier_hv(r, ref_objs) -> float:
    """Latency x BRAM x DSP x FF hypervolume of ``r.frontier`` against a
    shared reference box (1.1x the axis-max over ``ref_objs``), normalized
    per axis so no unit dominates."""
    from repro.core.autotune import _hv

    if not r.frontier or not ref_objs:
        return 0.0
    lo = [min(col) for col in zip(*ref_objs)]
    hi = [max(col) for col in zip(*ref_objs)]
    span = [h - l if h > l else 1.0 for l, h in zip(lo, hi)]
    pts = [tuple((x - l) / s for x, l, s in
                 zip(c.objectives(), lo, span)) for c in r.frontier]
    return _hv(pts, tuple(1.1 for _ in lo))


def compute_faults(storage: str = "bram", force: bool = False) -> dict:
    """Chaos benchmark: for every mismatched-bounds chain run hls.compile
    (a) clean, (b) under a recovered-fault schedule (every worker's first
    attempt crashes, retries succeed), and (c) under a degrading schedule
    (every dependence/legality ILP times out at the root).  Gates: the
    recovered run must be frontier-identical to clean with "exact"
    provenance and bounded wall-clock overhead; the degraded run must
    either match the clean frontier or carry provenance="degraded" —
    an unlabeled divergent frontier fails the bench.  Results (hypervolume
    ratio degraded/clean, recovery overhead) go to ``BENCH_faults.json``."""
    cache = {}
    if os.path.exists(FAULTS_JSON):
        cache = json.load(open(FAULTS_JSON))
    if storage in cache and not force:
        return cache[storage]

    from repro.core import faults, hls
    from repro.core.programs import CHAIN_BENCHMARKS

    # hermetic: chaos runs must not read or poison a developer's store
    saved = os.environ.get("REPRO_HLS_CACHE")
    os.environ["REPRO_HLS_CACHE"] = "0"
    out = {}
    try:
        for name, mk in CHAIN_BENCHMARKS.items():
            n = _PARETO_SIZES.get(name, 8)

            def run(plan=None, jobs=1):
                t0 = time.time()
                search = hls.SearchConfig(max_candidates=16, jobs=jobs,
                                          cache=False,
                                          worker_deadline_s=60.0)
                if plan is None:
                    r = hls.compile(mk(n, storage=storage), search=search)
                else:
                    with faults.inject(**plan):
                        r = hls.compile(mk(n, storage=storage),
                                        search=search)
                return r, time.time() - t0

            clean_r, clean_s = run()
            sig = _frontier_sig(clean_r)
            ref_objs = [c.objectives() for c in clean_r.frontier]
            hv_clean = _frontier_hv(clean_r, ref_objs)

            rec_r, rec_s = run(dict(seed=0, worker_crash=1.0,
                                    crash_attempts=(0,)), jobs=2)
            deg_r, deg_s = run(dict(seed=0, solver_timeout=1.0))

            rec = {
                "n": n,
                "clean_seconds": round(clean_s, 3),
                "recovered_seconds": round(rec_s, 3),
                "degraded_seconds": round(deg_s, 3),
                "recovery_overhead": round(rec_s / max(clean_s, 1e-9), 2),
                "frontier_size": len(clean_r.frontier),
                "recovered_identical": _frontier_sig(rec_r) == sig,
                "recovered_provenance": rec_r.provenance,
                "recovered_retries": sum(
                    d.get("kind") == "worker-retry"
                    for d in rec_r.diagnostics),
                "degraded_identical": _frontier_sig(deg_r) == sig,
                "degraded_provenance": deg_r.provenance,
                "degraded_frontier_size": len(deg_r.frontier),
                "hv_clean": round(hv_clean, 4),
                "hv_degraded": round(_frontier_hv(deg_r, ref_objs), 4),
                "hv_ratio": round(
                    _frontier_hv(deg_r, ref_objs) / max(hv_clean, 1e-9), 3),
            }
            out[name] = rec
            if clean_r.provenance != "exact":
                raise RuntimeError(
                    f"faults: clean run of '{name}' claims degraded "
                    "provenance — the fault harness leaked into a "
                    "fault-free compile")
            if not rec["recovered_identical"] \
                    or rec["recovered_provenance"] != "exact":
                raise RuntimeError(
                    f"faults: '{name}' recovered-fault frontier diverged "
                    f"from clean (identical={rec['recovered_identical']}, "
                    f"provenance={rec['recovered_provenance']}) — retried "
                    "worker faults must be invisible in the result")
            if not rec["degraded_identical"] \
                    and rec["degraded_provenance"] != "degraded":
                raise RuntimeError(
                    f"faults: '{name}' degraded run diverged from the "
                    "clean frontier WITHOUT provenance='degraded' — "
                    "unlabeled divergence is unsound")
            if rec["recovery_overhead"] > RECOVERY_OVERHEAD_CEIL:
                raise RuntimeError(
                    f"faults: '{name}' recovery overhead "
                    f"{rec['recovery_overhead']}x exceeds the "
                    f"{RECOVERY_OVERHEAD_CEIL}x ceiling "
                    f"(clean {clean_s:.2f}s, recovered {rec_s:.2f}s)")
    finally:
        if saved is None:
            os.environ.pop("REPRO_HLS_CACHE", None)
        else:
            os.environ["REPRO_HLS_CACHE"] = saved
    cache[storage] = out
    json.dump(cache, open(FAULTS_JSON, "w"), indent=1)
    return out


def faults_table(res: dict) -> list[tuple]:
    """Recovery overhead + degraded-vs-clean hypervolume, per program."""
    rows = []
    for name, r in res.items():
        rows.append((f"{name}.recovery_overhead", r["recovered_seconds"] * 1e6,
                     r["recovery_overhead"]))
        rows.append((f"{name}.recovered_identical", 0.0,
                     int(r["recovered_identical"])))
        rows.append((f"{name}.degraded_labeled", 0.0,
                     int(r["degraded_identical"]
                         or r["degraded_provenance"] == "degraded")))
        rows.append((f"{name}.hv_ratio", r["degraded_seconds"] * 1e6,
                     r["hv_ratio"]))
    return rows


ANALYSIS_JSON = os.path.join(os.path.dirname(__file__), os.pardir,
                             "BENCH_analysis.json")


def compute_analysis(storage: str = "bram", force: bool = False) -> dict:
    """Static-verifier benchmark (DESIGN.md §12): per mismatched-bounds
    chain, (a) lint the program and wall-clock the linter, (b) compile and
    run the independent schedule validator on the winner, (c) fire 25
    seeded schedule corruptions at the validator.  Gates (raise):

    * the corpus lints with zero error-severity findings,
    * every genuine winner schedule is accepted,
    * every corrupted schedule is rejected (the mutation-kill property).

    Results go to ``BENCH_analysis.json``."""
    cache = {}
    if os.path.exists(ANALYSIS_JSON):
        cache = json.load(open(ANALYSIS_JSON))
    if storage in cache and not force:
        return cache[storage]

    import numpy as np

    from repro.core import hls
    from repro.core.analysis import corrupt_schedule, lint, validate_static
    from repro.core.programs import CHAIN_BENCHMARKS

    out = {}
    for name, mk in CHAIN_BENCHMARKS.items():
        n = _PARETO_SIZES.get(name, 8)
        p = mk(n=n)
        t0 = time.time()
        diags = lint(p)
        lint_s = time.time() - t0
        errors = [d for d in diags if d.severity == "error"]
        if errors:
            raise AssertionError(
                f"{name}: lint errors {[str(d) for d in errors]}")
        r = hls.compile(p, pipeline=())
        s = r.best.schedule
        t0 = time.time()
        v = validate_static(s.program, s)
        val_s = time.time() - t0
        if not v.ok:
            raise AssertionError(
                f"{name}: golden schedule rejected: "
                f"{[str(d) for d in v.diagnostics]}")
        rng = np.random.default_rng(20260807)
        killed = tries = 0
        t0 = time.time()
        while killed < 25 and tries < 250:
            tries += 1
            made = corrupt_schedule(s, rng)
            if made is None:
                continue
            mut, info = made
            if validate_static(mut.program, mut, fail_fast=True).ok:
                raise AssertionError(f"{name}: validator accepted "
                                     f"corrupted schedule {info}")
            killed += 1
        mut_s = time.time() - t0
        out[name] = {
            "lint_findings": len(diags), "lint_seconds": lint_s,
            "pairs": v.pairs, "cases": v.cases, "ilp_calls": v.ilp_calls,
            "validate_seconds": val_s,
            "mutants_killed": killed, "mutation_seconds": mut_s,
        }

    cache[storage] = out
    json.dump(cache, open(ANALYSIS_JSON, "w"), indent=1)
    return out


def analysis_table(res: dict) -> list[tuple]:
    """Linter/validator wall-clock + mutation-kill rate, per chain."""
    rows = []
    for name, r in res.items():
        rows.append((f"{name}.lint", r["lint_seconds"] * 1e6,
                     f"findings={r['lint_findings']}"))
        rows.append((f"{name}.validate", r["validate_seconds"] * 1e6,
                     f"pairs={r['pairs']};cases={r['cases']};"
                     f"ilp={r['ilp_calls']}"))
        rows.append((f"{name}.mutation_kill", r["mutation_seconds"] * 1e6,
                     f"{r['mutants_killed']}/{r['mutants_killed']}"))
    return rows

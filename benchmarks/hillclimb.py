"""§Perf hillclimbing driver: hypothesis -> change -> re-lower -> measure.

Each target cell gets a list of config deltas (cumulative and standalone);
every step re-runs the dry-run compile and records the roofline terms next
to the hypothesis, so EXPERIMENTS.md §Perf can show the full
confirmed/refuted log.

Run AFTER the baseline sweep:
    PYTHONPATH=src python -m benchmarks.hillclimb [cell]
"""
import dataclasses
import json
import os
import sys
import time

HERE = os.path.dirname(__file__)
OUT = os.path.join(HERE, "results", "hillclimb")

# (step_name, hypothesis, cfg_deltas)
PLANS = {
    ("llama3_405b", "train_4k"): [
        ("remat_dots",
         "memory-dominant (137s vs compute 66s): full remat re-reads+"
         "recomputes the whole fwd in bwd; saving dot outputs "
         "(checkpoint_dots) should cut bwd traffic ~25% and flops ~20%",
         dict(remat="dots")),
        ("chunked_attn",
         "fp32 (S x S) score tensors are ~40% of layer bytes; online-softmax "
         "kv-chunking keeps score blocks transient -> memory term down, "
         "collective unchanged",
         dict(attn_impl="chunked", attn_chunk=1024)),
        ("chunked_attn+remat_dots",
         "the two levers are independent (traffic from different tensors); "
         "expect roughly multiplicative gains",
         dict(attn_impl="chunked", attn_chunk=1024, remat="dots")),
        ("chunked+dots+logits_bf16",
         "fp32 logits of 128k vocab cost (B,S,V/16)*4B several times in "
         "CE+bwd; bf16 logits halve that",
         dict(attn_impl="chunked", attn_chunk=1024, remat="dots",
              logits_fp32=False)),
        ("scores_bf16+dots",
         "the f32 (S x S) score chain (~6 traversals x 8.6GB/layer) is the "
         "single biggest traffic source; bf16 scores with fp32 row stats "
         "(flash numerics) halve it",
         dict(scores_bf16=True, remat="dots")),
        ("fsdp_only+dots+scores_bf16",
         "rwkv showed TP all-reduces dominate the collective term; 405B "
         "ZeRO-only over 256 chips (3.2GB params + 9.5GB optimizer/chip) "
         "drops the per-layer activation all-reduces entirely",
         dict(parallel_style="fsdp", remat="dots", scores_bf16=True)),
    ],
    ("kimi_k2_1t_a32b", "train_4k"): [
        ("remat_dots",
         "memory 141s / collective 78s / compute 38s: same remat lever as "
         "llama — bwd recompute of 61 MoE layers dominates traffic",
         dict(remat="dots")),
        ("capacity_1.0",
         "expert capacity factor 1.25 pads 25% dead slots through dispatch, "
         "expert matmuls and combine; cf=1.0 cuts expert flops/bytes and "
         "all-to-all volume ~20% (dropped-token tradeoff documented)",
         dict(_moe_cf=1.0)),
        ("dots+cf1.0+chunked",
         "combine the independent levers",
         dict(remat="dots", _moe_cf=1.0, attn_impl="chunked",
              attn_chunk=1024)),
        ("ep_style+dots",
         "REFUTED: capacity/chunking barely moved the collective term, so "
         "try experts-on-model + ZeRO elsewhere — XLA falls into "
         "'involuntary full rematerialization' resharding the dispatch "
         "buffers (collective 62.9 -> 1164s).  Kept as a negative result.",
         dict(parallel_style="ep", remat="dots")),
        ("sort_dispatch",
         "profile shows the one-hot cumsum dispatch materializes "
         "O(T*K*E) tensors — 13 TB at E=384 — dominating both the memory "
         "term and the resharding all-reduces; sort-based "
         "position-in-expert removes the E factor entirely "
         "(code change in layers.moe_forward, now the default)",
         dict()),
        ("sort_dispatch+dots",
         "stack the confirmed levers",
         dict(remat="dots")),
        ("sort+dots+cf1.0",
         "with dispatch fixed, capacity padding is a larger share",
         dict(remat="dots", _moe_cf=1.0)),
    ],
    ("rwkv6_3b", "train_4k"): [
        ("fsdp_only",
         "3B params over 256 chips makes TP matmuls tiny (2560/16=160 cols) "
         "while paying 2 all-reduces of the activations per layer; ZeRO-only "
         "sharding (batch over all 256) removes TP collectives entirely — "
         "expect the collective term (14.1s, dominant) to drop >5x",
         dict(parallel_style="fsdp")),
        ("fsdp+remat_dots",
         "with collectives gone the memory term dominates; save dots",
         dict(parallel_style="fsdp", remat="dots")),
    ],
}


def run_cell(arch, shape, steps):
    # late imports: dryrun sets xla_force_host_platform_device_count=512
    from repro.config import get_config
    from repro.launch import dryrun as dr
    from benchmarks.roofline import compute_terms

    os.makedirs(OUT, exist_ok=True)
    base_path = os.path.join(HERE, "results", "dryrun",
                             f"{arch}_{shape}_single.json")
    baseline = compute_terms(json.load(open(base_path)))
    print(f"== {arch} x {shape} baseline: compute {baseline['compute_s']:.1f}s "
          f"memory {baseline['memory_s']:.1f}s collective "
          f"{baseline['collective_s']:.1f}s dominant={baseline['dominant']} "
          f"fraction={baseline['roofline_fraction']:.4f}")
    results = [("baseline", "", baseline)]
    for name, hypothesis, deltas in steps:
        cfg = get_config(arch)
        d = dict(deltas)
        cf = d.pop("_moe_cf", None)
        if cf is not None:
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=cf))
        cfg = dataclasses.replace(cfg, **d)
        t0 = time.time()
        rec = dr.dryrun_cell(arch, shape, False, cfg_override=cfg)
        rec = compute_terms(rec)
        rec["hypothesis"] = hypothesis
        rec["step"] = name
        json.dump(rec, open(os.path.join(OUT, f"{arch}_{shape}__{name}.json"),
                            "w"), indent=1)
        dm = baseline["memory_s"] / max(rec["memory_s"], 1e-9)
        dc = baseline["collective_s"] / max(rec["collective_s"], 1e-9)
        df = baseline["compute_s"] / max(rec["compute_s"], 1e-9)
        print(f"  [{name}] ({time.time()-t0:.0f}s) compute {rec['compute_s']:.1f}s "
              f"(x{df:.2f}) memory {rec['memory_s']:.1f}s (x{dm:.2f}) "
              f"collective {rec['collective_s']:.1f}s (x{dc:.2f}) "
              f"dominant={rec['dominant']} fraction={rec['roofline_fraction']:.4f}")
        results.append((name, hypothesis, rec))
    return results


def main():
    only = sys.argv[1] if len(sys.argv) > 1 else None
    for (arch, shape), steps in PLANS.items():
        if only and only not in arch:
            continue
        run_cell(arch, shape, steps)


if __name__ == "__main__":
    main()

"""Benchmark harness — one section per paper table/figure + the framework's
own dry-run/roofline tables.  Prints ``name,us_per_call,derived`` CSV.

``python benchmarks/run.py`` runs everything; ``python benchmarks/run.py
SUITE`` runs one suite.  Unknown suite names are a hard argparse error (the
old ``sys.argv[1]`` filter silently ran nothing).
"""
from __future__ import annotations

import argparse

#: every runnable suite — argparse rejects anything else
SUITES = ("paper", "reg", "bram", "dse", "pareto", "dse-perf", "faults",
          "fusion", "codegen", "trace", "analysis", "pipeline", "kernels",
          "roofline")


def _emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="Run the benchmark suites (all by default).")
    ap.add_argument("suite", nargs="?", choices=SUITES, metavar="suite",
                    help=f"one of: {', '.join(SUITES)}")
    only = ap.parse_args(argv).suite

    from benchmarks import paper

    for storage in ("reg", "bram"):
        if only and only not in ("paper", storage):
            continue
        res = paper.compute(storage=storage)
        print("# === paper Fig.7 — multi-dim pipelining vs loop-only "
              f"[{storage}] (paper band: 1.7-3.7x, avg 2.42x) ===")
        rows = paper.fig7(res)
        _emit([(f"fig7.{storage}.{n}", us, d) for n, us, d in rows])
        avg = sum(d for _, _, d in rows) / len(rows)
        print(f"fig7.{storage}.average,0.0,{avg:.3f}")

        print("# === paper Fig.8 — vs Vitis-dataflow model on SPSC variants "
              f"[{storage}] (paper: ours avg 1.30x over dataflow) ===")
        _emit([(f"fig8.{storage}.{n}", us, d) for n, us, d in paper.fig8(res)])

        print("# === paper Fig.9 — resource model relative to Vitis-seq "
              f"[{storage}] ===")
        _emit([(f"fig9.{storage}.{n}", us, d) for n, us, d in paper.fig9(res)])

        print("# === paper Fig.10 — unmodified non-SPSC workloads "
              f"[{storage}] (paper band: 2-2.9x) ===")
        _emit([(f"fig10.{storage}.{n}", us, d) for n, us, d in paper.fig10(res)])

    if only in (None, "dse"):
        print("# === pass-pipeline DSE — transformed program vs untransformed "
              "compile_program under the iso-resource budget (DESIGN.md §6) ===")
        # always re-run: this section IS the verification sweep, a cached
        # replay would hide transform/DSE regressions (the JSON still caches
        # for read-only consumers like dse_table)
        res = paper.compute_dse(storage="bram", force=True)
        _emit([(f"dse.bram.{n}", us, d) for n, us, d in paper.dse_table(res)])

    if only in (None, "pareto"):
        print("# === Pareto-frontier DSE — hls.compile frontier sizes + "
              "latency x BRAM hypervolume vs the old greedy explore() winner "
              "(DESIGN.md §6) ===")
        # always re-run: this section IS the no-regression check (it raises
        # when a frontier stops dominating the greedy winner)
        res = paper.compute_pareto(storage="bram", force=True)
        _emit([(f"pareto.bram.{n}", us, d)
               for n, us, d in paper.pareto_table(res)])

    if only in (None, "dse-perf"):
        print("# === serving-scale DSE — persistent-cache warm/cold + "
              "parallel frontier expansion (DESIGN.md §8) ===")
        # always re-run against a fresh tmpdir store: this section IS the
        # determinism + speedup gate (it raises when the warm-cache speedup
        # drops under the floor, a frontier stops being byte-identical
        # across cold/warm/parallel, or stops dominating the greedy oracle)
        res = paper.compute_dse_perf(storage="bram", force=True)
        _emit([(f"dse_perf.bram.{n}", us, d)
               for n, us, d in paper.dse_perf_table(res)])

    if only in (None, "faults"):
        print("# === fault tolerance — chaos runs vs the clean frontier: "
              "recovery overhead + degraded hypervolume (DESIGN.md §9) ===")
        # always re-run: this section IS the failure-handling gate (it
        # raises when a recovered-fault run moves the frontier, when a
        # divergent degraded frontier goes unlabeled, or when the recovery
        # overhead blows past the ceiling)
        res = paper.compute_faults(storage="bram", force=True)
        _emit([(f"faults.bram.{n}", us, d)
               for n, us, d in paper.faults_table(res)])

    if only in (None, "fusion"):
        print("# === shift-and-peel fusion — mismatched-bounds stencil chains, "
              "fused vs unfused schedule (DESIGN.md §6) ===")
        # always re-run: this section verifies every fused candidate
        # differentially and the winner against the brute-force oracles
        res = paper.compute_fusion(storage="bram", force=True)
        _emit([(f"fusion.bram.{n}", us, d) for n, us, d in paper.fusion_table(res)])

    if only in (None, "codegen"):
        print("# === codegen — generated Pallas kernels: measured wall-clock "
              "(interpret, double vs single buffering) next to modeled "
              "latency (DESIGN.md §10) ===")
        # always re-run: this section IS the modeled-vs-measured drift gate
        # (it raises when double-buffering stops beating single on >= 2
        # chains, outputs stop being bit-identical across bufferings, a
        # kernel diverges from sequential_exec, or a chain's normalized
        # measured/modeled ratio leaves the pinned band)
        res = paper.compute_codegen(storage="bram", force=True)
        _emit([(f"codegen.bram.{n}", us, d)
               for n, us, d in paper.codegen_table(res)])

    if only in (None, "trace"):
        print("# === tracing frontend — traced JAX kernels (wkv6 scan, conv "
              "block, attention): frontier size + modeled speedup "
              "(DESIGN.md §11) ===")
        # always re-run: this section IS the frontend acceptance gate (it
        # raises when a traced program diverges from its source kernel or
        # when a traced frontier collapses to a single point)
        res = paper.compute_trace(storage="bram", force=True)
        _emit([(f"trace.bram.{n}", us, d) for n, us, d in paper.trace_table(res)])

    if only in (None, "analysis"):
        print("# === static verifier — linter + independent schedule "
              "validation wall-clock, and the mutation-kill gate "
              "(DESIGN.md §12) ===")
        # always re-run: this section IS the verifier acceptance gate (it
        # raises on any corpus lint error, any rejected genuine schedule,
        # or any accepted corrupted schedule)
        res = paper.compute_analysis(storage="bram", force=True)
        _emit([(f"analysis.bram.{n}", us, d)
               for n, us, d in paper.analysis_table(res)])

    if only in (None, "pipeline"):
        try:
            from benchmarks import pipeline_ilp_bench
            pipeline_ilp_bench.run(_emit)
        except Exception as e:  # pragma: no cover
            print(f"# pipeline_ilp bench unavailable: {e}")

    if only in (None, "kernels"):
        try:
            from benchmarks import kernel_bench
            kernel_bench.run(_emit)
        except Exception as e:  # pragma: no cover
            print(f"# kernel bench unavailable: {e}")

    if only in (None, "roofline"):
        try:
            from benchmarks import roofline
            roofline.report(_emit)
        except Exception as e:  # pragma: no cover
            print(f"# roofline report unavailable (run launch.dryrun first): {e}")


if __name__ == "__main__":
    main()

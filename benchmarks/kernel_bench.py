"""Kernel microbenchmarks: Pallas (interpret) vs jnp reference on CPU.

Wall-clock on this container measures the *reference* path meaningfully and
the kernels only structurally (interpret mode is a Python interpreter); the
derived column therefore reports correctness deltas + modeled VMEM working
sets, not CPU time ratios."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, n=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / n * 1e6


def run(emit):
    print("# === Pallas kernels (interpret-mode correctness + ref timing) ===")
    rows = []
    # flash attention
    B, H, S, hd = 1, 2, 256, 64
    ks = jax.random.split(jax.random.key(0), 3)
    q, k, v = (jax.random.normal(ks[i], (B, H, S, hd)) for i in range(3))
    us = _time(lambda a, b, c: ref.flash_attention_ref(a, b, c), q, k, v)
    got = ops.flash_attention(q, k, v, interpret=True)
    err = float(jnp.max(jnp.abs(got - ref.flash_attention_ref(q, k, v))))
    rows.append(("kernel.flash_attention.ref_us", us, f"maxerr={err:.1e}"))
    vmem = (128 * hd + 2 * 128 * hd + 128 * 128) * 4
    rows.append(("kernel.flash_attention.vmem_bytes_per_block", 0.0, vmem))
    # stencil pipeline
    img = jax.random.normal(jax.random.key(1), (66, 130))
    wx = jnp.asarray([0.25, 0.5, 0.25])
    us = _time(lambda a: ref.stencil_pipeline_ref(a, wx, wx), img)
    got = ops.stencil_pipeline(img, wx, wx, interpret=True)
    err = float(jnp.max(jnp.abs(got - ref.stencil_pipeline_ref(img, wx, wx))))
    rows.append(("kernel.stencil_pipeline.ref_us", us, f"maxerr={err:.1e}"))
    from repro.kernels.stencil_pipeline import _stencil_codegen_config
    br, halo = _stencil_codegen_config()
    rows.append(("kernel.stencil_pipeline.dse_config", 0.0,
                 f"block_rows={br};halo={halo}"))
    rows.append(("kernel.stencil_pipeline.ilp_halo_rows_fallback", 0.0,
                 ops.ilp_halo_rows(3)))
    # wkv6
    B, H, S, hd = 1, 2, 128, 64
    ks = jax.random.split(jax.random.key(2), 4)
    r, k2, v2 = (jax.random.normal(ks[i], (B, H, S, hd)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, H, S, hd))) * 0.5 + 0.45
    u = jnp.zeros((H, hd))
    us = _time(lambda *a: ref.wkv6_ref(*a)[0], r, k2, v2, w, u)
    got = ops.wkv6(r, k2, v2, w, u, interpret=True)
    err = float(jnp.max(jnp.abs(got - ref.wkv6_ref(r, k2, v2, w, u)[0])))
    rows.append(("kernel.wkv6.ref_us", us, f"maxerr={err:.1e}"))
    emit(rows)

"""Pipeline-schedule quality: the ILP-derived schedule vs GPipe-style and
non-pipelined baselines (latency in ticks; peak in-flight activations)."""
from __future__ import annotations

import time

from repro.core import overlap, pipeline_ilp as pp


def run(emit):
    print("# === pipeline-ILP schedules (paper §4.2 applied to PP) ===")
    rows = []
    for S, M in ((4, 8), (8, 16), (8, 32), (16, 32)):
        t0 = time.time()
        s = pp.synthesize(S, M, t_f=1, t_b=2)
        us = (time.time() - t0) * 1e6
        gp = pp.gpipe_latency(S, M)
        sq = pp.sequential_latency(S, M)
        rows.append((f"pp.S{S}M{M}.latency_ticks", us, s.latency))
        rows.append((f"pp.S{S}M{M}.vs_sequential", 0.0,
                     round(sq / s.latency, 3)))
        rows.append((f"pp.S{S}M{M}.vs_gpipe_latency", 0.0,
                     round(gp / s.latency, 3)))
        rows.append((f"pp.S{S}M{M}.peak_act", 0.0, s.peak_live_activations))
        rows.append((f"pp.S{S}M{M}.gpipe_peak_act", 0.0, S * M))
    t0 = time.time()
    enc = pp.synthesize(6, 8, t_f=1, backward=False, cross_from=1)
    rows.append(("pp.encdec_nonSPSC.ii", (time.time() - t0) * 1e6, enc.ii))
    for n in (4, 8, 16):
        plan = overlap.plan_ring_overlap(n)
        rows.append((f"overlap.ring{n}.ii", 0.0, plan.ii))
        rows.append((f"overlap.ring{n}.speedup_vs_serial", 0.0,
                     round(plan.overlap_speedup, 3)))
    emit(rows)

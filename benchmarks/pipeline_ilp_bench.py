"""Pipeline-schedule quality: the ILP-derived schedule vs GPipe-style and
non-pipelined baselines (latency in ticks; peak in-flight activations) —
plus scheduler *compile-time* tracking (DESIGN.md §5): wall-clock rows per
config and a ``BENCH_sched_compile.json`` snapshot at the repo root so the
perf trajectory of the compilation hot path is visible across PRs."""
from __future__ import annotations

import json
import pathlib
import time

from repro.core import overlap, pipeline_ilp as pp

_BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_sched_compile.json"


def _compile_corpus_rows():
    """Compile-time rows for the paper benchmark corpus (reduced size so the
    bench run stays interactive; the shape of the trend is what matters)."""
    from repro.core import compile_program
    from repro.core.programs import fig3_conv1d, unsharp, dus, two_mm

    rows = []
    for name, mk in (("fig3", fig3_conv1d), ("unsharp16", lambda: unsharp(16)),
                     ("dus16", lambda: dus(16)), ("two_mm8", lambda: two_mm(8))):
        p = mk()
        t0 = time.perf_counter()
        compile_program(p)
        ms = (time.perf_counter() - t0) * 1e3
        rows.append((f"compile.{name}.ms", ms * 1e3, round(ms, 2)))
    return rows


def run(emit):
    print("# === pipeline-ILP schedules (paper §4.2 applied to PP) ===")
    rows = []
    compile_ms = {}
    schedules = {}
    for S, M in ((4, 8), (8, 16), (8, 32), (16, 32)):
        t0 = time.perf_counter()
        s = pp.synthesize(S, M, t_f=1, t_b=2)
        dt = time.perf_counter() - t0
        us = dt * 1e6
        compile_ms[f"S{S}M{M}"] = round(dt * 1e3, 2)
        schedules[f"S{S}M{M}"] = dict(
            ii=s.ii, latency=s.latency, fwd_start=s.fwd_start,
            bwd_start=s.bwd_start, peak=s.peak_live_activations)
        gp = pp.gpipe_latency(S, M)
        sq = pp.sequential_latency(S, M)
        rows.append((f"pp.S{S}M{M}.compile_ms", us, compile_ms[f"S{S}M{M}"]))
        rows.append((f"pp.S{S}M{M}.latency_ticks", us, s.latency))
        rows.append((f"pp.S{S}M{M}.vs_sequential", 0.0,
                     round(sq / s.latency, 3)))
        rows.append((f"pp.S{S}M{M}.vs_gpipe_latency", 0.0,
                     round(gp / s.latency, 3)))
        rows.append((f"pp.S{S}M{M}.peak_act", 0.0, s.peak_live_activations))
        rows.append((f"pp.S{S}M{M}.gpipe_peak_act", 0.0, S * M))
    t0 = time.perf_counter()
    enc = pp.synthesize(6, 8, t_f=1, backward=False, cross_from=1)
    enc_dt = time.perf_counter() - t0
    compile_ms["encdec_nonSPSC"] = round(enc_dt * 1e3, 2)
    rows.append(("pp.encdec_nonSPSC.ii", enc_dt * 1e6, enc.ii))
    for n in (4, 8, 16):
        plan = overlap.plan_ring_overlap(n)
        rows.append((f"overlap.ring{n}.ii", 0.0, plan.ii))
        rows.append((f"overlap.ring{n}.speedup_vs_serial", 0.0,
                     round(plan.overlap_speedup, 3)))

    corpus_rows = _compile_corpus_rows()
    rows.extend(corpus_rows)
    emit(rows)

    # perf-trajectory snapshot (compared across PRs; schedules included so a
    # compile-time win that silently changed a schedule is caught in review)
    snapshot = {
        "generated_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "compile_ms": compile_ms,
        "corpus_compile_ms": {n.split(".")[1]: d for n, _, d in corpus_rows},
        "schedules": schedules,
    }
    _BENCH_JSON.write_text(json.dumps(snapshot, indent=1) + "\n")
    print(f"# wrote {_BENCH_JSON.name}")

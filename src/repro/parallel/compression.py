"""int8 gradient compression for data-parallel reduction.

Block-wise symmetric quantization (block = last dim) with an fp32 scale per
block; the all-reduce moves 1 byte/grad element + 4/block instead of 2-4.
Unbiasedness is preserved by stochastic rounding (seeded per step).  Used as
an opt-in distributed-optimization trick (launch/train.py --grad-compress,
hillclimb #2 in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x, key):
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-12
    y = x / scale
    noise = jax.random.uniform(key, x.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, axis_name: str, key):
    """psum a pytree of gradients in int8 (per-leaf blockwise scales).

    The scales are psum-maxed first so every participant uses the same grid;
    then int32-accumulated int8 payloads are reduced.  Returns fp32 grads."""
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = []
    for leaf, k in zip(leaves, keys):
        x = leaf.astype(jnp.float32)
        scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-12
        scale = jax.lax.pmax(scale, axis_name)          # shared grid
        noise = jax.random.uniform(k, x.shape, jnp.float32, -0.5, 0.5)
        q = jnp.clip(jnp.round(x / scale + noise), -127, 127).astype(jnp.int8)
        acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        out.append((acc.astype(jnp.float32) * scale / n).astype(leaf.dtype))
    return jax.tree.unflatten(treedef, out)

"""Declarative sharding rules: parameter/optimizer/batch/cache PartitionSpecs.

Scheme (mesh axes ``("pod",) data, model``):
  * FSDP   — weight matrices shard their *input-feature* dim over "data"
             (and "pod" when present): ZeRO-3-style, all-gathered per layer.
  * TP     — attention heads / FFN columns / MoE experts shard over tp.
  * DP     — the batch shards over ("pod", "data").
  * SP     — long-context decode (batch=1) shards KV caches over "data"
             (sequence dimension); XLA inserts the flash-decode style
             partial-softmax collectives.

Rules are keyed on the parameter leaf name; a leading stacked-period axis
(rank + 1) is padded with None automatically, so the same table serves both
the scanned blocks and the unstacked prefix layers.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ArchConfig, ShapeConfig


def _fsdp_axis(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


# --- trace-time activation constraints --------------------------------------
# Model code calls constrain(x, "dp", None, tp, ...) at the points where
# XLA's sharding propagation historically goes wrong (5-D attention einsums,
# MoE dispatch).  The mesh is installed by the launchers around lowering; with
# no mesh installed (unit tests, 1-device smoke) constrain() is a no-op.

_CTX_MESH: list = []


class ctx_mesh:
    def __init__(self, mesh, style: str = "tp"):
        self.mesh = mesh
        self.style = style

    def __enter__(self):
        _CTX_MESH.append((self.mesh, self.style))
        return self.mesh

    def __exit__(self, *a):
        _CTX_MESH.pop()


def constrain(x, *axes):
    """Tokens: "dp" = batch axes; "dpx" = dispatch-batch axes (the G dim of
    MoE expert buffers — excludes the expert axis); "ep" = expert axis;
    "model" = TP axis (dropped for ZeRO-only styles)."""
    if not _CTX_MESH:
        return x
    mesh, style = _CTX_MESH[-1]
    all_axes = tuple(a for a in ("pod", "data", "model")
                     if a in mesh.axis_names)
    nonmodel = tuple(a for a in all_axes if a != "model")

    def res(a):
        if style == "fsdp":
            return {"dp": all_axes, "dpx": all_axes,
                    "ep": None, "model": None}.get(a, a)
        if style == "ep":
            return {"dp": all_axes, "dpx": nonmodel,
                    "ep": "model", "model": None}.get(a, a)
        return {"dp": _fsdp_axis(mesh), "dpx": _fsdp_axis(mesh),
                "ep": "model", "model": "model"}.get(a, a)

    resolved = tuple(res(a) for a in axes)
    spec = fit_spec(P(*resolved), x.shape, mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


def fit_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop sharding axes that do not divide the corresponding dim (e.g. 8 KV
    heads on a 16-way model axis -> replicate the heads instead).  Keeps the
    dry-run honest: every spec is valid for every architecture."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        keep = list(axes)
        while keep:
            prod = 1
            for a in keep:
                prod *= mesh.shape[a]
            if shape[i] % prod == 0:
                break
            keep.pop()
        if not keep:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(tuple(keep))
    return P(*out)


# leaf name -> spec for the UNSTACKED rank (trailing dims)
def _rules(fsdp, tp="model"):
    return {
        # embeddings / head
        "embed": P(tp, fsdp),
        "lm_head": P(fsdp, tp),
        "img_proj": P(fsdp, tp),
        # attention
        "wq": P(fsdp, tp, None),
        "wk": P(fsdp, tp, None),
        "wv": P(fsdp, tp, None),
        "wo": P(tp, None, fsdp),
        # MLA
        "wdq": P(fsdp, None),
        "wuq": P(None, tp, None),
        "wdkv": P(fsdp, None),
        "wukv": P(None, tp, None),
        # FFN
        "w_gate": P(fsdp, tp),
        "w_up": P(fsdp, tp),
        "w_down": P(tp, fsdp),
        "router": P(fsdp, None),
        # mamba
        "w_in": P(fsdp, tp),
        "conv_w": P(None, tp),
        "w_bc": P(tp, None),
        "w_dt": P(tp, None),
        "w_dt2": P(None, tp),
        "a_log": P(tp, None),
        "d_skip": P(tp),
        "w_out": P(tp, fsdp),
        # rwkv
        "wr": P(fsdp, tp),
        "ck": P(fsdp, tp),
        "cv": P(tp, fsdp),
        "u_bonus": P(tp),
    }


_MOE_3D = {"w_gate", "w_up", "w_down"}  # (E, D, F)-shaped under "ffn"


def param_specs(cfg: ArchConfig, params_shape, mesh: Mesh):
    """PartitionSpec pytree matching a params(-shaped) pytree."""
    if cfg.parallel_style == "fsdp":
        # ZeRO-only: no tensor parallelism; every weight shards its feature
        # dim over ALL mesh axes and the batch spans them too
        fsdp = tuple(a for a in ("pod", "data", "model")
                     if a in mesh.axis_names)
        tp = None
    elif cfg.parallel_style == "ep":
        # experts keep the "model" axis (EP); everything else is ZeRO over
        # the data axes only
        fsdp = _fsdp_axis(mesh)
        tp = None
    else:
        fsdp = _fsdp_axis(mesh)
        tp = "model"
    rules = _rules(fsdp, tp)
    # expert-parallel axis: kept for styles "tp" and "ep"
    ep = "model" if cfg.parallel_style in ("tp", "ep") else None
    # rwkv shares names with attention outputs
    rules["wdecay"] = rules["wg"] = rules["wr"]

    def spec_for(path, leaf):
        names = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        name = names[-1]
        rank = len(leaf.shape)
        base = rules.get(name)
        if name == "wo" and cfg.family == "ssm":
            base = P(tp, fsdp)  # rwkv wo is (D, D)
        if base is None and name in ("wk", "wv"):
            base = rules["wq"]
        if base is None:
            base = P()  # norms, biases, small vectors: replicated
        # MoE expert tensors carry a leading E dim -> EP over "model"
        if name in _MOE_3D and rank - sum(
                1 for n in names if n == "blocks") >= 3 and "shared" not in names:
            # (E, D, F) / (E, F, D): experts on the EP axis, features on fsdp
            base = P(ep, fsdp, None) if name in ("w_gate", "w_up") \
                else P(ep, None, fsdp)
        pad = rank - len(base)
        if pad < 0:
            base = P(*base[-rank:])
            pad = 0
        return fit_spec(P(*([None] * pad), *base), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def opt_specs(pspecs):
    return {"m": pspecs, "v": pspecs, "count": P()}


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh):
    if cfg.parallel_style in ("fsdp", "ep"):
        axes = tuple(a for a in ("pod", "data", "model")
                     if a in mesh.axis_names)
    else:
        axes = (("pod", "data") if "pod" in mesh.axis_names else ("data",))
    dp = P(axes)
    total_dp = 1
    for a in axes:
        total_dp *= mesh.shape[a]
    shardable = shape.global_batch % total_dp == 0
    b0 = dp[0] if shardable else None
    specs = {}
    from repro.models.api import batch_shapes
    for k, (shp, _) in batch_shapes(cfg, shape).items():
        specs[k] = fit_spec(P(b0, *([None] * (len(shp) - 1))), shp, mesh)
    return specs


def cache_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh, cache_shape):
    """KV/state cache shardings.  decode_32k shards batch; long_500k (B=1)
    shards the sequence axis of attention caches over "data" (SP)."""
    dp = ("pod", "data") if "pod" in mesh.axis_names else "data"
    total_dp = mesh.shape["data"] * (mesh.shape.get("pod", 1))
    batch_ok = shape.global_batch % total_dp == 0

    def spec_for(path, leaf):
        names = [getattr(k, "key", None) for k in path]
        name = names[-1]
        rank = len(leaf.shape)
        stacked = 1 if "blocks" in names else 0
        if name in ("k", "v", "ckv"):          # (B, Smax, K, hd) / (B,Smax,R)
            if batch_ok:
                # batch over the data axes AND the cache sequence over
                # "model" — otherwise a 32k-deep cache leaves the model
                # axis idle and costs 16x the per-device HBM (found via the
                # kimi decode memory analysis, EXPERIMENTS.md §Dry-run)
                inner = [dp, "model"] + [None] * (rank - stacked - 2)
            else:  # SP: shard the sequence dim
                inner = [None, "data"] + [None] * (rank - stacked - 2)
            return P(*([None] * stacked), *inner)
        if name in ("s",):                      # rwkv state (B, H, hd, hd)
            if batch_ok:
                inner = [dp] + [None] * (rank - stacked - 1)
            else:
                inner = [None, "model"] + [None] * (rank - stacked - 2)
            return P(*([None] * stacked), *inner)
        if name in ("h",):                      # mamba (B, di, N)
            if batch_ok:
                inner = [dp] + [None] * (rank - stacked - 1)
            else:
                inner = [None, "model"] + [None] * (rank - stacked - 2)
            return P(*([None] * stacked), *inner)
        if batch_ok:
            return P(*([None] * stacked), dp, *([None] * (rank - stacked - 1)))
        return P(*([None] * rank))

    def fitted(path, leaf):
        return fit_spec(spec_for(path, leaf), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(fitted, cache_shape)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))

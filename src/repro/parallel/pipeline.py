"""Statically-scheduled pipeline-parallel executor (shard_map + ppermute).

Realizes the ILP-synthesized schedule from repro/core/pipeline_ilp.py: the
forward walks microbatches through the stage ring at the schedule's II with
``lax.ppermute`` hops — no host-side synchronization, matching the paper's
statically scheduled circuits.  The backward schedule is the AD transpose of
the forward (ppermute transposes to the reverse permutation), which is
exactly the ILP's reversed bwd chain.

Works on any mesh axis; tested against the unpipelined reference on an
8-device host-platform mesh in tests/test_multidevice.py (subprocess).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipelined_forward(stage_fn, stage_params, microbatches, mesh,
                      axis: str = "stage"):
    """stage_params: pytree stacked on axis 0 (= n_stages, sharded over
    ``axis``).  microbatches: (M, mb, ...) array.  Returns (M, mb, ...) of
    final-stage outputs, replicated.

    Schedule: tick t in [0, M+S-1); device s runs microbatch m = t - s
    (the ILP's fwd_start[s] = s * t_f affine schedule with II = t_f)."""
    S = mesh.shape[axis]
    M = microbatches.shape[0]

    def body(params, mbs):
        # params: (1, ...) local stage slice; mbs: (M, mb, ...) replicated
        s = jax.lax.axis_index(axis)
        p_local = jax.tree.map(lambda x: x[0], params)
        mb_shape = mbs.shape[1:]
        carry = jnp.zeros(mb_shape, mbs.dtype)          # inter-stage register
        outs = jnp.zeros((M,) + mb_shape, mbs.dtype)

        def tick(t, state):
            carry, outs = state
            m = t - s                                   # ILP: fwd_tick(s, m)
            # stage 0 ingests microbatch t; others take the ppermute carry
            x = jnp.where(s == 0,
                          mbs[jnp.clip(t, 0, M - 1)], carry)
            y = stage_fn(p_local, x)
            active = (m >= 0) & (m < M)
            y = jnp.where(active, y, carry)
            # last stage banks its result; everyone forwards around the ring
            outs = jax.lax.cond(
                active & (s == S - 1),
                lambda o: o.at[jnp.clip(m, 0, M - 1)].set(y),
                lambda o: o, outs)
            nxt = jax.lax.ppermute(y, axis,
                                   [(i, (i + 1) % S) for i in range(S)])
            return nxt, outs

        _, outs = jax.lax.fori_loop(0, M + S - 1, tick, (carry, outs))
        # replicate the last stage's collected outputs
        outs = jax.lax.psum(
            jnp.where(s == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    from jax.experimental.shard_map import shard_map
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(axis), P()), out_specs=P(),
                   check_rep=False)
    return fn(stage_params, microbatches)


def pipelined_loss(stage_fn, stage_params, microbatches, targets, mesh,
                   axis: str = "stage"):
    """MSE over the pipelined forward — jax.grad of this runs the ILP
    schedule forward and its transpose backward."""
    outs = pipelined_forward(stage_fn, stage_params, microbatches, mesh, axis)
    return jnp.mean(jnp.square(outs - targets))


def reference_forward(stage_fn, stage_params, microbatches):
    """Unpipelined oracle: apply stages sequentially to every microbatch."""
    S = jax.tree.leaves(stage_params)[0].shape[0]

    def apply_all(x):
        for s in range(S):
            p = jax.tree.map(lambda a: a[s], stage_params)
            x = stage_fn(p, x)
        return x

    return jax.vmap(apply_all)(microbatches)

"""Overlap-scheduled collective matmul (ring all-gather x matmul).

y = all_gather(x, axis) @ W  is decomposed into P steps: at step k each
device multiplies the shard it currently holds while ppermute-ing it to the
next neighbour — compute hides communication.  The step interleave (send
then matmul per tick, II=1) is validated by the ILP scheduler in
core/overlap.py: the ICI link and the MXU are modeled as two single-port
resources and the scheduler proves an II=1 pipelined schedule exists.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def ag_matmul(x_local, w_full, mesh, axis: str):
    """x_local: this device's (m, k) shard of a (P*m, k) row-sharded matrix;
    w_full: (k, n) replicated.  Returns the (P*m, n) product, row-sharded the
    same way — without ever materializing the full gather."""
    Pn = mesh.shape[axis]

    def body(x, w):
        x = x[0] if x.ndim == 3 and x.shape[0] == 1 else x
        idx = jax.lax.axis_index(axis)
        m = x.shape[0]
        out = jnp.zeros((Pn * m, w.shape[1]), w.dtype)

        def step(k, state):
            shard, out = state
            src = (idx - k) % Pn          # whose shard we hold at step k
            y = shard @ w                 # matmul current shard (MXU port)
            out = jax.lax.dynamic_update_slice(out, y, (src * m, 0))
            shard = jax.lax.ppermute(     # send it along the ring (ICI port)
                shard, axis, [(i, (i + 1) % Pn) for i in range(Pn)])
            return shard, out

        _, out = jax.lax.fori_loop(0, Pn, step, (x, out))
        return out

    from jax.experimental.shard_map import shard_map
    fn = shard_map(body, mesh=mesh, in_specs=(P(axis, None), P()),
                   out_specs=P(), check_rep=False)
    return fn(x_local, w_full)

"""Step-function builders shared by the trainer, the server, and the dry-run.

Each builder returns (fn, in_shardings, out_shardings, abstract_inputs) so the
dry-run can ``jit(fn, in_shardings=...).lower(*abstract).compile()`` without
allocating anything, and the real launchers can feed concrete arrays.
"""
from __future__ import annotations

from functools import partial

import jax
from jax.sharding import PartitionSpec as P

from repro.config import ArchConfig, ShapeConfig
from repro.models import api, lm
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, \
    cosine_schedule
from repro.parallel import sharding


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(lambda: lm.init_params(cfg, jax.random.key(0)))


def abstract_opt_state(cfg: ArchConfig, params_shape):
    return jax.eval_shape(adamw_init, params_shape)


def abstract_cache(cfg: ArchConfig, shape: ShapeConfig):
    return jax.eval_shape(
        lambda: lm.init_cache(cfg, shape.global_batch, shape.seq_len))


def build_train_step(cfg: ArchConfig, shape: ShapeConfig, mesh):
    pspecs = sharding.param_specs(cfg, abstract_params(cfg), mesh)
    ospecs = {"m": pspecs, "v": pspecs, "count": P()}
    bspecs = sharding.batch_specs(cfg, shape, mesh)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(partial(lm.loss_fn, cfg))(params, batch)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        lr = cosine_schedule(opt_state["count"])
        params, opt_state = adamw_update(params, grads, opt_state, lr)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    in_sh = (pspecs, ospecs, bspecs)
    out_sh = (pspecs, ospecs, {"loss": P(), "grad_norm": P()})
    pshape = abstract_params(cfg)
    abstract = (pshape, abstract_opt_state(cfg, pshape),
                api.input_specs(cfg, shape))
    return train_step, in_sh, out_sh, abstract


def build_prefill_step(cfg: ArchConfig, shape: ShapeConfig, mesh):
    pspecs = sharding.param_specs(cfg, abstract_params(cfg), mesh)
    bspecs = sharding.batch_specs(cfg, shape, mesh)

    def prefill_step(params, batch):
        return lm.forward(cfg, params, batch)

    in_sh = (pspecs, bspecs)
    out_sh = None  # let the partitioner choose the logits layout
    abstract = (abstract_params(cfg), api.input_specs(cfg, shape))
    return prefill_step, in_sh, out_sh, abstract


def build_decode_step(cfg: ArchConfig, shape: ShapeConfig, mesh):
    pspecs = sharding.param_specs(cfg, abstract_params(cfg), mesh)
    cshape = abstract_cache(cfg, shape)
    cspecs = sharding.cache_specs(cfg, shape, mesh, cshape)
    bspecs = sharding.batch_specs(cfg, shape, mesh)

    def serve_step(params, cache, batch):
        return lm.decode_step(cfg, params, cache, batch)

    in_sh = (pspecs, cspecs, bspecs)
    out_sh = (None, cspecs)  # cache layout must be stable across steps
    abstract = (abstract_params(cfg), cshape, api.input_specs(cfg, shape))
    return serve_step, in_sh, out_sh, abstract


def build(cfg: ArchConfig, shape: ShapeConfig, mesh):
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh)
    return build_decode_step(cfg, shape, mesh)

"""HLO-text cost analyzer.

The CPU backend's ``compiled.cost_analysis()`` only covers the entry
computation — ``while`` (lax.scan) bodies are invisible, which undercounts a
scanned transformer by ~the layer count.  This module re-derives the roofline
inputs directly from ``compiled.as_text()``:

  * builds the computation call graph (while body/condition, fusion calls,
    to_apply),
  * recovers each while loop's trip count from the ``compare(..., constant)``
    in its condition computation,
  * multiplies per-computation costs by their execution multiplicity,
  * counts dot FLOPs (2 * result_elems * contraction_elems), elementwise-ish
    FLOPs are approximated by fused-output elements, and collective bytes by
    kind (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute), skipping the ``-done`` halves of async pairs.

Validated against jax's own cost analysis on unrolled (while-free) modules in
tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-_]+)\s*\(.*\)\s*->", re.M)
_SHAPE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_CALL_ATTR = re.compile(r"(?:body|calls|to_apply|condition)=\%?([\w\.\-_]+)")
_CALLS_LIST = re.compile(r"calls=\{([^}]*)\}")
_WHILE = re.compile(r"=\s*[a-z0-9]+\[.*?\]?[^=]*while\(")


def _shape_elems(dt: str, dims: str) -> tuple[int, int]:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n, n * _DTYPE_BYTES.get(dt, 0)


@dataclass
class Computation:
    name: str
    lines: list[str] = field(default_factory=list)
    flops: float = 0.0
    bytes: float = 0.0  # operand+result bytes of top-level (post-fusion) ops
    # (bytes, leading_dim) records so loop bodies can discount scan-stacked
    # buffers that are sliced per iteration (leading dim == trip count)
    byte_records: list = field(default_factory=list)
    coll: dict[str, int] = field(default_factory=dict)
    # (callee, kind) pairs; kind "while_body" gets the trip multiplier
    calls: list[tuple[str, str]] = field(default_factory=list)
    trip_for: dict[str, int] = field(default_factory=dict)


def _split_computations(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry = None
    for line in text.splitlines():
        if (line.startswith("ENTRY") or
                (line.startswith("%") and "->" in line and line.rstrip().endswith("{"))):
            m = _COMP_HDR.match(line)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
                continue
        if cur is not None:
            if line.startswith("}"):
                cur = None
            else:
                cur.lines.append(line)
    comps["__entry__"] = comps[entry] if entry else next(iter(comps.values()))
    return comps


def _result_shape(line: str):
    """Shape on the lhs of '=' (the op result)."""
    eq = line.find("=")
    m = _SHAPE.search(line, eq + 1)
    return m


_DOT = re.compile(r"\bdot\(")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_COLL_OP = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_TRIP_CMP = re.compile(r"compare\(([^)]*)\)")
_CONST_REF = re.compile(r"%?(constant[\w\.\-]*)")
_INLINE_CONST = re.compile(r"constant\((\d+)\)")


_DEF = re.compile(r"^\s*%?([\w\.\-]+)\s*=\s*([a-z][a-z0-9]*)\[([0-9,]*)\]")


_FREE_OPS = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(tuple|get-tuple-element|bitcast|parameter|constant|iota)\(")


def _analyze_comp(c: Computation):
    # symbol table: instruction name -> (dtype, dims) for array-shaped results
    symtab: dict[str, tuple[str, str]] = {}
    for line in c.lines:
        m = _DEF.match(line)
        if m:
            symtab[m.group(1)] = (m.group(2), m.group(3))
    for line in c.lines:
        # --- memory traffic (top-level ops move their operands/results) --
        if "=" in line and not _FREE_OPS.search(line):
            rm = _result_shape(line)
            if rm:
                # slicing ops only touch the slice, not the whole buffer
                # (dynamic-slice reads its window; dynamic-update-slice is
                # aliased in place and writes only the update)
                mslice = re.search(
                    r"\b(dynamic-slice|dynamic-update-slice|gather|scatter)\(",
                    line)
                def dim0(dims: str):
                    head = dims.split(",")[0]
                    return int(head) if head else None

                if mslice:
                    kind = mslice.group(1)
                    if kind in ("dynamic-slice", "gather"):
                        c.byte_records.append(
                            (2 * _shape_elems(*rm.groups())[1], None))
                    else:
                        # update operand = second %ref inside the parens
                        paren = line.find("(", line.find("="))
                        refs = re.findall(r"%([\w\.\-]+)",
                                          line[paren:])
                        upd = next((r for r in refs[1:2] if r in symtab), None)
                        shp = symtab[upd] if upd else rm.groups()
                        c.byte_records.append(
                            (2 * _shape_elems(*shp)[1], None))
                    continue
                c.byte_records.append(
                    (_shape_elems(*rm.groups())[1], dim0(rm.group(2))))
                paren = line.find("(", line.find("=", 0))
                endp = line.find(")", paren)
                for ref in re.findall(r"%([\w\.\-]+)",
                                      line[paren:endp if endp > 0 else len(line)]):
                    if ref in symtab:
                        dt, dims = symtab[ref]
                        c.byte_records.append(
                            (_shape_elems(dt, dims)[1], dim0(dims)))
        # --- calls -----------------------------------------------------
        is_while = bool(re.search(r"\bwhile\(", line))
        is_fusion = "fusion(" in line
        for m in _CALL_ATTR.finditer(line):
            attr = m.group(0).split("=")[0]
            kind = "while_body" if (is_while and attr == "body") else \
                   "while_cond" if (is_while and attr == "condition") else \
                   ("fusion" if is_fusion else "call")
            c.calls.append((m.group(1), kind))
        m = _CALLS_LIST.search(line)
        if m:
            for nm in m.group(1).split(","):
                nm = nm.strip().lstrip("%")
                if nm:
                    c.calls.append((nm, "call"))
        # --- dot flops ---------------------------------------------------
        if _DOT.search(line):
            rm = _result_shape(line)
            if rm:
                relems, _ = _shape_elems(*rm.groups())
                # lhs operand: first %ref (or inline shape) after "dot("
                start = _DOT.search(line).end()
                cm = _CONTRACT.search(line)
                contract = 1
                dims = None
                om = re.compile(r"%([\w\.\-]+)").search(line, start)
                inline = _SHAPE.search(line, start)
                if inline and (not om or inline.start() < om.start()):
                    dims = [int(x) for x in inline.group(2).split(",") if x]
                elif om and om.group(1) in symtab:
                    dims = [int(x) for x in symtab[om.group(1)][1].split(",") if x]
                if dims is not None and cm:
                    for ci in cm.group(1).split(","):
                        if ci:
                            contract *= dims[int(ci)]
                c.flops += 2.0 * relems * contract
        # --- collectives -------------------------------------------------
        cm = _COLL_OP.search(line)
        if cm and cm.group(2) != "-done":
            kind = cm.group(1)
            rm = _result_shape(line)
            nbytes = 0
            if rm is not None:
                # tuple results: sum every shape before the op name
                eq = line.find("=")
                op_at = cm.start()
                for sm in _SHAPE.finditer(line, eq + 1, op_at):
                    nbytes += _shape_elems(*sm.groups())[1]
            c.coll[kind] = c.coll.get(kind, 0) + nbytes


def _trip_count(cond: Computation) -> int | None:
    """Trip count from the loop condition: compare(%iv, %constant) LT."""
    consts: dict[str, int] = {}
    for line in cond.lines:
        m = re.match(r"\s*%?([\w\.\-]+)\s*=.*constant\((\d+)\)", line)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for line in cond.lines:
        if "compare(" in line:
            im = _INLINE_CONST.search(line)
            if im:
                return int(im.group(1))
            for ref in re.findall(r"%([\w\.\-]+)", line[line.find("compare("):]):
                if ref in consts:
                    return consts[ref]
    if consts:
        return max(consts.values())
    return None


def analyze(text: str, default_trip: int = 1) -> dict:
    comps = _split_computations(text)
    entry = comps.pop("__entry__")
    for c in comps.values():
        _analyze_comp(c)

    # resolve trip counts for while bodies
    trips: dict[str, int] = {}
    for c in comps.values():
        body = cond = None
        for callee, kind in c.calls:
            if kind == "while_body":
                body = callee
            elif kind == "while_cond":
                cond = callee
            if body and cond:
                t = None
                if cond in comps:
                    t = _trip_count(comps[cond])
                trips[body] = t if t else default_trip
                trips[cond] = trips[body]
                body = cond = None

    # multiplicity via DFS from entry; fusion-internal computations do not
    # contribute memory traffic (their values live in registers)
    mult: dict[str, float] = {}
    bmult: dict[str, float] = {}

    def visit(name: str, m: float, bm: float, depth=0):
        if depth > 50 or name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        bmult[name] = bmult.get(name, 0.0) + bm
        seen = set()
        for callee, kind in comps[name].calls:
            key = (callee, kind)
            if key in seen:
                continue  # attrs can repeat on one line
            seen.add(key)
            factor = trips.get(callee, default_trip) if kind in (
                "while_body", "while_cond") else 1
            visit(callee, m * factor,
                  0.0 if kind == "fusion" else bm * factor, depth + 1)

    visit(entry.name, 1.0, 1.0)

    flops = 0.0
    bytes_ = 0.0
    coll: dict[str, float] = {}
    per_comp = {}
    for name, m in mult.items():
        c = comps[name]
        flops += m * c.flops
        trip = trips.get(name)
        cbytes = 0.0
        for b, d0 in c.byte_records:
            # scan-stacked buffers (leading dim == this loop's trip count)
            # are sliced per iteration: charge one slice, not the stack
            if trip and d0 == trip:
                b = b / trip
            cbytes += b
        c.bytes = cbytes
        bytes_ += bmult.get(name, 0.0) * cbytes
        for k, v in c.coll.items():
            coll[k] = coll.get(k, 0.0) + m * v
        if c.flops or c.coll:
            per_comp[name] = {"mult": m, "flops": c.flops, "coll": c.coll}
    return {"flops": flops, "bytes": bytes_, "collective_bytes": coll,
            "trips": trips, "per_comp": per_comp}

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
"""Multi-pod dry-run: lower + compile every (architecture x input-shape) on
the production meshes with 512 placeholder host devices, then extract the
roofline terms (FLOPs / bytes from cost_analysis, collective bytes parsed
from the optimized HLO) and the per-device memory analysis.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]

Results are cached as JSON under benchmarks/results/dryrun/.
"""
import argparse
import json
import re
import time
import traceback

import jax

from repro.config import SHAPES, all_cells, get_config
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "results", "dryrun")

_COLL_RE = re.compile(
    r"^\s*(?:\S+\s*=\s*)?"
    r"\(?((?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?(?:,\s*)?)+)\)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", re.M)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes of every collective op in the (per-device)
    optimized HLO.  ``-done`` ops are skipped; ``-start`` counted once."""
    out: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shapes_str, kind, _ = m.groups()
        nbytes = 0
        for dm in _SHAPE_RE.finditer(shapes_str):
            dt, dims = dm.groups()
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0) + nbytes
    return out


def _compile_cell(cfg, shape, mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.parallel.sharding import ctx_mesh

    fn, in_sh, out_sh, abstract = steps_mod.build(cfg, shape, mesh)

    def to_named(tree):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
            tree, is_leaf=lambda x: isinstance(x, P) or x is None)

    with ctx_mesh(mesh, style=cfg.parallel_style):
        jfn = jax.jit(fn, in_shardings=to_named(in_sh),
                      out_shardings=to_named(out_sh))
        lowered = jfn.lower(*abstract)
        return lowered.compile()


def dryrun_cell(arch_id: str, shape_name: str, multi_pod: bool,
                cfg_override=None) -> dict:
    """Lower+compile one cell and extract roofline inputs.

    The CPU backend's ``cost_analysis()`` excludes while (lax.scan)
    subcomputations entirely, so FLOPs and collective bytes are re-derived
    from the optimized HLO text by hlo_analysis.analyze(), which multiplies
    loop bodies by their parsed trip counts.  (Elementwise flops are not
    counted — dots dominate all 10 architectures; noted in EXPERIMENTS.md.)"""
    from repro.launch import hlo_analysis

    cfg = cfg_override or get_config(arch_id)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)

    t0 = time.time()
    compiled = _compile_cell(cfg, shape, mesh)
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    ana = hlo_analysis.analyze(compiled.as_text())

    rec = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": int(mesh.size),
        "flops_per_device": ana["flops"],
        "hbm_bytes_per_device": ana["bytes"],
        "collective_bytes_per_device": ana["collective_bytes"],
        "while_trips": ana["trips"],
        "entry_cost_analysis": {"flops": float(cost.get("flops", 0.0))},
        "memory": {
            "argument_size": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_size": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_size": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_size": int(getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        "compile_seconds": round(t_compile, 1),
        "model_params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "kind": shape.kind,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tuned", action="store_true",
                    help="apply the §Perf-confirmed levers (config.tune)")
    args = ap.parse_args()

    os.makedirs(RESULTS, exist_ok=True)
    cells = []
    if args.all:
        for aid, sname, ok, why in all_cells():
            if args.arch and aid != args.arch:
                continue
            cells.append((aid, sname, ok, why))
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape, True, "")]

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    n_ok = n_skip = n_fail = 0
    for aid, sname, ok, why in cells:
        for mp in meshes:
            tag = f"{aid}_{sname}_{'multi' if mp else 'single'}" + \
                ("_tuned" if args.tuned else "")
            path = os.path.join(RESULTS, tag + ".json")
            if os.path.exists(path) and not args.force:
                print(f"[cached] {tag}")
                n_ok += 1
                continue
            if not ok:
                json.dump({"arch": aid, "shape": sname,
                           "mesh": "multi" if mp else "single",
                           "skipped": why}, open(path, "w"), indent=1)
                print(f"[skip]   {tag}: {why}")
                n_skip += 1
                continue
            try:
                t0 = time.time()
                from repro.config import tune
                ovr = tune(get_config(aid), SHAPES[sname],
                           n_chips=512 if mp else 256) if args.tuned else None
                rec = dryrun_cell(aid, sname, mp, cfg_override=ovr)
                json.dump(rec, open(path, "w"), indent=1)
                print(f"[ok]     {tag}: flops/dev={rec['flops_per_device']:.3e} "
                      f"coll={sum(rec['collective_bytes_per_device'].values()):.3e}B "
                      f"({time.time()-t0:.0f}s)")
                n_ok += 1
            except Exception as e:
                n_fail += 1
                err = f"{type(e).__name__}: {e}"
                json.dump({"arch": aid, "shape": sname,
                           "mesh": "multi" if mp else "single",
                           "error": err[:2000]}, open(path + ".err", "w"))
                print(f"[FAIL]   {tag}: {err[:300]}")
                traceback.print_exc(limit=3)
    print(f"dryrun: {n_ok} ok, {n_skip} skipped, {n_fail} failed")


if __name__ == "__main__":
    main()

"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3_8b --reduced \
        --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

On this CPU container use --reduced (same-family miniature).  On a real
slice the full config + production mesh apply unchanged: the jitted step is
the same one the dry-run compiles for 256/512 chips.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import ShapeConfig, get_config
from repro.data import SyntheticLMData, make_train_iterator
from repro.launch import steps as steps_mod
from repro.models import lm
from repro.optim import adamw_init
from repro.runtime import StepWatchdog
from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint


def build_mesh():
    n = len(jax.devices())
    import math
    model = math.gcd(n, 2) if n > 1 else 1
    return jax.make_mesh((n // model, model), ("data", "model"))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--watchdog-s", type=float, default=600.0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    mesh = build_mesh()
    step_fn, in_sh, out_sh, _ = steps_mod.build(cfg, shape, mesh)

    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.parallel.sharding import ctx_mesh

    def named(tree):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
            tree, is_leaf=lambda x: isinstance(x, P) or x is None)

    with ctx_mesh(mesh, style=cfg.parallel_style):
        jstep = jax.jit(step_fn, in_shardings=named(in_sh),
                        out_shardings=named(out_sh), donate_argnums=(0, 1))

        params = lm.init_params(cfg, jax.random.key(args.seed))
        opt = adamw_init(params)
        start = 0
        ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
        if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
            start = latest_step(args.ckpt_dir)
            params, opt = restore_checkpoint(
                args.ckpt_dir, start, (params, opt))
            print(f"[train] resumed from step {start}")

        ds = SyntheticLMData(vocab=cfg.vocab, seq_len=args.seq,
                             batch=args.batch, seed=args.seed)
        it = make_train_iterator(ds, start_step=start)
        wd = StepWatchdog(args.watchdog_s,
                          lambda: print("[train] WATCHDOG: step timed out"))
        losses = []
        t0 = time.time()
        for step, batch in it:
            if step >= args.steps:
                break
            wd.start_step()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt, metrics = jstep(params, opt, batch)
            loss = float(metrics["loss"])
            wd.end_step()
            losses.append(loss)
            if wd.straggling():
                print(f"[train] straggler flag at step {step}")
            if step % args.log_every == 0:
                dt = time.time() - t0
                print(f"[train] step {step} loss {loss:.4f} "
                      f"({dt / max(1, step - start + 1):.2f}s/step)")
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, (params, opt))
        it.close()
        if ckpt:
            ckpt.save(args.steps, (params, opt))
            ckpt.wait()
        print(f"[train] done: first loss {losses[0]:.4f} "
              f"last loss {losses[-1]:.4f}")
        return losses


if __name__ == "__main__":
    main()

"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because tests/benches must see one
CPU device while only launch/dryrun.py forces 512 host devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = (data, model), 256 chips (TPU v5e pod).
    Multi-pod: (2, 16, 16) = (pod, data, model), 512 chips; DP gradient
    reduction crosses the "pod" axis (DCN), everything else stays inside a
    pod's ICI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for multi-device subprocess tests (8 host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def describe(mesh) -> str:
    return f"mesh{dict(mesh.shape)}"

"""Batched serving driver: prefill a batch of prompts, then decode N tokens
with the KV/state caches produced by the prefill.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6_3b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config
from repro.models import lm


def prefill_into_cache(cfg, params, tokens, cache):
    """Feed prompt tokens one at a time (teacher-forced) to build the cache.
    (A production server uses the batched prefill kernel; this exercises the
    same decode_step the dry-run lowers.)"""
    B, S = tokens.shape
    logits = None

    def body(carry, t):
        cache, _ = carry
        batch = {"token": jax.lax.dynamic_slice_in_dim(tokens, t, 1, axis=1),
                 "pos": jnp.full((B,), t, jnp.int32)}
        logits, cache = lm.decode_step(cfg, params, cache, batch)
        return (cache, logits), None

    (cache, logits), _ = jax.lax.scan(
        lambda c, t: body(c, t), (cache, jnp.zeros((B, 1, cfg.vocab))),
        jnp.arange(S))
    return cache, logits


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6_3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    B = args.batch
    Smax = args.prompt_len + args.gen
    params = lm.init_params(cfg, jax.random.key(args.seed))
    cache = lm.init_cache(cfg, B, Smax)
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(2, cfg.vocab, (B, args.prompt_len)),
                          jnp.int32)

    extra = {}
    if cfg.family == "encdec":
        extra["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_seq, cfg.d_model)), cfg.dtype)

    @jax.jit
    def decode(params, cache, token, pos):
        batch = {"token": token, "pos": pos, **extra}
        return lm.decode_step(cfg, params, cache, batch)

    t0 = time.time()
    # prefill (token-by-token through the same decode path)
    tok = prompts[:, 0:1]
    for t in range(args.prompt_len):
        logits, cache = decode(params, cache,
                               prompts[:, t:t + 1],
                               jnp.full((B,), t, jnp.int32))
    print(f"[serve] prefill {args.prompt_len} tokens: {time.time()-t0:.2f}s")

    # greedy decode
    out = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for i in range(args.gen):
        pos = jnp.full((B,), args.prompt_len + i, jnp.int32)
        logits, cache = decode(params, cache, tok, pos)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok[:, 0]))
    dt = time.time() - t0
    gen = np.stack(out, axis=1)
    print(f"[serve] generated {args.gen} tokens x {B} seqs in {dt:.2f}s "
          f"({args.gen * B / dt:.1f} tok/s)")
    print("[serve] sample:", gen[0][:16].tolist())
    return gen


if __name__ == "__main__":
    main()

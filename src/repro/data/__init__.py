from .pipeline import SyntheticLMData, make_train_iterator

__all__ = ["SyntheticLMData", "make_train_iterator"]

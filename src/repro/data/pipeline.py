"""Deterministic synthetic LM data pipeline.

Design goals for the multi-pod setting:
  * deterministic per (seed, step, host): every host can regenerate its shard
    after a restart without coordination (fault tolerance),
  * cheap on-host generation with double-buffered prefetch,
  * sequence packing of variable-length "documents" into fixed (B, S) blocks
    with an EOS-delimited structure, so the loss mask is non-trivial.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


@dataclass
class SyntheticLMData:
    vocab: int
    seq_len: int
    batch: int                   # per-host batch
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    eos: int = 1

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Markov-ish token stream packed into (batch, seq_len) blocks."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.host_id)
        B, S, V = self.batch, self.seq_len, self.vocab
        # documents of random length packed back-to-back with EOS separators
        toks = rng.integers(2, V, size=(B, S), dtype=np.int64)
        # correlate neighbours so a model can actually learn something
        toks[:, 1:] = np.where(rng.random((B, S - 1)) < 0.5,
                               toks[:, :-1], toks[:, 1:])
        doc_ends = rng.random((B, S)) < (1.0 / 97)
        toks = np.where(doc_ends, self.eos, toks)
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = self.eos
        mask = np.ones((B, S), np.float32)
        return {"tokens": toks.astype(np.int32),
                "labels": labels.astype(np.int32),
                "mask": mask}


def make_train_iterator(ds: SyntheticLMData, start_step: int = 0,
                        prefetch: int = 2):
    """Background-thread prefetching iterator, resumable at any step."""
    q: queue.Queue = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            item = (step, ds.batch_at(step))
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    t = threading.Thread(target=worker, daemon=True)
    t.start()

    class _It:
        def __iter__(self):
            return self

        def __next__(self):
            return q.get()

        def close(self):
            stop.set()

    return _It()

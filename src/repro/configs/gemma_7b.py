"""Gemma 7B  [arXiv:2403.08295; hf] — GeGLU, head_dim=256, kv=16."""
import dataclasses

from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="gemma-7b", family="dense",
        n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
        d_ff=24576, vocab=256000, act="geglu", rope_theta=10000.0,
        tie_embeddings=True,
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=32, d_ff=192, vocab=512)

"""Kimi K2 1T-A32B  [arXiv:2501.kimi2; paper-table] — trillion-parameter MoE:
384 routed experts top-8 (+1 shared), 61 layers, first layer dense."""
import dataclasses

from repro.config import ArchConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=18432,  # dense-prefix FFN width
        vocab=163840, act="swiglu",
        moe=MoEConfig(n_experts=384, top_k=8, d_ff=2048, n_shared=1),
        dense_prefix_layers=1,
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=192, vocab=512,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=64, n_shared=1))

"""DeepSeek-V2 236B  [arXiv:2405.04434; hf] — MLA (kv_lora=512) + MoE with
2 shared + 160 routed experts, top-6; first layer dense."""
import dataclasses

from repro.config import ArchConfig, MLAConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-236b", family="moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=128,
        d_ff=12288,  # dense-prefix FFN width
        vocab=102400, act="swiglu",
        moe=MoEConfig(n_experts=160, top_k=6, d_ff=1536, n_shared=2),
        dense_prefix_layers=1,
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
                      nope_head_dim=128, v_head_dim=128),
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=160, vocab=512,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=64, n_shared=1),
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48, rope_head_dim=8,
                      nope_head_dim=16, v_head_dim=16))

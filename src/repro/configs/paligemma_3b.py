"""PaliGemma 3B  [arXiv:2407.07726; hf] — SigLIP vision tower (STUB:
``input_specs`` provides 256 precomputed patch embeddings) + gemma-2b-style
decoder with MQA (kv=1) and GeGLU."""
import dataclasses

from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="paligemma-3b", family="vlm",
        n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
        d_ff=16384, vocab=257216, act="geglu", rope_theta=10000.0,
        tie_embeddings=True, n_img_tokens=256,
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
        head_dim=16, d_ff=160, vocab=512, n_img_tokens=8)

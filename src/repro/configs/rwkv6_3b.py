"""RWKV-6 "Finch" 3B  [arXiv:2404.05892; hf] — attention-free, data-dependent
decay; 32L d_model=2560, vocab 65536."""
import dataclasses

from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-3b", family="ssm",
        n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
        rwkv_head_dim=64, d_ff=8960, vocab=65536, act="rwkv",
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
        rwkv_head_dim=64, d_ff=256, vocab=512)

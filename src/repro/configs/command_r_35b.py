"""Command-R 35B  [hf:CohereForAI/c4ai-command-r-v01] — GQA, no biases."""
import dataclasses

from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="command-r-35b", family="dense",
        n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=22528, vocab=256000, act="swiglu", rope_theta=8000000.0,
        tie_embeddings=True,
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
        head_dim=16, d_ff=256, vocab=512)

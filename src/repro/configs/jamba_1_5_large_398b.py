"""Jamba-1.5 Large 398B  [arXiv:2403.19887; hf] — hybrid Mamba/attention at a
1:7 ratio (one attention layer per 8-layer period, at position 4), MoE
(16 experts, top-2) on every other layer."""
import dataclasses

from repro.config import ArchConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=24576, vocab=65536, act="swiglu",
        period=8, attn_positions=(4,),
        moe=MoEConfig(n_experts=16, top_k=2, d_ff=24576), moe_every=2,
        mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=512,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=128))

"""Whisper small  [arXiv:2212.04356] — encoder-decoder, 12+12 layers,
d_model=768.  The conv audio frontend is a STUB per the assignment:
``input_specs`` provides precomputed frame embeddings (batch, 1500, d)."""
import dataclasses

from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-small", family="encdec",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
        d_ff=3072, vocab=51865, act="gelu",
        n_enc_layers=12, enc_seq=1500,
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=512, n_enc_layers=2, enc_seq=16)

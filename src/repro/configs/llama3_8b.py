"""Llama-3 8B  [arXiv:2407.21783] — dense GQA, 128k vocab."""
import dataclasses

from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="llama3-8b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=128256, act="swiglu", rope_theta=500000.0,
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
        head_dim=16, d_ff=256, vocab=512)

"""Llama-3 405B  [arXiv:2407.21783] — dense GQA, 128k vocab."""
import dataclasses

from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="llama3-405b", family="dense",
        n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, head_dim=128,
        d_ff=53248, vocab=128256, act="swiglu", rope_theta=500000.0,
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        config(), n_layers=3, d_model=128, n_heads=8, n_kv_heads=2,
        head_dim=16, d_ff=352, vocab=512)

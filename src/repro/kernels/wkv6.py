"""RWKV-6 (Finch) WKV recurrence as a Pallas TPU kernel.

Grid = (batch, heads); each program owns one head's (hd x hd) state matrix in
fp32 and walks the sequence in chunks.  Within a chunk the recurrence is
evaluated in the parallel (linear-attention) form — cumulative log-decays, a
strictly-lower-triangular intra-chunk attention, the diagonal "bonus" u term,
and a carried cross-chunk state — so the MXU sees (C x hd)@(hd x hd) matmuls
instead of a length-S scalar chain.  The published CUDA kernel keeps the
state in shared memory and serializes tokens; the TPU adaptation trades that
for chunked matrix form (DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, *, chunk, seq):
    hd = r_ref.shape[-1]
    C = chunk
    u = u_ref[...].astype(jnp.float32)                     # (hd,)
    tri = jnp.tril(jnp.ones((C, C), jnp.float32), -1)

    def body(j, state):
        sl = (pl.dslice(j * C, C), slice(None))
        r = pl.load(r_ref, sl).astype(jnp.float32)         # (C, hd)
        k = pl.load(k_ref, sl).astype(jnp.float32)
        v = pl.load(v_ref, sl).astype(jnp.float32)
        w = pl.load(w_ref, sl).astype(jnp.float32)
        logw = jnp.log(w)
        cw = jnp.cumsum(logw, axis=0)                      # (C, hd)
        rd = r * jnp.exp(cw - logw)
        kd = k * jnp.exp(-cw)
        att = (rd @ kd.T) * tri                            # (C, C)
        out = att @ v
        # bonus term (current token only): o += (r . (u*k)) v
        bonus = jnp.sum(r * k * u[None, :], axis=1, keepdims=True) * v
        out = out + bonus
        out = out + rd @ state                             # carried state
        wtot = jnp.exp(cw[-1])                             # (hd,)
        state1 = state * wtot[:, None] + \
            (k * jnp.exp(cw[-1][None, :] - cw)).T @ v
        pl.store(o_ref, sl, out.astype(o_ref.dtype))
        return state1

    state0 = jnp.zeros((hd, hd), jnp.float32)
    jax.lax.fori_loop(0, seq // C, body, state0)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, *, chunk=64, interpret=False):
    """r,k,v,w: (B, H, S, hd); w is the per-token decay in (0,1);
    u: (H, hd).  Returns (B, H, S, hd)."""
    B, H, S, hd = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    return pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk, seq=S),
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((None, None, S, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, S, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, S, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, S, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, hd), lambda b, h: (h, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, S, hd), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), r.dtype),
        interpret=interpret,
    )(r, k, v, w, u)

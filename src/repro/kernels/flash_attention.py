"""Flash attention (forward) as a Pallas TPU kernel.

Online-softmax tiling: the grid walks (batch*heads, q blocks); each program
streams kv blocks through VMEM, keeping the running max/denominator in
registers.  Block sizes are MXU-aligned (multiples of 128 on the lane dim).

TPU adaptation notes (DESIGN.md §3): HBM->VMEM streaming replaces the GPU
SRAM tiling of the original flash-attention; the (BQ, BK) score tile feeds
the 128x128 MXU directly; fp32 accumulation in VREGs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, causal, block_k, seq_k):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale          # (BQ, hd)
    BQ, hd = q.shape
    acc = jnp.zeros((BQ, hd), jnp.float32)
    m = jnp.full((BQ,), NEG_INF, jnp.float32)
    l = jnp.zeros((BQ,), jnp.float32)
    nkv = seq_k // block_k

    def body(j, carry):
        acc, m, l = carry
        k = pl.load(k_ref, (pl.dslice(j * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(j * block_k, block_k), slice(None)))
        s = q @ k.astype(jnp.float32).T                  # (BQ, BK)
        if causal:
            qpos = qi * BQ + jax.lax.broadcasted_iota(jnp.int32, (BQ, block_k), 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (BQ, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m1 = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m1[:, None])
        alpha = jnp.exp(m - m1)
        l1 = l * alpha + p.sum(axis=1)
        acc1 = acc * alpha[:, None] + p @ v.astype(jnp.float32)
        return acc1, m1, l1

    if causal:
        # only kv blocks at or before this q block contribute
        nkv_eff = qi + 1 if isinstance(qi, int) else None
        acc, m, l = jax.lax.fori_loop(
            0, (qi * q_ref.shape[0]) // block_k + 1, body, (acc, m, l))
    else:
        acc, m, l = jax.lax.fori_loop(0, nkv, body, (acc, m, l))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, block_q=128, block_k=128,
                    interpret=False):
    """q,k,v: (B, H, S, hd) -> (B, H, S, hd)."""
    B, H, S, hd = q.shape
    Sk = k.shape[2]
    block_q = min(block_q, S)
    block_k = min(block_k, Sk)
    assert S % block_q == 0 and Sk % block_k == 0
    scale = hd ** -0.5
    qr = q.reshape(B * H, S, hd)
    kr = k.reshape(B * H, Sk, hd)
    vr = v.reshape(B * H, Sk, hd)
    out = pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, causal=causal,
                          block_k=block_k, seq_k=Sk),
        grid=(B * H, S // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, Sk, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, Sk, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, S, hd)

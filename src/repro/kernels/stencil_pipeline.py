"""Fused producer-consumer stencil chain as a Pallas TPU kernel — the
paper's Fig. 1 pattern (two chained convolutions) adapted to the TPU memory
hierarchy.

The FPGA version overlaps the two loop nests with an ILP-derived slack: the
consumer may start once the producer has written ``halo`` rows.  On TPU the
same slack *sizes the VMEM line buffer*: each grid step loads a row tile plus
``halo`` extra rows, computes the producer stage (conv-x) for the whole tile
in VMEM, and immediately consumes it (conv-y) — the intermediate array never
touches HBM.

Since the codegen backend landed (DESIGN.md §10) this hand-written kernel is
the *golden reference*: ``repro.core.codegen.lower_program`` generates the
same kernel from the ``programs.blur_chain`` IR (the golden test asserts
bit-exact agreement), and the block/halo configuration is read off the
generated kernel — ``hls.compile`` shift-and-peel-fuses the mismatched-bounds
chain, the knee point of the latency x BRAM Pareto frontier is lowered with
``CompileResult.emit_pallas()``, and the kernel's ``block_rows`` / ``halo``
supply both values (the fusion's row shift IS the halo).  The older fixed
probe (``ilp_halo_rows``) is kept only as the fallback when the sweep finds
no shifted fusion.  ``stencil_dse_config`` remains as a deprecated wrapper
(DESIGN.md §6 MIGRATION).

This module owns the single implementation; ``repro.kernels.ops`` re-exports
it (they used to diverge on the ``interpret`` default).
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def default_interpret() -> bool:
    """Pallas interpret mode on CPU (this container), compiled on TPU."""
    return jax.default_backend() != "tpu"


def _kernel(img_ref, wx_ref, wy_ref, o_ref, *, block_rows, halo):
    i = pl.program_id(0)
    BR = block_rows
    Wout = o_ref.shape[1]
    # line buffer: BR + halo input rows (the ILP slack), full width
    rows = pl.load(img_ref, (pl.dslice(i * BR, BR + halo), slice(None)))
    rows = rows.astype(jnp.float32)
    # producer stage: conv-x (3 taps along width)
    wx = wx_ref[...].astype(jnp.float32)
    bx = (rows[:, 0:Wout] * wx[0] + rows[:, 1:Wout + 1] * wx[1]
          + rows[:, 2:Wout + 2] * wx[2])                 # (BR+halo, Wout)
    # consumer stage: conv-y (3 taps along rows) — starts "halo" rows behind
    wy = wy_ref[...].astype(jnp.float32)
    out = bx[0:BR] * wy[0] + bx[1:BR + 1] * wy[1] + bx[2:BR + 2] * wy[2]
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "halo", "interpret"))
def _stencil_call(img, wx, wy, *, block_rows, halo, interpret):
    H, W = img.shape
    Hout, Wout = H - 2, W - 2
    return pl.pallas_call(
        functools.partial(_kernel, block_rows=block_rows, halo=halo),
        grid=(Hout // block_rows,),
        in_specs=[
            pl.BlockSpec((H, W), lambda i: (0, 0)),   # streamed line window
            pl.BlockSpec((3,), lambda i: (0,)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, Wout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Hout, Wout), img.dtype),
        interpret=interpret,
    )(img, wx, wy)


def stencil_pipeline(img, wx, wy, *, block_rows=None, halo=None,
                     interpret=None):
    """img: (H, W); wx, wy: (3,).  Returns conv_y(conv_x(img)) of shape
    (H-2, W-2), computed in one fused pass.  ``block_rows``/``halo`` default
    to the DSE-derived configuration (``stencil_dse_config``); ``interpret``
    defaults to True off-TPU."""
    interpret = default_interpret() if interpret is None else interpret
    if block_rows is None or halo is None:
        dse_rows, dse_halo = _stencil_codegen_config()
        block_rows = dse_rows if block_rows is None else block_rows
        halo = dse_halo if halo is None else halo
    H, _ = img.shape
    Hout = H - 2
    block_rows = min(block_rows, Hout)
    assert Hout % block_rows == 0, (Hout, block_rows)
    return _stencil_call(img, wx, wy, block_rows=block_rows, halo=halo,
                         interpret=interpret)


@functools.lru_cache()
def ilp_halo_rows(taps: int = 3) -> int:
    """Fallback fixed probe (demoted: the ``emit_pallas`` sweep in
    ``_stencil_codegen_config`` is the primary source): derive the
    line-buffer halo from the paper's memory-dependence
    ILP by scheduling a two-nest conv chain and converting the
    producer->consumer slack into rows (slack = -(halo rows) * II_row).

    The two-nest chain is produced by the pass pipeline rather than built by
    hand: the producer is written as raw accumulation + a pointwise scale
    nest, and ``FuseProducerConsumer`` (equal-bounds mode, with an exact ILP
    legality proof) collapses them into the single producer nest whose RAW
    edges on ``mid`` carry the halo."""
    from repro.core.autotune import compile_program
    from repro.core.ir import ProgramBuilder
    from repro.core.transforms import (FuseProducerConsumer, Normalize,
                                       PassManager)

    n = 8
    b = ProgramBuilder("halo_probe")
    Hm = n + taps - 1
    b.array("img", (n + 2 * (taps - 1), n), partition=(0, 1), ports=("w", "r"))
    b.array("acc", (Hm, n), partition=(0, 1), ports=("w", "r"))
    b.array("mid", (Hm, n), partition=(0, 1), ports=("w", "r"))
    b.array("out", (n, n), partition=(0, 1), ports=("w", "r"))
    # producer, unfused form: accumulate taps, then scale pointwise
    with b.loop("pi", 0, Hm) as i:
        with b.loop("pj", 0, n) as j:
            t = [b.load("img", i + t_, j) for t_ in range(taps)]
            b.store("acc", b.sum_tree(t), i, j)
    with b.loop("si", 0, Hm) as i:
        with b.loop("sj", 0, n) as j:
            b.store("mid", b.mul(b.load("acc", i, j), b.const(1.0 / taps)), i, j)
    # consumer conv over the fused producer's output
    with b.loop("ci", 0, n) as i:
        with b.loop("cj", 0, n) as j:
            t = [b.mul(b.load("mid", i + t_, j), b.const(1.0 / taps))
                 for t_ in range(taps)]
            b.store("out", b.sum_tree(t), i, j)
    # equal-bounds fusion only: the probe MEASURES the cross-nest slack, so
    # the consumer must stay a separate nest (shift fusion would absorb it)
    p = PassManager([Normalize(), FuseProducerConsumer(enable_shift=False)],
                    verify=True).run(b.build())
    assert len(p.body) == 2, "accumulate+scale must fuse into the producer"
    s = compile_program(p)
    prod, _ = p.body
    ii_row = s.iis[prod.uid]
    # the RAW dependence edges on `mid` carry the slack: lower = delay - slack
    # = wr_latency + halo_rows * II_row; the worst edge is the deepest tap.
    worst = max(e.lower for e in s.edges
                if e.kind == "RAW" and e.array == "mid")
    return max(1, -(-(worst - 1) // ii_row))  # ceil


# (taps, n) -> "dse" or "fallback(<reason>)": which path produced the config
# returned by _stencil_codegen_config — tests assert the DSE sweep actually
# ran, so a silently broken sweep cannot hide behind the fallback's values.
_CONFIG_SOURCE: dict[tuple[int, int], str] = {}


def _stencil_dse_sweep(taps: int, n: int) -> tuple[int, int]:
    """Run the hls.compile Pareto sweep, lower the knee point with
    ``emit_pallas``, and read (block_rows, halo) off the generated kernel;
    raises RuntimeError when no frontier point shift-fused bx."""
    from repro.core import hls
    from repro.core.errors import UnlowerableProgram
    from repro.core.programs import blur_chain

    # bram storage so the tile-window footprint term differentiates block
    # sizes; the partition move is excluded — full partitioning is a knob
    # the kernel's VMEM line buffer cannot express
    p = blur_chain(n, storage="bram", taps=taps)
    r = hls.compile(
        p,
        objectives=(hls.minimize("latency"), hls.minimize("bram")),
        search=hls.SearchConfig(moves=("fuse", "tile"), unroll_factors=(),
                                tile_sizes=(2, 4), max_candidates=8))

    def row_shift(c):
        for entry in getattr(c.program, "_fusion_log", []):
            if "bx" in entry["arrays"] and entry["shift"][0] > 0:
                return entry["shift"][0]
        return None

    fused = [c for c in r.frontier if row_shift(c) is not None]
    if not fused:
        raise RuntimeError("DSE sweep found no shifted fusion of bx on the "
                           "frontier")
    # knee of the latency x BRAM trade-off among the fused frontier points,
    # lowered to the generated kernel: its window analysis turns the fusion's
    # row shift into the line-buffer halo, and the knee's tiling of the
    # fused row loop into the Pallas grid's row-block size
    knee = r.knee("latency", "bram", among=fused)
    try:
        kern = r.emit_pallas(knee)
    except UnlowerableProgram as e:
        raise RuntimeError(f"knee point unlowerable: {e}") from e
    return kern.block_rows, kern.halo["bx"]


@functools.lru_cache()
def _stencil_codegen_config(taps: int = 3, n: int = 8) -> tuple[int, int]:
    """(block_rows, halo) for ``stencil_pipeline``, read off the generated
    kernel of the DSE knee point.

    ``hls.compile`` explores transform pipelines over the mismatched-bounds
    blur chain; the knee of the latency x BRAM curve among the candidates
    that shift-and-peel fused the intermediate ``bx`` is lowered with
    ``CompileResult.emit_pallas()`` and the kernel reports its own config:
    ``PallasKernel.halo["bx"]`` is the fusion's row shift (the number of
    producer rows the consumer trails by — the line-buffer halo) and
    ``PallasKernel.block_rows`` the knee's tiling of the fused row loop.
    Falls back to the fixed ``ilp_halo_rows`` probe if the sweep yields no
    shifted fusion; ``stencil_config_source`` reports which path produced
    the values.

    Persistence rides the PR 6 compile cache: ``hls.compile`` stores the
    whole frontier content-addressed (``repro.core.cache``), so a serving
    process pays the sweep once per machine and this function only replays
    a cache hit — no private side entry needed.  The ``lru_cache`` on top
    memoizes the in-process lookups; cache entries carry the scheduler
    salt, so a compiler change invalidates them and the sweep reruns."""
    try:
        cfg = _stencil_dse_sweep(taps, n)
        _CONFIG_SOURCE[(taps, n)] = "dse"
    except RuntimeError as e:  # demoted fixed-probe fallback
        _CONFIG_SOURCE[(taps, n)] = f"fallback({e})"
        cfg = 8, ilp_halo_rows(taps)
    return cfg


def stencil_dse_config(taps: int = 3, n: int = 8) -> tuple[int, int]:
    """Deprecated wrapper (DESIGN.md §6 MIGRATION): the blessed path is
    ``hls.compile(blur_chain(...)).emit_pallas()`` — the generated kernel
    carries ``block_rows``/``halo`` itself.  Old signature kept; delegates
    to the same config the kernel defaults use."""
    warnings.warn(
        "stencil_dse_config is deprecated; use hls.compile(...)"
        ".emit_pallas() and read PallasKernel.block_rows / .halo "
        "(DESIGN.md §6 MIGRATION)", DeprecationWarning, stacklevel=2)
    return _stencil_codegen_config(taps, n)


def stencil_config_source(taps: int = 3, n: int = 8) -> str:
    """'dse' when the stencil config values came from the emit_pallas
    sweep, else 'fallback(<reason>)'."""
    _stencil_codegen_config(taps, n)
    return _CONFIG_SOURCE[(taps, n)]

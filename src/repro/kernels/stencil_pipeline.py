"""Fused producer-consumer stencil chain as a Pallas TPU kernel — the
paper's Fig. 1 pattern (two chained convolutions) adapted to the TPU memory
hierarchy.

The FPGA version overlaps the two loop nests with an ILP-derived slack: the
consumer may start once the producer has written ``halo`` rows.  On TPU the
same slack *sizes the VMEM line buffer*: each grid step loads a row tile plus
``halo`` extra rows, computes the producer stage (conv-x) for the whole tile
in VMEM, and immediately consumes it (conv-y) — the intermediate array never
touches HBM.  ``ops.ilp_halo_rows()`` derives the halo by running the
paper's memory-dependence ILP on the two-nest affine program.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(img_ref, wx_ref, wy_ref, o_ref, *, block_rows, halo):
    i = pl.program_id(0)
    BR = block_rows
    Wout = o_ref.shape[1]
    # line buffer: BR + halo input rows (the ILP slack), full width
    rows = pl.load(img_ref, (pl.dslice(i * BR, BR + halo), slice(None)))
    rows = rows.astype(jnp.float32)
    # producer stage: conv-x (3 taps along width)
    wx = wx_ref[...].astype(jnp.float32)
    bx = (rows[:, 0:Wout] * wx[0] + rows[:, 1:Wout + 1] * wx[1]
          + rows[:, 2:Wout + 2] * wx[2])                 # (BR+halo, Wout)
    # consumer stage: conv-y (3 taps along rows) — starts "halo" rows behind
    wy = wy_ref[...].astype(jnp.float32)
    out = bx[0:BR] * wy[0] + bx[1:BR + 1] * wy[1] + bx[2:BR + 2] * wy[2]
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def stencil_pipeline(img, wx, wy, *, block_rows=8, interpret=False):
    """img: (H, W); wx, wy: (3,).  Returns conv_y(conv_x(img)) of shape
    (H-2, W-2), computed in one fused pass."""
    H, W = img.shape
    Hout, Wout = H - 2, W - 2
    halo = 2  # == ops.ilp_halo_rows(): ceil(-slack / II_row) for 3-tap chains
    block_rows = min(block_rows, Hout)
    assert Hout % block_rows == 0, (Hout, block_rows)
    return pl.pallas_call(
        functools.partial(_kernel, block_rows=block_rows, halo=halo),
        grid=(Hout // block_rows,),
        in_specs=[
            pl.BlockSpec((H, W), lambda i: (0, 0)),   # streamed line window
            pl.BlockSpec((3,), lambda i: (0,)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, Wout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Hout, Wout), img.dtype),
        interpret=interpret,
    )(img, wx, wy)

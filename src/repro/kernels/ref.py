"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, causal=True):
    """q,k,v: (B, H, S, hd) -> (B, H, S, hd), fp32 softmax."""
    hd = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * hd ** -0.5
    if causal:
        S = q.shape[2]
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w.astype(q.dtype), v)


def stencil_pipeline_ref(img, wx, wy):
    """Fused producer-consumer separable stencil chain (the paper's Fig. 1
    pattern): bx = conv_x(img, wx); out = conv_y(bx, wy).
    img: (H, W); wx, wy: (3,).  'valid' padding: out is (H-2, W-2)."""
    bx = sum(img[:, i:img.shape[1] - 2 + i] * wx[i] for i in range(3))
    out = sum(bx[i:img.shape[0] - 2 + i, :] * wy[i] for i in range(3))
    return out


def wkv6_ref(r, k, v, w, u):
    """RWKV-6 data-dependent-decay recurrence, sequential reference.
    r,k,v,w: (B, H, S, hd); u: (H, hd).  Returns (out, final_state).

       S_t = diag(w_t) S_{t-1} + k_t^T v_t
       o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
    """
    B, H, S, hd = r.shape

    def step(s, args):
        rt, kt, vt, wt = args  # (B,H,hd)
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,hd,hd)
        out = jnp.einsum("bhd,bhde->bhe", rt, s + u[..., :, None] * kv)
        s1 = s * wt[..., :, None] + kv
        return s1, out

    s0 = jnp.zeros((B, H, hd, hd), r.dtype)
    args = tuple(t.transpose(2, 0, 1, 3) for t in (r, k, v, w))
    s_fin, outs = jax.lax.scan(step, s0, args)
    return outs.transpose(1, 2, 0, 3), s_fin

"""jit'd public wrappers for the Pallas kernels + the ILP/kernel bridge.

``interpret`` defaults to True on CPU (this container) and False on TPU, so
the same call sites work in both environments.

``stencil_pipeline`` (and its configuration helpers — the deprecated
``stencil_dse_config`` wrapper and the fallback ``ilp_halo_rows``) are
re-exported from ``repro.kernels.stencil_pipeline`` — that module owns the
single implementation; this one used to carry a diverging duplicate
wrapper.  The blessed configuration path is now
``hls.compile(...).emit_pallas()`` (DESIGN.md §10).
"""
from __future__ import annotations

from repro.kernels.flash_attention import flash_attention as _fa
from repro.kernels.stencil_pipeline import (default_interpret as
                                            _default_interpret,
                                            ilp_halo_rows, stencil_dse_config,
                                            stencil_pipeline)
from repro.kernels.wkv6 import wkv6 as _wkv

__all__ = ["flash_attention", "stencil_pipeline", "stencil_dse_config",
           "ilp_halo_rows", "wkv6"]


def flash_attention(q, k, v, *, causal=True, block_q=128, block_k=128,
                    interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _fa(q, k, v, causal=causal, block_q=block_q, block_k=block_k,
               interpret=interpret)


def wkv6(r, k, v, w, u, *, chunk=64, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _wkv(r, k, v, w, u, chunk=chunk, interpret=interpret)

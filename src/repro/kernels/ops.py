"""jit'd public wrappers for the Pallas kernels + the ILP/kernel bridge.

``interpret`` defaults to True on CPU (this container) and False on TPU, so
the same call sites work in both environments.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _fa
from repro.kernels.stencil_pipeline import stencil_pipeline as _sp
from repro.kernels.wkv6 import wkv6 as _wkv


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, block_q=128, block_k=128,
                    interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _fa(q, k, v, causal=causal, block_q=block_q, block_k=block_k,
               interpret=interpret)


def stencil_pipeline(img, wx, wy, *, block_rows=8, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _sp(img, wx, wy, block_rows=block_rows, interpret=interpret)


def wkv6(r, k, v, w, u, *, chunk=64, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _wkv(r, k, v, w, u, chunk=chunk, interpret=interpret)


@functools.lru_cache()
def ilp_halo_rows(taps: int = 3) -> int:
    """Derive the stencil_pipeline line-buffer halo from the paper's
    memory-dependence ILP: schedule a two-nest conv chain and convert the
    producer->consumer slack into rows (slack = -(halo rows) * II_row).

    The two-nest chain is produced by the pass pipeline rather than built by
    hand: the producer is written as raw accumulation + a pointwise scale
    nest, and ``FuseProducerConsumer`` (with an exact ILP legality proof)
    collapses them into the single producer nest whose RAW edges on ``mid``
    carry the halo."""
    from repro.core import compile_program
    from repro.core.ir import ProgramBuilder
    from repro.core.transforms import FuseProducerConsumer, Normalize, PassManager

    n = 8
    b = ProgramBuilder("halo_probe")
    Hm = n + taps - 1
    b.array("img", (n + 2 * (taps - 1), n), partition=(0, 1), ports=("w", "r"))
    b.array("acc", (Hm, n), partition=(0, 1), ports=("w", "r"))
    b.array("mid", (Hm, n), partition=(0, 1), ports=("w", "r"))
    b.array("out", (n, n), partition=(0, 1), ports=("w", "r"))
    # producer, unfused form: accumulate taps, then scale pointwise
    with b.loop("pi", 0, Hm) as i:
        with b.loop("pj", 0, n) as j:
            t = [b.load("img", i + t_, j) for t_ in range(taps)]
            b.store("acc", b.sum_tree(t), i, j)
    with b.loop("si", 0, Hm) as i:
        with b.loop("sj", 0, n) as j:
            b.store("mid", b.mul(b.load("acc", i, j), b.const(1.0 / taps)), i, j)
    # consumer conv over the fused producer's output
    with b.loop("ci", 0, n) as i:
        with b.loop("cj", 0, n) as j:
            t = [b.mul(b.load("mid", i + t_, j), b.const(1.0 / taps))
                 for t_ in range(taps)]
            b.store("out", b.sum_tree(t), i, j)
    p = PassManager([Normalize(), FuseProducerConsumer()], verify=True).run(b.build())
    assert len(p.body) == 2, "accumulate+scale must fuse into the producer"
    s = compile_program(p)
    prod, _ = p.body
    ii_row = s.iis[prod.uid]
    # the RAW dependence edges on `mid` carry the slack: lower = delay - slack
    # = wr_latency + halo_rows * II_row; the worst edge is the deepest tap.
    worst = max(e.lower for e in s.edges
                if e.kind == "RAW" and e.array == "mid")
    return max(1, -(-(worst - 1) // ii_row))  # ceil

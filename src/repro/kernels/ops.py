"""jit'd public wrappers for the Pallas kernels + the ILP/kernel bridge.

``interpret`` defaults to True on CPU (this container) and False on TPU, so
the same call sites work in both environments.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _fa
from repro.kernels.stencil_pipeline import stencil_pipeline as _sp
from repro.kernels.wkv6 import wkv6 as _wkv


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, block_q=128, block_k=128,
                    interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _fa(q, k, v, causal=causal, block_q=block_q, block_k=block_k,
               interpret=interpret)


def stencil_pipeline(img, wx, wy, *, block_rows=8, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _sp(img, wx, wy, block_rows=block_rows, interpret=interpret)


def wkv6(r, k, v, w, u, *, chunk=64, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _wkv(r, k, v, w, u, chunk=chunk, interpret=interpret)


@functools.lru_cache()
def ilp_halo_rows(taps: int = 3) -> int:
    """Derive the stencil_pipeline line-buffer halo from the paper's
    memory-dependence ILP: schedule a two-nest conv chain and convert the
    producer->consumer slack into rows (slack = -(halo rows) * II_row)."""
    from repro.core import compile_program
    from repro.core.ir import ProgramBuilder

    n = 8
    b = ProgramBuilder("halo_probe")
    b.array("img", (n + 2 * (taps - 1), n), partition=(0, 1), ports=("w", "r"))
    b.array("mid", (n + taps - 1, n), partition=(0, 1), ports=("w", "r"))
    b.array("out", (n, n), partition=(0, 1), ports=("w", "r"))
    for src, dst, tag, extent in (("img", "mid", "p", n + taps - 1),
                                  ("mid", "out", "c", n)):
        with b.loop(f"{tag}i", 0, extent) as i:
            with b.loop(f"{tag}j", 0, n) as j:
                acc = [b.mul(b.load(src, i + t, j), b.const(1.0 / taps))
                       for t in range(taps)]
                b.store(dst, b.sum_tree(acc), i, j)
    p = b.build()
    s = compile_program(p)
    prod, _ = p.body
    ii_row = s.iis[prod.uid]
    # the RAW dependence edges on `mid` carry the slack: lower = delay - slack
    # = wr_latency + halo_rows * II_row; the worst edge is the deepest tap.
    worst = max(e.lower for e in s.edges
                if e.kind == "RAW" and e.array == "mid")
    return max(1, -(-(worst - 1) // ii_row))  # ceil

"""Configuration system: architecture configs, input-shape sets, runtime knobs.

Every assigned architecture has a module in ``repro/configs`` exporting
``config()`` (the exact published numbers) and ``reduced()`` (a same-family
miniature for CPU smoke tests).  Shapes follow the assignment:

    train_4k     seq 4096,   global_batch 256   (training)
    prefill_32k  seq 32768,  global_batch 32    (inference prefill)
    decode_32k   seq 32768,  global_batch 128   (one-token decode w/ KV cache)
    long_500k    seq 524288, global_batch 1     (long-context decode;
                                                 sub-quadratic archs only)
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                 # per-expert FFN width
    n_shared: int = 0         # shared (always-on) experts
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None            # default d_model // n_heads
    act: str = "swiglu"                        # swiglu | geglu
    norm_eps: float = 1e-5
    rope_theta: float = 500000.0
    tie_embeddings: bool = False
    qkv_bias: bool = False
    # MoE
    moe: Optional[MoEConfig] = None
    moe_every: int = 1                         # MoE layer cadence
    dense_prefix_layers: int = 0               # leading dense layers (dsv2/kimi)
    # MLA
    mla: Optional[MLAConfig] = None
    # hybrid (jamba): within each period, which positions are attention
    period: int = 1
    attn_positions: tuple[int, ...] = ()       # for hybrid families
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # rwkv6
    rwkv_head_dim: int = 64
    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 1500                        # audio frames after conv stub
    # vlm (paligemma)
    n_img_tokens: int = 0                      # SigLIP patch tokens (stub)
    # runtime knobs (hillclimbing targets)
    dtype: str = "bfloat16"
    remat: str = "full"                        # none | full | dots
    logits_fp32: bool = True
    attn_impl: str = "dense"                   # dense | chunked (flash-style)
    attn_chunk: int = 1024                     # kv-block for chunked attention
    # tp: TP+FSDP | fsdp: ZeRO only | ep: experts on "model", rest ZeRO
    parallel_style: str = "tp"
    scores_bf16: bool = False                  # bf16 attention scores

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.hd
        emb = V * D * (1 if self.tie_embeddings else 2)
        total = emb
        for li in range(L):
            kind = self.layer_kind(li)
            if kind == "attn" or kind == "mla":
                if self.mla:
                    m = self.mla
                    qd = m.nope_head_dim + m.rope_head_dim
                    attn = (D * m.q_lora_rank + m.q_lora_rank * self.n_heads * qd
                            + D * (m.kv_lora_rank + m.rope_head_dim)
                            + m.kv_lora_rank * self.n_heads *
                            (m.nope_head_dim + m.v_head_dim)
                            + self.n_heads * m.v_head_dim * D)
                else:
                    attn = D * self.n_heads * hd + 2 * D * self.n_kv_heads * hd \
                        + self.n_heads * hd * D
            elif kind == "mamba":
                di = self.mamba_expand * D
                attn = 2 * D * di + di * self.mamba_d_conv + \
                    di * (2 * self.mamba_d_state + di // 16 * 2) + di * D
            elif kind == "rwkv":
                attn = 5 * D * D + D * D  # time-mix projections + output
            else:
                attn = 0
            if kind == "rwkv":
                ff = 2 * D * self.d_ff + self.d_ff * D  # channel mix approx
            elif self.is_moe_layer(li):
                ff = (self.moe.n_experts + self.moe.n_shared) * 3 * D * self.moe.d_ff \
                    + D * self.moe.n_experts
            else:
                ff = 3 * D * F
            total += attn + ff
        if self.n_enc_layers:
            total += self.n_enc_layers * (4 * D * self.n_heads * hd + 3 * D * F)
            total += L * (4 * D * self.n_heads * hd)  # cross attention
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k only)."""
        if not self.moe:
            return self.param_count()
        D = self.d_model
        total = self.vocab * D * (1 if self.tie_embeddings else 2)
        for li in range(self.n_layers):
            hd = self.hd
            attn = D * self.n_heads * hd + 2 * D * self.n_kv_heads * hd \
                + self.n_heads * hd * D
            if self.mla:
                m = self.mla
                qd = m.nope_head_dim + m.rope_head_dim
                attn = (D * m.q_lora_rank + m.q_lora_rank * self.n_heads * qd
                        + D * (m.kv_lora_rank + m.rope_head_dim)
                        + m.kv_lora_rank * self.n_heads *
                        (m.nope_head_dim + m.v_head_dim)
                        + self.n_heads * m.v_head_dim * D)
            if self.is_moe_layer(li):
                ff = (self.moe.top_k + self.moe.n_shared) * 3 * D * self.moe.d_ff
            else:
                ff = 3 * D * self.d_ff
            total += attn + ff
        return total

    def layer_kind(self, li: int) -> str:
        if self.family == "ssm":
            return "rwkv"
        if self.family == "hybrid":
            return "attn" if (li % self.period) in self.attn_positions else "mamba"
        if self.mla:
            return "mla"
        return "attn"

    def is_moe_layer(self, li: int) -> bool:
        if self.moe is None or li < self.dense_prefix_layers:
            return False
        return (li % self.moe_every) == 0 if self.moe_every > 1 else True

    @property
    def subquadratic(self) -> bool:
        """Can this arch run 500k-token decode?  (SSM / mostly-SSM hybrid.)"""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have an autoregressive decoder


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}

ARCH_IDS = [
    "rwkv6_3b", "llama3_405b", "gemma_7b", "llama3_8b", "command_r_35b",
    "jamba_1_5_large_398b", "deepseek_v2_236b", "kimi_k2_1t_a32b",
    "whisper_small", "paligemma_3b",
]


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("skip: pure full-attention architecture — 524288-token "
                       "quadratic attention is out of scope (DESIGN.md)")
    return True, ""


def get_config(arch_id: str, reduced: bool = False,
               tuned: bool = False) -> ArchConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    cfg = mod.reduced() if reduced else mod.config()
    return tune(cfg) if tuned else cfg


def tune(cfg: ArchConfig, shape: "ShapeConfig" = None,
         n_chips: int = 256) -> ArchConfig:
    """Apply the §Perf-confirmed levers (EXPERIMENTS.md):
      * remat=dots (confirmed on every hillclimbed cell: -20% compute),
      * bf16 attention scores with fp32 row stats,
      * ZeRO-only sharding when (a) the optimizer state fits a 256-chip pod
        (params + 2 moments bf16 <= ~13 GB/chip), (b) the model is dense
        (expert tensors do not divide across all axes), and (c) the global
        batch actually divides the full chip count — pure DP with an
        unshardable batch replicates work (measured 14x regression on
        prefill_32k, §Perf).  Confirmed 5.0x on rwkv6-3b and 1.4x on
        llama3-405b train."""
    per_chip = 3 * 2 * cfg.param_count() / n_chips / 1e9  # GB, bf16 p+m+v
    batch_ok = shape is None or shape.global_batch % n_chips == 0
    style = "fsdp" if (cfg.moe is None and per_chip <= 13.0 and batch_ok) \
        else "tp"
    return dataclasses.replace(cfg, remat="dots", scores_bf16=True,
                               parallel_style=style)


def all_cells():
    """All (arch, shape) dry-run cells with applicability flags."""
    for aid in ARCH_IDS:
        cfg = get_config(aid)
        for sname, shape in SHAPES.items():
            ok, why = shape_applicable(cfg, shape)
            yield aid, sname, ok, why

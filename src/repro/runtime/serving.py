"""Continuous-batching serving scheduler.

A production serving loop cannot wait for a whole batch of requests to
finish before admitting new ones: it runs a fixed number of *slots*, each
holding one in-flight sequence, and every decode step advances all active
slots at once.  Finished sequences free their slot, which the admission
queue refills on the next step — the KV/state cache rows are reused
in place (position counters reset per slot).

This mirrors the ILP-scheduler worldview one level up: the decode step is a
statically scheduled circuit; admission is the only dynamic decision, and it
happens on the host between steps — no device-side synchronization.

Used by tests/test_serving.py and runnable on real request streams via
``ContinuousBatcher.run``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (len,) int32
    max_new: int
    # filled by the batcher:
    output: list = field(default_factory=list)
    done: bool = False


@dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                 # next write position in this slot's cache
    remaining: int = 0
    pending_prompt: Optional[np.ndarray] = None
    prompt_cursor: int = 0


class ContinuousBatcher:
    """Slot-based continuous batching over a one-token decode step.

    decode_fn(cache, tokens (B,1), pos (B,)) -> (logits (B,1,V), cache).
    Prompts are streamed through the same decode path one token per step
    (prefill-as-decode); production systems swap in the batched prefill
    kernel, the slot logic is identical."""

    def __init__(self, decode_fn: Callable, init_cache: Callable,
                 n_slots: int, eos: int = 1, max_len: int = 1 << 30):
        self.decode_fn = decode_fn
        self.cache = init_cache(n_slots)
        self.n_slots = n_slots
        self.eos = eos
        self.max_len = max_len
        self.slots = [_Slot() for _ in range(n_slots)]
        self.queue: list[Request] = []
        self.completed: list[Request] = []
        self.steps = 0
        self.occupancy: list[int] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for s in self.slots:
            if s.req is None and self.queue:
                req = self.queue.pop(0)
                s.req = req
                s.pos = 0
                s.remaining = req.max_new
                s.pending_prompt = req.prompt.astype(np.int32)
                s.prompt_cursor = 0

    def _active(self):
        return [i for i, s in enumerate(self.slots) if s.req is not None]

    def step(self):
        """One decode step across all slots; returns #active slots."""
        self._admit()
        act = self._active()
        self.occupancy.append(len(act))
        if not act:
            return 0
        tokens = np.zeros((self.n_slots, 1), np.int32)
        pos = np.zeros((self.n_slots,), np.int32)
        for i, s in enumerate(self.slots):
            if s.req is None:
                continue
            if s.prompt_cursor < len(s.pending_prompt):
                tokens[i, 0] = s.pending_prompt[s.prompt_cursor]
            else:
                tokens[i, 0] = s.req.output[-1] if s.req.output else self.eos
            pos[i] = s.pos
        logits, self.cache = self.decode_fn(
            self.cache, jnp.asarray(tokens), jnp.asarray(pos))
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        for i, s in enumerate(self.slots):
            if s.req is None:
                continue
            s.pos += 1
            if s.prompt_cursor < len(s.pending_prompt):
                s.prompt_cursor += 1
                if s.prompt_cursor < len(s.pending_prompt):
                    continue  # still prefilling
                # prompt done: the logits just produced the first new token
            s.req.output.append(int(nxt[i]))
            s.remaining -= 1
            if (s.remaining <= 0 or nxt[i] == self.eos
                    or s.pos >= self.max_len - 1):
                s.req.done = True
                self.completed.append(s.req)
                s.req = None  # slot freed; cache row reused in place
        self.steps += 1
        return len(act)

    def run(self, max_steps: int = 100000):
        while (self.queue or self._active()) and self.steps < max_steps:
            self.step()
        return self.completed

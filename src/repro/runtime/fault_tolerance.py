"""Fault tolerance: checkpoint/restart loop, step watchdog, failure
injection, and straggler notes.

At 1000+ nodes the dominant failures are (a) hard node loss -> the job
restarts from the last checkpoint on a (possibly resized) slice, (b) hangs
(network flap, ICI link down) -> a watchdog kills the step so the scheduler
can restart, (c) stragglers -> with a *statically scheduled* step (this
framework's design, mirroring the paper) there is no head-of-line queue to
re-order; mitigation is slice-level: the watchdog flags hosts whose step
time exceeds p99 * slack so orchestration can migrate them.  The ILP
schedule's slack analysis (core/pipeline_ilp.py) quantifies how much tick
skew a pipeline absorbs before stalling: slack_ticks = II - t_f.

This module is exercised by tests/test_fault_tolerance.py with injected
failures; on a real cluster the same loop runs unchanged per host.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint


class StepWatchdog:
    """Fires ``on_timeout`` if a step takes longer than ``timeout_s``."""

    def __init__(self, timeout_s: float, on_timeout: Callable[[], None]):
        self.timeout_s = timeout_s
        self.on_timeout = on_timeout
        self._timer: Optional[threading.Timer] = None
        self.step_times: list[float] = []
        self._t0 = None

    def start_step(self):
        self.cancel()
        self._t0 = time.monotonic()
        self._timer = threading.Timer(self.timeout_s, self.on_timeout)
        self._timer.daemon = True
        self._timer.start()

    def end_step(self):
        if self._t0 is not None:
            self.step_times.append(time.monotonic() - self._t0)
        self.cancel()

    def cancel(self):
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None

    def straggling(self, slack: float = 2.0) -> bool:
        """Is the most recent step anomalously slow vs the trailing median?"""
        if len(self.step_times) < 5:
            return False
        hist = sorted(self.step_times[-50:-1])
        med = hist[len(hist) // 2]
        return self.step_times[-1] > slack * med


@dataclass
class FaultTolerantLoop:
    """Generic checkpoint/restart training loop.

    ``step_fn(state, step) -> state`` may raise; the loop restores the last
    checkpoint and continues.  ``make_state()`` builds the initial state.
    Failure injection for tests: ``inject = {step: Exception}``."""

    ckpt_dir: str
    make_state: Callable[[], object]
    step_fn: Callable[[object, int], object]
    ckpt_every: int = 10
    max_restarts: int = 3
    inject: dict = field(default_factory=dict)

    def run(self, n_steps: int):
        ckpt = AsyncCheckpointer(self.ckpt_dir)
        restarts = 0
        state, step = self._restore_or_init()
        log = {"restarts": 0, "resumed_at": step, "completed": 0}
        while step < n_steps:
            try:
                if step in self.inject:
                    exc = self.inject.pop(step)
                    raise exc
                state = self.step_fn(state, step)
                step += 1
                log["completed"] += 1
                if step % self.ckpt_every == 0:
                    ckpt.save(step, state)
            except Exception:
                restarts += 1
                log["restarts"] = restarts
                if restarts > self.max_restarts:
                    ckpt.wait()
                    raise
                ckpt.wait()
                state, step = self._restore_or_init()
        ckpt.wait()
        ckpt.save(step, state)
        ckpt.wait()
        return state, log

    def _restore_or_init(self):
        last = latest_step(self.ckpt_dir)
        if last is None:
            return self.make_state(), 0
        template = self.make_state()
        return restore_checkpoint(self.ckpt_dir, last, template), last

from .fault_tolerance import FaultTolerantLoop, StepWatchdog
from .serving import ContinuousBatcher, Request

__all__ = ["FaultTolerantLoop", "StepWatchdog", "ContinuousBatcher",
           "Request"]

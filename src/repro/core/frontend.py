"""JAX tracing frontend: build Program IR from real kernels (DESIGN.md §11).

``trace(fn, *example_args)`` runs ``jax.make_jaxpr`` on a shape-specialized
JAX function and interprets the jaxpr into the affine dialect: every tensor
equation becomes one perfect loop nest storing a fresh intermediate array,
pure layout primitives (broadcast/transpose/squeeze/slice/1-reshape) become
*views* — affine re-indexings that never materialize — and ``lax.scan``
becomes a recurrence loop whose carry lives in a time-indexed state array
(``C[t+1] = f(C[t], xs[t])``), so a traced scan is exactly the multi-loop
task shape ``ir.nest_shape`` reports as ``multi_loop`` and the generalized
dependence model understands.

Reductions (``reduce_sum``/``reduce_max``, ``dot_general`` contractions) are
unrolled into left-fold op chains — the same element order ``sequential_exec``
and the XLA CPU loops use — which keeps the differential check tight:
``TracedProgram.validate()`` runs the traced Program through
``sim.sequential_exec`` and the original function under ``enable_x64`` on the
same inputs and compares at ``rtol=1e-12``.

The frontend is deliberately narrow: the supported primitive set is the one
the bundled kernels need (wkv6 recurrence, separable conv block, softmax
attention).  Anything else raises the structured
:class:`errors.UntraceableFunction` naming the offending primitive, so
callers widen the kernel instead of string-matching a trace dump.

Entry points ``wkv6_program`` / ``conv_block_program`` /
``attention_program`` trace single-head, tiny-shape variants of the real
kernels in ``repro.kernels`` (same math, scalar loop form) — small enough
for the DSE yet structurally faithful: the wkv6 trace carries the
data-dependent-decay recurrence, the attention trace the two matmuls and
the max/sum softmax reductions.
"""
from __future__ import annotations

import inspect
import itertools
import math
from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import numpy as np

from .errors import UntraceableFunction
from .ir import AffExpr, Program, ProgramBuilder, aff

try:  # gated: the frontend is the only core module that needs jax itself
    import jax
    import jax.numpy as jnp
    try:  # jax >= 0.4.35 moves the jaxpr types under jax.extend
        from jax.extend.core import Literal as _Literal
    except Exception:  # pragma: no cover - older jax
        from jax.core import Literal as _Literal
except ImportError:  # pragma: no cover - container always has jax
    jax = None
    jnp = None
    _Literal = ()

#: widest reduction/contraction the tracer will unroll into an op chain.
MAX_UNROLL = 256

_ELT2 = {"add": "add", "sub": "sub", "mul": "mul", "div": "div",
         "max": "max", "min": "min"}
_ELT1 = {"exp": "exp"}
_PYFOLD = {"add": lambda a, b: a + b, "sub": lambda a, b: a - b,
           "mul": lambda a, b: a * b, "div": lambda a, b: a / b,
           "max": max, "min": min, "exp": math.exp, "neg": lambda a: -a}

# storage preset for every traced array (same dual-read BRAM banking the
# hand-built benchmarks use, so DSE moves see familiar resource tradeoffs)
_STORAGE = dict(kind="bram", ports=("w", "r", "r", "r"), partition=(0,))


@dataclass(frozen=True)
class _Val:
    """A traced tensor value: an IR array plus an affine view.

    ``spec`` has one entry per ARRAY dim: either an :class:`AffExpr`
    (context-fixed index — a scan iv, a constant) or ``(dim, coef, const)``
    mapping the value's logical ``dim`` onto the array dim as
    ``coef * iv + const``.  Layout primitives only rewrite ``spec``."""

    array: str
    shape: tuple  # logical shape (may be () for scalars)
    spec: tuple


class _Tracer:
    def __init__(self, name: str):
        self.b = ProgramBuilder(name)
        self._ivn = itertools.count()
        self._arrn = itertools.count()
        # open scan context: list of (time AffExpr, extent)
        self.prefix: list = []
        self.fn_name = name

    # -- plumbing -----------------------------------------------------------
    def _die(self, primitive: str, detail: str = ""):
        raise UntraceableFunction(self.fn_name, primitive, detail)

    def _iv(self, stem: str = "i") -> str:
        return f"{stem}{next(self._ivn)}"

    def _new_array(self, full_shape, is_arg=False, name=None) -> str:
        name = name if name is not None else f"t{next(self._arrn)}"
        self.b.array(name, tuple(int(x) for x in full_shape),
                     is_arg=is_arg, **_STORAGE)
        return name

    def _spec(self, lead: tuple, shape: tuple) -> tuple:
        ents = list(lead)
        ents += [(d, 1, 0) for d in range(len(shape))] if shape else [aff(0)]
        return tuple(ents)

    def _load(self, val: _Val, els: Sequence[AffExpr]) -> str:
        idx = []
        for ent in val.spec:
            if isinstance(ent, AffExpr):
                idx.append(ent)
            else:
                d, coef, const = ent
                idx.append(els[d] * coef + const)
        return self.b.load(val.array, *idx)

    def _bload(self, v, els, out_shape) -> str:
        """Load ``v`` at the nest point ``els``, numpy-broadcasting
        size-1 value dims (and scalars) against ``out_shape``."""
        if isinstance(v, float):
            return self.b.const(v)
        if not v.shape:
            return self._load(v, [])
        if len(v.shape) != len(out_shape):
            self._die("broadcast", f"rank {len(v.shape)} operand against "
                                   f"rank {len(out_shape)} result")
        adj = [aff(0) if v.shape[k] == 1 and out_shape[k] != 1 else els[k]
               for k in range(len(v.shape))]
        return self._load(v, adj)

    def _emit_nest(self, shape, body_fn, *, store_arr=None,
                   store_lead=(), pre_drop=0) -> _Val:
        """One perfect nest over ``shape`` inside the open scan prefix;
        ``body_fn(els) -> ssa`` computes the element, which is stored into
        ``store_arr`` (fresh intermediate when None).  ``pre_drop`` drops
        that many innermost prefix dims from the store index — used when
        ``store_lead`` itself supplies the time index (carry store-back)."""
        shape = tuple(int(s) for s in shape)
        loop_shape = shape or (1,)
        pre = tuple(e for e, _ in self.prefix)
        if pre_drop:
            pre = pre[:len(pre) - pre_drop]
        if store_arr is None:
            full = tuple(n for _, n in self.prefix) + loop_shape
            store_arr = self._new_array(full)
        ctxs, ivs = [], []
        for n in loop_shape:
            ctx = self.b.loop(self._iv(), 0, n)
            ivs.append(ctx.__enter__())
            ctxs.append(ctx)
        val = body_fn(ivs if shape else [])
        self.b.store(store_arr, val, *(pre + tuple(store_lead) + tuple(ivs)))
        for ctx in reversed(ctxs):
            ctx.__exit__()
        return _Val(store_arr, shape, self._spec(pre + tuple(store_lead),
                                                 shape))

    # -- jaxpr interpretation ----------------------------------------------
    def _lift_const(self, c):
        a = np.asarray(c)
        if a.size == 1:
            return float(a.reshape(()))
        self._die("constant", f"array constant of shape {a.shape} "
                              "(pass it as a function argument)")

    def _read(self, atom, env):
        if isinstance(atom, _Literal):
            return self._lift_const(atom.val)
        return env[atom]

    def run(self, closed, invals):
        jx = closed.jaxpr
        env = {}
        for var, c in zip(jx.constvars, closed.consts):
            env[var] = self._lift_const(c)
        for var, v in zip(jx.invars, invals):
            env[var] = v
        for eqn in jx.eqns:
            self._eqn(eqn, env)
        return [self._read(a, env) for a in jx.outvars]

    def _eqn(self, eqn, env):
        prim = eqn.primitive.name
        invals = [self._read(a, env) for a in eqn.invars]
        params = eqn.params
        if prim == "scan":
            outs = self._scan(eqn, invals)
            for var, v in zip(eqn.outvars, outs):
                if type(var).__name__ != "DropVar":
                    env[var] = v
            return
        if prim in ("pjit", "closed_call", "core_call", "custom_jvp_call",
                    "custom_vjp_call", "remat2", "checkpoint"):
            inner = params.get("jaxpr") or params.get("call_jaxpr")
            if inner is None:
                self._die(prim, "call primitive without an inner jaxpr")
            outs = self.run(inner, invals)
            for var, v in zip(eqn.outvars, outs):
                if type(var).__name__ != "DropVar":
                    env[var] = v
            return
        out_var = eqn.outvars[0]
        out_shape = tuple(out_var.aval.shape)
        if prim in _ELT2 or prim in _ELT1 or prim in ("neg", "integer_pow"):
            if all(isinstance(v, float) for v in invals) and prim in _PYFOLD:
                env[out_var] = _PYFOLD[prim](*invals)
                return
            env[out_var] = self._elementwise(prim, params, invals, out_shape)
        elif prim == "broadcast_in_dim":
            env[out_var] = self._broadcast(invals[0], params, out_shape)
        elif prim == "transpose":
            env[out_var] = self._transpose(invals[0], params["permutation"],
                                           out_shape)
        elif prim == "squeeze":
            env[out_var] = self._squeeze(invals[0], params["dimensions"],
                                         out_shape)
        elif prim == "reshape":
            env[out_var] = self._reshape(invals[0], out_shape)
        elif prim == "slice":
            env[out_var] = self._slice(invals[0], params, out_shape)
        elif prim in ("reduce_sum", "reduce_max", "reduce_min"):
            env[out_var] = self._reduce(prim, invals[0], params["axes"],
                                        out_shape)
        elif prim == "dot_general":
            env[out_var] = self._dot(invals[0], invals[1],
                                     params["dimension_numbers"], out_shape)
        elif prim in ("convert_element_type", "stop_gradient", "copy"):
            env[out_var] = invals[0]
        else:
            self._die(prim)

    # -- compute primitives -------------------------------------------------
    def _elementwise(self, prim, params, invals, out_shape) -> _Val:
        def body(els):
            if prim == "neg":
                z = self.b.const(0.0)
                return self.b.sub(z, self._bload(invals[0], els, out_shape))
            if prim == "integer_pow":
                y = int(params["y"])
                if y < 1:
                    self._die("integer_pow", f"exponent {y}")
                x = self._bload(invals[0], els, out_shape)
                acc = x
                for _ in range(y - 1):
                    acc = self.b.mul(acc, x)
                return acc
            args = [self._bload(v, els, out_shape) for v in invals]
            return self.b.arith(_ELT2.get(prim) or _ELT1[prim], *args)

        return self._emit_nest(out_shape, body)

    def _reduce(self, prim, v, axes, out_shape) -> _Val:
        if isinstance(v, float):
            self._die(prim, "reduction of a constant")
        axes = tuple(sorted(int(a) for a in axes))
        extents = [v.shape[a] for a in axes]
        count = 1
        for n in extents:
            count *= n
        if count > MAX_UNROLL:
            self._die(prim, f"reduction of {count} elements exceeds the "
                            f"unroll budget ({MAX_UNROLL})")
        fn = {"reduce_sum": "add", "reduce_max": "max",
              "reduce_min": "min"}[prim]

        def body(els):
            terms = []
            for combo in itertools.product(*[range(n) for n in extents]):
                full, free = [], iter(els)
                for k in range(len(v.shape)):
                    full.append(aff(combo[axes.index(k)]) if k in axes
                                else next(free))
                terms.append(self._load(v, full))
            acc = terms[0]  # left fold: sequential_exec's element order
            for t in terms[1:]:
                acc = self.b.arith(fn, acc, t)
            return acc

        return self._emit_nest(out_shape, body)

    def _dot(self, a, b, dimension_numbers, out_shape) -> _Val:
        (lc, rc), (lb, rb) = dimension_numbers
        if lb or rb or len(lc) != 1 or len(rc) != 1:
            self._die("dot_general", f"dimension_numbers {dimension_numbers}"
                                     " (batched/multi-axis contraction)")
        if isinstance(a, float) or isinstance(b, float):
            self._die("dot_general", "contraction with a constant operand")
        lc0, rc0 = int(lc[0]), int(rc[0])
        K = a.shape[lc0]
        if K > MAX_UNROLL:
            self._die("dot_general", f"contraction of {K} elements exceeds "
                                     f"the unroll budget ({MAX_UNROLL})")
        lf = [d for d in range(len(a.shape)) if d != lc0]
        rf = [d for d in range(len(b.shape)) if d != rc0]

        def body(els):
            acc = None
            for k in range(K):
                fa = [None] * len(a.shape)
                fa[lc0] = aff(k)
                for i, d in enumerate(lf):
                    fa[d] = els[i]
                fb = [None] * len(b.shape)
                fb[rc0] = aff(k)
                for j, d in enumerate(rf):
                    fb[d] = els[len(lf) + j]
                term = self.b.mul(self._load(a, fa), self._load(b, fb))
                acc = term if acc is None else self.b.add(acc, term)
            return acc

        return self._emit_nest(out_shape, body)

    # -- layout primitives (views: spec rewrites, no code) ------------------
    def _broadcast(self, v, params, out_shape):
        if isinstance(v, float):
            return v
        bd = tuple(int(d) for d in params["broadcast_dimensions"])
        ents = []
        for ent in v.spec:
            if isinstance(ent, AffExpr):
                ents.append(ent)
            else:
                d, coef, const = ent
                if v.shape[d] == 1 and out_shape[bd[d]] != 1:
                    ents.append(aff(const))  # stretched dim: index pins to 0
                else:
                    ents.append((bd[d], coef, const))
        return _Val(v.array, out_shape, tuple(ents))

    def _transpose(self, v, permutation, out_shape):
        if isinstance(v, float):
            return v
        perm = tuple(int(x) for x in permutation)
        inv = {d: j for j, d in enumerate(perm)}
        ents = [ent if isinstance(ent, AffExpr)
                else (inv[ent[0]], ent[1], ent[2]) for ent in v.spec]
        return _Val(v.array, out_shape, tuple(ents))

    def _squeeze(self, v, dimensions, out_shape):
        if isinstance(v, float):
            return v
        drop = set(int(d) for d in dimensions)
        remap = {}
        for d in range(len(v.shape)):
            if d not in drop:
                remap[d] = len(remap)
        ents = []
        for ent in v.spec:
            if isinstance(ent, AffExpr):
                ents.append(ent)
            elif ent[0] in drop:  # extent-1 dim: its iv is always 0
                ents.append(aff(ent[2]))
            else:
                ents.append((remap[ent[0]], ent[1], ent[2]))
        return _Val(v.array, out_shape, tuple(ents))

    def _reshape(self, v, out_shape):
        if isinstance(v, float):
            return v
        old_nz = [d for d in range(len(v.shape)) if v.shape[d] != 1]
        new_nz = [d for d in range(len(out_shape)) if out_shape[d] != 1]
        if [v.shape[d] for d in old_nz] != [out_shape[d] for d in new_nz]:
            self._die("reshape", f"{v.shape} -> {out_shape} (only inserting/"
                                 "removing size-1 dims is traceable)")
        remap = dict(zip(old_nz, new_nz))
        ents = []
        for ent in v.spec:
            if isinstance(ent, AffExpr):
                ents.append(ent)
            elif ent[0] in remap:
                ents.append((remap[ent[0]], ent[1], ent[2]))
            else:  # a size-1 dim: always index its constant offset
                ents.append(aff(ent[2]))
        return _Val(v.array, out_shape, tuple(ents))

    def _slice(self, v, params, out_shape):
        if isinstance(v, float):
            return v
        starts = tuple(int(x) for x in params["start_indices"])
        strides = params.get("strides") or (1,) * len(starts)
        strides = tuple(int(x) for x in strides)
        ents = []
        for ent in v.spec:
            if isinstance(ent, AffExpr):
                ents.append(ent)
            else:
                d, coef, const = ent
                ents.append((d, coef * strides[d],
                             const + coef * starts[d]))
        return _Val(v.array, out_shape, tuple(ents))

    # -- scan: the recurrence loop ------------------------------------------
    def _scan(self, eqn, invals) -> list:
        pr = eqn.params
        if pr.get("reverse"):
            self._die("scan", "reverse=True")
        T = int(pr["length"])
        n_c, n_k = int(pr["num_consts"]), int(pr["num_carry"])
        body = pr["jaxpr"]
        consts = invals[:n_c]
        inits = invals[n_c:n_c + n_k]
        xs = invals[n_c + n_k:]
        pre_exts = tuple(n for _, n in self.prefix)
        pre_exprs = tuple(e for e, _ in self.prefix)

        carries = []  # (array, logical shape)
        for i, init in enumerate(inits):
            shp = tuple(body.jaxpr.invars[n_c + i].aval.shape)
            cname = self._new_array(pre_exts + (T + 1,) + (shp or (1,)))
            self._emit_nest(shp,
                            lambda els, v=init, s=shp: self._bload(v, els, s),
                            store_arr=cname, store_lead=(aff(0),))
            carries.append((cname, shp))

        ctx = self.b.loop(self._iv("t"), 0, T)
        t = ctx.__enter__()
        self.prefix.append((t, T))
        try:
            benv = list(consts)
            for cname, shp in carries:
                benv.append(_Val(cname, shp,
                                 self._spec(pre_exprs + (t,), shp)))
            for x in xs:
                benv.append(self._bind_time(x, t))
            bouts = self.run(body, benv)
            new_carries = bouts[:n_k]
            ys = bouts[n_k:]
            for (cname, shp), nv in zip(carries, new_carries):
                self._emit_nest(shp,
                                lambda els, v=nv, s=shp:
                                self._bload(v, els, s),
                                store_arr=cname, store_lead=(t + 1,),
                                pre_drop=1)
            y_arrays = []
            for y in ys:
                shp = () if isinstance(y, float) else y.shape
                fresh = (not isinstance(y, float)
                         and y.spec == self._spec(pre_exprs + (t,), shp))
                if fresh:  # already a per-step intermediate: reuse in place
                    y_arrays.append((y.array, shp))
                else:
                    yv = self._emit_nest(
                        shp, lambda els, v=y, s=shp: self._bload(v, els, s))
                    y_arrays.append((yv.array, shp))
        finally:
            self.prefix.pop()
            ctx.__exit__()

        outs = []
        for cname, shp in carries:
            outs.append(_Val(cname, shp,
                             self._spec(pre_exprs + (aff(T),), shp)))
        for yarr, shp in y_arrays:
            outs.append(_Val(yarr, (T,) + shp,
                             self._spec(pre_exprs, (T,) + shp)))
        return outs

    def _bind_time(self, val, t: AffExpr):
        """Bind a scanned input's leading (time) dim to the loop iv."""
        if isinstance(val, float):
            self._die("scan", "scanned-over constant input")
        ents = []
        for ent in val.spec:
            if isinstance(ent, AffExpr):
                ents.append(ent)
            elif ent[0] == 0:
                ents.append(t * ent[1] + ent[2])
            else:
                ents.append((ent[0] - 1, ent[1], ent[2]))
        return _Val(val.array, val.shape[1:], tuple(ents))


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


@dataclass
class TracedProgram:
    """A Program built by tracing ``fn`` plus the differential-check hooks.

    ``program`` is ordinary affine IR — feed it straight to ``hls.compile``.
    ``in_names``/``out_names`` name the arrays bound to the function's
    arguments and (copied) outputs; ``in_shapes``/``out_shapes`` keep the
    original JAX shapes (scalars are stored as shape-(1,) arrays)."""

    program: Program
    fn: Callable
    in_names: tuple
    out_names: tuple
    in_shapes: tuple
    out_shapes: tuple

    def validate(self, seed: int = 0, rtol: float = 1e-12) -> float:
        """Differential check: run the traced Program through
        ``sim.sequential_exec`` and ``fn`` (float64) on the same inputs;
        returns the max relative error, raising AssertionError past
        ``rtol``."""
        from jax.experimental import enable_x64

        from . import sim

        inputs = sim.make_inputs(self.program, seed=seed)
        got = sim.sequential_exec(self.program, inputs)
        args = [np.asarray(inputs[n], np.float64).reshape(s)
                for n, s in zip(self.in_names, self.in_shapes)]
        with enable_x64():
            want = self.fn(*[jnp.asarray(a) for a in args])
        if not isinstance(want, (tuple, list)):
            want = (want,)
        worst = 0.0
        for name, shape, w in zip(self.out_names, self.out_shapes, want):
            g = np.asarray(got[name], np.float64).reshape(shape)
            w = np.asarray(w, np.float64)
            err = np.max(np.abs(g - w) / np.maximum(np.abs(w), 1e-300))
            worst = max(worst, float(err))
            if not np.allclose(g, w, rtol=rtol, atol=0):
                raise AssertionError(
                    f"traced '{self.program.name}' diverges from its source "
                    f"kernel on '{name}': max rel err {err:.3e} > {rtol:g}")
        return worst

    def lint(self):
        """Static findings on the traced IR (``analysis.lint``) — catches
        what :meth:`validate` cannot: accesses that only leave their array
        on inputs the differential seed never exercises, dead stores the
        simulator silently performs, and multi-writer hazards masked by
        sequential execution order."""
        from .analysis import lint
        return lint(self.program)


def trace(fn: Callable, *example_args, name: Optional[str] = None,
          in_names: Optional[Sequence[str]] = None,
          out_names: Optional[Sequence[str]] = None) -> TracedProgram:
    """Trace ``fn`` on ``example_args`` into a :class:`TracedProgram`."""
    if jax is None:  # pragma: no cover - container always has jax
        raise ImportError("repro.core.frontend requires jax")
    name = name or getattr(fn, "__name__", "traced")
    closed = jax.make_jaxpr(fn)(*example_args)
    if in_names is None:
        try:
            in_names = list(inspect.signature(fn).parameters)
        except (TypeError, ValueError):
            in_names = []
    in_names = list(in_names)
    flat_avals = [v.aval for v in closed.jaxpr.invars]
    if len(in_names) != len(flat_avals):  # pytree args: positional names
        in_names = [f"x{i}" for i in range(len(flat_avals))]
    tr = _Tracer(name)
    invals = []
    in_shapes = []
    for argname, aval in zip(in_names, flat_avals):
        shp = tuple(int(s) for s in aval.shape)
        tr._new_array(shp or (1,), is_arg=True, name=argname)
        invals.append(_Val(argname, shp, tr._spec((), shp)))
        in_shapes.append(shp)
    outs = tr.run(closed, invals)
    if out_names is None:
        out_names = [f"out{i}" for i in range(len(outs))] \
            if len(outs) > 1 else ["out"]
    out_shapes = []
    for oname, val in zip(out_names, outs):
        shp = () if isinstance(val, float) else val.shape
        tr._new_array((tuple(shp) or (1,)), is_arg=True, name=oname)
        tr._emit_nest(shp, lambda els, v=val, s=shp: tr._bload(v, els, s),
                      store_arr=oname)
        out_shapes.append(tuple(shp))
    return TracedProgram(program=tr.b.build(), fn=fn,
                         in_names=tuple(in_names),
                         out_names=tuple(out_names),
                         in_shapes=tuple(in_shapes),
                         out_shapes=tuple(out_shapes))


# ---------------------------------------------------------------------------
# Traced variants of the bundled kernels (single head, tiny shapes)
# ---------------------------------------------------------------------------


def wkv6_program(T: int = 4, D: int = 4) -> TracedProgram:
    """Single-head RWKV-6 WKV recurrence (``kernels.ref.wkv6_ref`` math with
    B=H=1): a ``lax.scan`` over tokens carrying the (D, D) state."""

    def wkv6_head(r, k, v, w, u):
        def step(s, xs):
            rt, kt, vt, wt = xs                       # (D,)
            kv = kt[:, None] * vt[None, :]            # (D, D)
            out = ((s + u[:, None] * kv) * rt[:, None]).sum(axis=0)
            s1 = s * wt[:, None] + kv
            return s1, out

        s0 = jnp.zeros((s0_d, s0_d), r.dtype)
        _, outs = jax.lax.scan(step, s0, (r, k, v, w))
        return outs

    s0_d = D
    ex = [np.zeros((T, D), np.float32)] * 4 + [np.zeros((D,), np.float32)]
    return trace(wkv6_head, *ex, name=f"traced_wkv6_t{T}d{D}")


def conv_block_program(H: int = 8, W: int = 8) -> TracedProgram:
    """Separable 3x3 conv block (``kernels.ref.stencil_pipeline_ref``):
    a row pass then a column pass — the paper's Fig. 1 chain, traced."""

    def conv_block(img, wx, wy):
        bx = (img[:, 0:W - 2] * wx[0] + img[:, 1:W - 1] * wx[1]
              + img[:, 2:W] * wx[2])
        return (bx[0:H - 2, :] * wy[0] + bx[1:H - 1, :] * wy[1]
                + bx[2:H, :] * wy[2])

    ex = [np.zeros((H, W), np.float32), np.zeros((3,), np.float32),
          np.zeros((3,), np.float32)]
    return trace(conv_block, *ex, name=f"traced_conv_h{H}w{W}")


def attention_program(T: int = 4, D: int = 4) -> TracedProgram:
    """Single-head softmax attention (``kernels.ref.flash_attention_ref``
    math, non-causal, B=H=1): two matmuls around a max/sum softmax."""

    def attention(q, k, v):
        s = (q @ k.T) * (D ** -0.5)
        m = s.max(axis=1, keepdims=True)
        e = jnp.exp(s - m)
        z = e.sum(axis=1, keepdims=True)
        return (e / z) @ v

    ex = [np.zeros((T, D), np.float32)] * 3
    return trace(attention, *ex, name=f"traced_attention_t{T}d{D}")

"""The scheduling ILP (§4) over absolute offsets.

HIR assigns every op a start time *relative to its parent region*; we solve
for the absolute offset theta_op = sum of relative t along the ancestor chain
(with all enclosing ivs = 0).  Every paper constraint then becomes a
difference constraint

    theta_snk - theta_src >= lower

(lower = delay - slack for memory/port dependences, = producer latency for
SSA dependences, = 0 for the structural t >= 0 constraints), i.e. a system
with a totally-unimodular matrix: Bellman-Ford (longest path) gives the exact
integer earliest schedule and feasibility; the paper's §4.3 objective
(minimize shift-register delays) is then optimized by integer coordinate
descent (exact LP via our simplex for small programs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from .deps import DepAnalysis, DepEdge
from .ir import ArithOp, ConstOp, LoadOp, Loop, Program, StoreOp


@dataclass
class Schedule:
    program: Program
    iis: dict[int, int]                 # loop uid -> II
    theta: dict[int, int]               # op uid -> absolute offset
    edges: list[DepEdge]
    feasible: bool = True
    # "exact" when every dependence slack was solved to proven optimality;
    # "degraded" when a truncated solver forced a conservative (sound but
    # possibly over-serialized) bound somewhere — see DESIGN.md §9
    provenance: str = "exact"

    # ------------------------------------------------------------------
    def t(self, op_uid: int, parent_uid: Optional[int]) -> int:
        base = self.theta[parent_uid] if parent_uid is not None else 0
        return self.theta[op_uid] - base

    def _iv_span(self, ancestors) -> int:
        return sum((l.trip - 1) * self.iis[l.uid] for l in ancestors)

    def completion_time(self) -> int:
        worst = 0
        for node, anc in self.program.walk():
            if isinstance(node, Loop):
                continue
            end = self.theta[node.uid] + self._iv_span(anc) + \
                self.program.op_latency(node)
            worst = max(worst, end)
        return worst

    def nest_latency(self, top_item) -> int:
        """Latency of one top-level item in isolation (relative to its start)."""
        base = self.theta[top_item.uid]
        worst = 0
        for node, anc in self.program.walk():
            if isinstance(node, Loop):
                continue
            if not any(a is top_item for a in anc):
                continue
            end = self.theta[node.uid] - base + self._iv_span(anc) + \
                self.program.op_latency(node)
            worst = max(worst, end)
        return worst

    def sequential_nests_latency(self) -> int:
        """The paper's 'loop-only pipelining' baseline: every top-level loop
        nest fully pipelined internally but nests executed back-to-back."""
        total = 0
        for item in self.program.body:
            if isinstance(item, Loop):
                total += self.nest_latency(item)
            else:
                total += self.program.op_latency(item)
        return total

    # -- resource metrics (paper §4.3 / Fig. 9) -------------------------
    def delay_register_bits(self) -> int:
        """Shift-register bits: per SSA def, bits * max delay over its uses."""
        defs = {}
        for node, _ in self.program.walk():
            if not isinstance(node, Loop) and node.result is not None:
                defs[node.result] = node
        per_def: dict[str, int] = {}
        for node, _ in self.program.walk():
            if isinstance(node, Loop):
                continue
            uses = list(getattr(node, "args", ()) or ())
            if isinstance(node, StoreOp):
                uses.append(node.value)
            for u in uses:
                d = defs.get(u)
                if d is None:
                    continue
                delay = self.theta[node.uid] - self.theta[d.uid] - \
                    self.program.op_latency(d)
                per_def[u] = max(per_def.get(u, 0), max(0, delay))
        return 32 * sum(per_def.values())


# ---------------------------------------------------------------------------


def _all_nodes(p: Program):
    return [node for node, _ in p.walk()]


def _parent_map(p: Program) -> dict[int, Optional[int]]:
    pm: dict[int, Optional[int]] = {}
    for node, anc in p.walk():
        pm[node.uid] = anc[-1].uid if anc else None
    return pm


def check_loop_occupancy(p: Program, iis: dict[int, int]) -> bool:
    """Loop-counter non-reentrance: II_outer >= trip_inner * II_inner for a
    directly nested loop (matches Fig. 3: II_i = 14 = 2 * II_j)."""
    for node, anc in p.walk():
        if isinstance(node, Loop) and anc:
            parent = anc[-1]
            if iis[parent.uid] < node.trip * iis[node.uid]:
                return False
    return True


def longest_path(nodes, edges: list[DepEdge]) -> Optional[dict[int, int]]:
    """Earliest schedule via integer Bellman-Ford; None if positive cycle.

    Vectorized: edges become (src, snk, lower) numpy columns sorted by sink;
    each relaxation pass is one gather + segmented max (``reduceat``) instead
    of a Python loop over edges.  Synchronous relaxation reaches the least
    fixpoint in <= |V| passes (optimal walks are simple when no positive
    cycle exists); still changing after that means a positive cycle.
    """
    ids = [n.uid for n in nodes]
    nv = len(ids)
    idx = {u: i for i, u in enumerate(ids)}
    es = [(idx[e.src], idx[e.snk], e.lower) for e in edges
          if e.lower > -10**9]
    if not es:
        return dict.fromkeys(ids, 0)
    arr = np.asarray(es, dtype=np.int64)
    order = np.argsort(arr[:, 1], kind="stable")
    src, snk, low = arr[order, 0], arr[order, 1], arr[order, 2]
    starts = np.flatnonzero(np.r_[True, snk[1:] != snk[:-1]])
    targets = snk[starts]
    theta = np.zeros(nv, dtype=np.int64)
    for _ in range(nv + 1):
        best = np.maximum.reduceat(theta[src] + low, starts)
        cur = theta[targets]
        if np.all(best <= cur):
            return dict(zip(ids, theta.tolist()))
        theta[targets] = np.maximum(cur, best)
    return None  # positive cycle -> infeasible


def _minimize_delays(p: Program, theta: dict[int, int], edges: list[DepEdge],
                     passes: int = 60) -> dict[int, int]:
    """Integer coordinate descent on the §4.3 objective: for each node, move
    it within its feasible interval in the direction that reduces
    (shift-register delays) with Sum(theta) as the tie-break."""
    inc: dict[int, list] = {}
    out: dict[int, list] = {}
    weight: dict[int, int] = {uid: 0 for uid in theta}
    for e in edges:
        out.setdefault(e.src, []).append(e)
        inc.setdefault(e.snk, []).append(e)
        if e.kind == "SSA":
            weight[e.snk] = weight.get(e.snk, 0) + 32   # as a use: earlier is better
            weight[e.src] = weight.get(e.src, 0) - 32   # as a def: later is better
    for uid in weight:
        weight[uid] += 1  # epsilon * sum(theta) tie-break: earlier preferred

    order = [n.uid for n in _all_nodes(p)]
    for _ in range(passes):
        changed = False
        for uid in order:
            lb = 0
            for e in inc.get(uid, ()):  # theta_uid >= theta_src + lower
                lb = max(lb, theta[e.src] + e.lower)
            ub = None
            for e in out.get(uid, ()):  # theta_snk >= theta_uid + lower
                cap = theta[e.snk] - e.lower
                ub = cap if ub is None else min(ub, cap)
            w = weight[uid]
            tgt = theta[uid]
            if w > 0:
                tgt = lb
            elif w < 0 and ub is not None:
                tgt = max(lb, ub)
            if tgt != theta[uid]:
                theta[uid] = tgt
                changed = True
        if not changed:
            break
    return theta


def build_edges(dep: DepAnalysis, iis: dict[int, int]) -> list[DepEdge]:
    """Memory edges are cached per conflicting pair on the IIs of the loops
    in that pair's iteration vectors, so a probe that moves one loop's II
    only recomputes the edges touching that loop; SSA/structural edges are
    II-independent and built once per DepAnalysis."""
    return dep.memory_edges(iis) + dep.static_edges()


def schedule(p: Program, iis: dict[int, int],
             dep: Optional[DepAnalysis] = None,
             minimize_registers: bool = True) -> Schedule:
    dep = dep or DepAnalysis(p)
    nodes = dep.all_nodes()

    def prov() -> str:
        # evaluated at return time: slacks (and hence degradations) are
        # computed lazily while the edges are being built
        return "degraded" if getattr(dep, "degradations", None) else "exact"

    if not check_loop_occupancy(p, iis):
        return Schedule(p, iis, {n.uid: 0 for n in nodes}, [], feasible=False,
                        provenance=prov())
    edges = build_edges(dep, iis)
    theta = longest_path(nodes, edges)
    if theta is None:
        return Schedule(p, iis, {n.uid: 0 for n in nodes}, edges,
                        feasible=False, provenance=prov())
    if minimize_registers:
        theta = _minimize_delays(p, theta, edges)
    return Schedule(p, iis, theta, edges, feasible=True, provenance=prov())


def feasible(p: Program, iis: dict[int, int], dep: DepAnalysis) -> bool:
    if not check_loop_occupancy(p, iis):
        return False
    edges = build_edges(dep, iis)
    return longest_path(dep.all_nodes(), edges) is not None


# ---------------------------------------------------------------------------
# HIR-style pretty printer (Fig. 3b flavour) for demos/debugging
# ---------------------------------------------------------------------------


def emit_hir(s: Schedule) -> str:
    p = s.program
    lines = [f"hir.func @{p.name} at %t {{"]

    def rec(items, parent_uid, depth):
        pad = "  " * depth
        for it in items:
            if isinstance(it, Loop):
                t = s.t(it.uid, parent_uid)
                lines.append(
                    f"{pad}hir.for %{it.ivname} = {it.lb} to {it.ub} "
                    f"at +{t} iter_time(%t{it.ivname}) {{")
                rec(it.body, it.uid, depth + 1)
                lines.append(f"{pad}  hir.next_iter at %t{it.ivname}+{s.iis[it.uid]}"
                             f"  {{II = {s.iis[it.uid]}}}")
                lines.append(f"{pad}}}")
            else:
                t = s.t(it.uid, parent_uid)
                if isinstance(it, LoadOp):
                    desc = f"%{it.result} = hir.load {it.array}[port {it.port}]{list(it.index)}"
                elif isinstance(it, StoreOp):
                    desc = f"hir.store {it.value} to {it.array}[port {it.port}]{list(it.index)}"
                elif isinstance(it, ArithOp):
                    desc = f"%{it.result} = hir.call @{it.fn}_f32{list(it.args)}"
                elif isinstance(it, ConstOp):
                    desc = f"%{it.result} = hir.const {it.value}"
                else:
                    desc = repr(it)
                lines.append(f"{pad}{desc} at +{t}")

    rec(p.body, None, 1)
    lines.append("}")
    return "\n".join(lines)

"""Persistent content-addressed compile cache (DESIGN.md §8).

Production use of the DSE is compile-once/serve-many: the same program is
recompiled on every process start, every retune, every CI run.  This module
makes repeat compiles O(lookup) by keying schedules and whole Pareto
frontiers on a *program fingerprint* — a generalization of
``deps.iteration_space_key`` that covers everything the compiled artifact
depends on:

  * the iteration spaces (loop structure, bounds, pragmas, peel/tile/fusion
    markers) and every affine access function,
  * array shapes, widths, storage kinds, ports and partitioning,
  * the op-latency table,
  * the textual pass-pipeline applied on top of the program,
  * the resource-model mode, and
  * a scheduler *version salt* (``SCHEDULER_SALT``) — bumped whenever the
    scheduler, the transforms or the resource model change semantics, so
    stale entries from an older compiler can never be replayed.

Unlike ``iteration_space_key`` the fingerprint is **uid-free** (node
identities are walk positions, not the process-local ``ir._uid`` counter),
which is what lets entries persist across processes: schedules are packed
positionally (``pack_schedule``/``unpack_schedule``) and rehydrated onto a
freshly built program whose uids differ.

Store layout: one JSON blob per entry under ``$REPRO_HLS_CACHE_DIR`` (default
``~/.cache/repro-hls``), sharded by the first two key hex digits.  Writes are
atomic (temp file + ``os.replace``) so concurrent writers never corrupt the
store — the worst case is both doing the same work and one rename winning.
The store is size-bounded: an LRU sweep (by mtime; reads ``os.utime`` their
entry) evicts the oldest entries past ``max_entries``/``max_bytes``.

Correctness contract (tested differentially in tests/test_cache.py): a cache
hit must be byte-identical to a cold compile — same ``theta``/``iis``/
latency/resource vector — and any corrupt, truncated or stale-salt entry is
detected, discarded and recompiled.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from typing import Optional

from . import faults
from .ir import ArithOp, ConstOp, LoadOp, Loop, Program, StoreOp

# Version salt: bump whenever the scheduler, a transform, or the resource
# model changes behavior — persisted entries with a different salt are
# invalid by definition and are discarded on read.
# 7: checksummed wrapper format + Schedule/frontier provenance fields.
SCHEDULER_SALT = "repro-hls-7"

DEFAULT_MAX_ENTRIES = 4096
DEFAULT_MAX_BYTES = 256 << 20  # 256 MiB


# ---------------------------------------------------------------------------
# Program fingerprint
# ---------------------------------------------------------------------------


def program_text(p: Program) -> str:
    """Canonical uid-free description of everything a schedule depends on.

    Node identity is the walk position (preorder) + depth, which pins the
    loop tree shape; ``fuse_group`` ids (global-counter values) are
    renumbered in first-seen order.  SSA value names are included — they
    carry the def-use graph — and are deterministic per builder/transform
    invocation, so two constructions of the same program (in different
    processes) produce the same text.
    """
    parts = []
    for name in sorted(p.arrays):
        a = p.arrays[name]
        parts.append(
            f"A{name}:{a.shape}:{a.kind}:{a.ports}:{a.partition}:"
            f"{a.rd_latency}:{a.wr_latency}:{a.elem_bits}:{int(a.is_arg)}")
    parts.append("D" + ",".join(f"{k}={v}"
                                for k, v in sorted(p.op_delays.items())))
    groups: dict[int, int] = {}
    for node, anc in p.walk():
        d = len(anc)
        if isinstance(node, Loop):
            g = node.fuse_group
            if g is not None:
                g = groups.setdefault(g, len(groups))
            parts.append(
                f"L{d}:{node.ivname}:{node.lb}:{node.ub}:"
                f"{int(node.pipeline)}:{node.ii}:{int(node.unroll)}:"
                f"{int(node.peel)}:{node.tile_block}:{g}")
        elif isinstance(node, LoadOp):
            parts.append(f"R{d}:{node.array}:{node.index!r}:{node.result}")
        elif isinstance(node, StoreOp):
            parts.append(f"W{d}:{node.array}:{node.index!r}:{node.value}")
        elif isinstance(node, ArithOp):
            parts.append(f"O{d}:{node.fn}:{node.result}:"
                         + ",".join(node.args))
        elif isinstance(node, ConstOp):
            parts.append(f"C{d}:{node.value!r}:{node.result}")
        else:  # future node kinds must not silently alias
            parts.append(f"X{d}:{type(node).__name__}")
    return "|".join(parts)


def fingerprint(p: Program, *, pipeline: str = "", mode: str = "ours",
                salt: str = SCHEDULER_SALT, extra: str = "") -> str:
    """sha256 hex key over (program text, pipeline string, resource-model
    mode, scheduler salt, caller-specific extra)."""
    h = hashlib.sha256()
    for chunk in (program_text(p), pipeline, mode, salt, extra):
        h.update(chunk.encode())
        h.update(b"\x1f")
    return h.hexdigest()


def string_key(*parts: str, salt: str = SCHEDULER_SALT) -> str:
    """A content key for non-Program payloads (e.g. kernel DSE configs)."""
    h = hashlib.sha256()
    for chunk in parts + (salt,):
        h.update(str(chunk).encode())
        h.update(b"\x1f")
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Positional (uid-free) schedule serialization
# ---------------------------------------------------------------------------


def pack_schedule(s) -> dict:
    """Pack a ``scheduler.Schedule`` positionally: uids become walk indices
    of ``s.program``, so the blob rehydrates onto any structurally identical
    program regardless of its process-local uids."""
    order = [n for n, _ in s.program.walk()]
    idx = {n.uid: i for i, n in enumerate(order)}
    return {
        "iis": [s.iis[n.uid] for n in order if isinstance(n, Loop)],
        "theta": sorted([idx[u], t] for u, t in s.theta.items()),
        "edges": [[idx[e.src], idx[e.snk], e.lower, e.kind, e.array]
                  for e in s.edges],
        "feasible": bool(s.feasible),
        "provenance": getattr(s, "provenance", "exact"),
    }


def unpack_schedule(q: Program, blob: dict):
    """Rehydrate a packed schedule onto ``q``.  Raises ``ValueError`` when
    the blob does not fit the program's shape (stale entry)."""
    from .deps import DepEdge
    from .scheduler import Schedule

    order = [n for n, _ in q.walk()]
    loops = [n for n in order if isinstance(n, Loop)]
    iis_list = blob["iis"]
    if len(iis_list) != len(loops):
        raise ValueError(
            f"cached schedule has {len(iis_list)} loop IIs, program has "
            f"{len(loops)} loops")
    iis = {l.uid: int(v) for l, v in zip(loops, iis_list)}
    theta = {}
    for i, t in blob["theta"]:
        if not 0 <= i < len(order):
            raise ValueError(f"cached theta index {i} out of range")
        theta[order[i].uid] = int(t)
    edges = []
    for src, snk, lower, kind, array in blob["edges"]:
        if not (0 <= src < len(order) and 0 <= snk < len(order)):
            raise ValueError("cached edge index out of range")
        edges.append(DepEdge(src=order[src].uid, snk=order[snk].uid,
                             lower=int(lower), kind=kind, array=array))
    return Schedule(program=q, iis=iis, theta=theta, edges=edges,
                    feasible=bool(blob.get("feasible", True)),
                    provenance=str(blob.get("provenance", "exact")))


# ---------------------------------------------------------------------------
# Disk store
# ---------------------------------------------------------------------------


class CacheStore:
    """A content-addressed JSON blob store with atomic writes and LRU
    eviction.  All failure modes degrade to a miss — a broken disk can slow
    compiles down but never wrong them."""

    def __init__(self, root: Optional[str] = None, *,
                 salt: str = SCHEDULER_SALT,
                 max_entries: int = DEFAULT_MAX_ENTRIES,
                 max_bytes: int = DEFAULT_MAX_BYTES):
        self.root = root or default_cache_dir()
        self.salt = salt
        self.max_entries = max_entries
        self.max_bytes = max_bytes
        self.hits = 0
        self.misses = 0
        self.puts = 0
        self.evictions = 0
        self.repairs = 0  # corrupt entries detected, discarded, recompiled
        self._mem: dict[str, object] = {}  # in-process read-through layer

    # ------------------------------------------------------------------
    def _path(self, key: str) -> str:
        return os.path.join(self.root, key[:2], key + ".json")

    @staticmethod
    def _checksum(data_str: str) -> str:
        return hashlib.sha256(data_str.encode()).hexdigest()

    def get(self, key: str):
        """The entry for ``key`` or None.  Corrupt (torn write, bit flip,
        checksum mismatch) or stale-salt blobs are deleted, counted in
        ``repairs``, and reported as a miss (the caller recompiles/re-puts)."""
        obj = self._mem.get(key)
        if obj is not None:
            self.hits += 1
            return obj
        path = self._path(key)
        try:
            with open(path, "r") as f:
                raw = f.read()
            if faults.should_fire("cache_corrupt", key="get:" + key):
                # simulate a torn blob surfacing at read time
                raw = raw[:max(1, (2 * len(raw)) // 3)]
            wrapper = json.loads(raw)
            if not isinstance(wrapper, dict) or wrapper.get("salt") != self.salt:
                raise ValueError("cache salt mismatch")
            data = wrapper["data"]
            # round-tripping through json.dumps reproduces the exact string
            # the checksum was taken over at put time (canonical separators,
            # shortest-round-trip float repr, insertion-ordered dicts)
            if wrapper.get("sum") != self._checksum(
                    json.dumps(data, separators=(",", ":"))):
                raise ValueError("cache checksum mismatch")
            obj = data
        except FileNotFoundError:
            self.misses += 1
            return None
        except (OSError, ValueError, KeyError):
            try:  # corrupt or stale: discard so it cannot strike twice
                os.unlink(path)
            except OSError:
                pass
            self.repairs += 1
            faults.note("cache-repair", key=key)
            self.misses += 1
            return None
        try:
            os.utime(path)  # recency for the LRU sweep
        except OSError:
            pass
        self._mem[key] = obj
        self.hits += 1
        return obj

    def put(self, key: str, obj) -> None:
        """Atomically persist ``obj`` under ``key`` (temp file + rename:
        concurrent writers race benignly — last rename wins, both valid).
        The temp file is fsynced before the rename and the payload carries a
        checksum, so a crash mid-write leaves either the old entry or a blob
        ``get`` detects as corrupt — never a silently wrong schedule."""
        data_str = json.dumps(obj, separators=(",", ":"))
        payload = ('{"salt":%s,"sum":%s,"data":%s}'
                   % (json.dumps(self.salt),
                      json.dumps(self._checksum(data_str)), data_str))
        torn = faults.should_fire("cache_corrupt", key="put:" + key)
        if torn:
            # emulate a writer that died mid-write (no fsync/rename
            # discipline): a truncated blob lands under the final name and
            # the in-memory layer never saw the object
            payload = payload[:max(1, (2 * len(payload)) // 3)]
        else:
            self._mem[key] = obj
        path = self._path(key)
        try:
            os.makedirs(os.path.dirname(path), exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                       prefix=".tmp-", suffix=".json")
            try:
                with os.fdopen(fd, "w") as f:
                    f.write(payload)
                    f.flush()
                    os.fsync(f.fileno())
                os.replace(tmp, path)
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
            self.puts += 1
            if self.puts % 32 == 0:  # amortized sweep
                self._evict()
        except OSError:
            pass  # read-only disk etc.: in-memory layer still serves

    # ------------------------------------------------------------------
    def _entries(self) -> list[tuple[float, int, str]]:
        out = []
        try:
            shards = os.scandir(self.root)
        except OSError:
            return out
        for shard in shards:
            if not shard.is_dir():
                continue
            try:
                for e in os.scandir(shard.path):
                    if e.name.endswith(".json") and \
                            not e.name.startswith(".tmp-"):
                        try:
                            st = e.stat()
                            out.append((st.st_mtime, st.st_size, e.path))
                        except OSError:
                            pass
            except OSError:
                pass
        return out

    def _evict(self) -> None:
        """Drop oldest-mtime entries until within the entry/byte bounds."""
        entries = self._entries()
        total = sum(sz for _, sz, _ in entries)
        if len(entries) <= self.max_entries and total <= self.max_bytes:
            return
        entries.sort()  # oldest first
        while entries and (len(entries) > self.max_entries
                           or total > self.max_bytes):
            _, sz, path = entries.pop(0)
            try:
                os.unlink(path)
                self.evictions += 1
            except OSError:
                pass
            total -= sz
        self._mem.clear()  # conservatively resync with disk

    def sweep(self) -> None:
        """Force an eviction sweep now (the put path amortizes it)."""
        self._evict()

    def clear(self) -> None:
        self._mem.clear()
        for _, _, path in self._entries():
            try:
                os.unlink(path)
            except OSError:
                pass

    def stats(self) -> dict:
        entries = self._entries()
        return {"hits": self.hits, "misses": self.misses, "puts": self.puts,
                "evictions": self.evictions, "repairs": self.repairs,
                "entries": len(entries),
                "bytes": sum(sz for _, sz, _ in entries)}


# ---------------------------------------------------------------------------
# Default store resolution
# ---------------------------------------------------------------------------

_STORES: dict[str, CacheStore] = {}


def default_cache_dir() -> str:
    return os.environ.get("REPRO_HLS_CACHE_DIR") or os.path.join(
        os.path.expanduser("~"), ".cache", "repro-hls")


def cache_enabled() -> bool:
    """The kill switch: ``REPRO_HLS_CACHE=0`` disables persistence entirely
    (every compile is cold).  The test suite runs with it off except for the
    dedicated cache tests, which point ``REPRO_HLS_CACHE_DIR`` at a tmpdir."""
    return os.environ.get("REPRO_HLS_CACHE", "1").lower() not in (
        "0", "off", "false", "")


def get_store() -> Optional[CacheStore]:
    """The process-wide store for the current cache dir, or None when the
    cache is disabled.  Re-reads the env on every call so tests can redirect
    the store mid-process."""
    if not cache_enabled():
        return None
    root = default_cache_dir()
    st = _STORES.get(root)
    if st is None:
        st = _STORES[root] = CacheStore(root)
    return st

"""Typed compile-failure taxonomy (DESIGN.md §9).

Every failure the compiler can surface to a caller is a :class:`CompileError`
subclass, so `hls.compile` users can distinguish "your spec is unsatisfiable"
from "the environment misbehaved" without string-matching.  Transient faults
(worker crashes, torn cache blobs) are normally *recovered* — retried,
quarantined, or repaired — and reported through ``CompileResult.diagnostics``
rather than raised; these types cover the cases where recovery is impossible
or the caller asked for strictness.
"""
from __future__ import annotations


class CompileError(Exception):
    """Base class for all structured compilation failures."""


class ScheduleInfeasible(CompileError):
    """No feasible static schedule exists for the requested configuration.

    Also raised when conservative solver degradation leaves the II search
    without a provably feasible point — an honest failure, never a silently
    wrong schedule.
    """


class SolverTruncated(CompileError):
    """An ILP search was cut off (deadline/node cap) with no usable bound."""


class WorkerFault(CompileError):
    """A DSE pool worker failed permanently (quarantined after retries)."""


class CacheFault(CompileError):
    """The persistent cache is unusable beyond per-entry repair."""


class NestContractViolation(CompileError):
    """A layer was handed a nest shape outside what it supports.

    The shape vocabulary is `ir.nest_shape` (DESIGN.md §11): every rejection
    names a machine-readable ``code`` (e.g. ``"multi-chain"``,
    ``"imperfect-nest"``, ``"reduction"``, ``"top-level-ops"``), the layer
    that refused (``where``), and the offending task/array in ``detail`` —
    replacing the old reject-by-diagnostic-string sites in ``dataflow.py``
    and ``codegen.py`` so ``CompileResult.diagnostics`` is uniform.
    """

    def __init__(self, code: str, where: str, detail: str):
        self.code = str(code)
        self.where = str(where)
        self.detail = str(detail)
        super().__init__(f"{where}: [{code}] {detail}")

    def as_diagnostic(self) -> dict:
        return {"kind": f"{self.where}-rejection", "code": self.code,
                "detail": self.detail}


class UntraceableFunction(CompileError):
    """The JAX tracing frontend met a function it cannot lower to Program IR.

    Carries the unsupported jaxpr primitive (or structural feature) so
    callers can widen the traced function rather than string-match."""

    def __init__(self, fn_name: str, primitive: str, detail: str = ""):
        self.fn_name = str(fn_name)
        self.primitive = str(primitive)
        self.detail = str(detail)
        super().__init__(
            f"cannot trace '{self.fn_name}': unsupported {self.primitive}"
            + (f" ({self.detail})" if detail else ""))


class UnlowerableProgram(CompileError):
    """The program has no Pallas lowering (``codegen.emit_pallas``).

    Raised with the full list of structural ``reasons`` — each a
    :class:`NestContractViolation` (legacy callers may still pass strings;
    they are wrapped with code ``"legacy"``) — instead of an opaque
    downstream failure.  ``emit_pallas`` additionally records the rejection
    in ``CompileResult.diagnostics`` (kind ``codegen-unlowerable``) so the
    DSE trace shows which design points cannot become kernels.
    """

    def __init__(self, program_name: str, reasons):
        self.program_name = str(program_name)
        self.violations = [
            r if isinstance(r, NestContractViolation)
            else NestContractViolation("legacy", "codegen", str(r))
            for r in reasons]
        self.reasons = [str(r) for r in reasons]
        super().__init__(
            f"program '{self.program_name}' has no Pallas lowering: "
            + "; ".join(self.reasons))

"""Typed compile-failure taxonomy (DESIGN.md §9).

Every failure the compiler can surface to a caller is a :class:`CompileError`
subclass, so `hls.compile` users can distinguish "your spec is unsatisfiable"
from "the environment misbehaved" without string-matching.  Transient faults
(worker crashes, torn cache blobs) are normally *recovered* — retried,
quarantined, or repaired — and reported through ``CompileResult.diagnostics``
rather than raised; these types cover the cases where recovery is impossible
or the caller asked for strictness.
"""
from __future__ import annotations


class CompileError(Exception):
    """Base class for all structured compilation failures."""


class ScheduleInfeasible(CompileError):
    """No feasible static schedule exists for the requested configuration.

    Also raised when conservative solver degradation leaves the II search
    without a provably feasible point — an honest failure, never a silently
    wrong schedule.
    """


class SolverTruncated(CompileError):
    """An ILP search was cut off (deadline/node cap) with no usable bound."""


class WorkerFault(CompileError):
    """A DSE pool worker failed permanently (quarantined after retries)."""


class CacheFault(CompileError):
    """The persistent cache is unusable beyond per-entry repair."""


class UnlowerableProgram(CompileError):
    """The program has no Pallas lowering (``codegen.emit_pallas``).

    Raised with the full list of structural ``reasons`` — imperfect or
    too-deep nests, reductions (a nest reading an array it writes), multi-
    writer arrays, non-affine-separable accesses — instead of an opaque
    downstream failure.  ``emit_pallas`` additionally records the rejection
    in ``CompileResult.diagnostics`` (kind ``codegen-unlowerable``) so the
    DSE trace shows which design points cannot become kernels.
    """

    def __init__(self, program_name: str, reasons):
        self.program_name = str(program_name)
        self.reasons = [str(r) for r in reasons]
        super().__init__(
            f"program '{self.program_name}' has no Pallas lowering: "
            + "; ".join(self.reasons))

"""Typed compile-failure taxonomy (DESIGN.md §9).

Every failure the compiler can surface to a caller is a :class:`CompileError`
subclass, so `hls.compile` users can distinguish "your spec is unsatisfiable"
from "the environment misbehaved" without string-matching.  Transient faults
(worker crashes, torn cache blobs) are normally *recovered* — retried,
quarantined, or repaired — and reported through ``CompileResult.diagnostics``
rather than raised; these types cover the cases where recovery is impossible
or the caller asked for strictness.
"""
from __future__ import annotations

from dataclasses import dataclass


class CompileError(Exception):
    """Base class for all structured compilation failures."""


class ScheduleInfeasible(CompileError):
    """No feasible static schedule exists for the requested configuration.

    Also raised when conservative solver degradation leaves the II search
    without a provably feasible point — an honest failure, never a silently
    wrong schedule.
    """


class SolverTruncated(CompileError):
    """An ILP search was cut off (deadline/node cap) with no usable bound."""


class WorkerFault(CompileError):
    """A DSE pool worker failed permanently (quarantined after retries)."""


class CacheFault(CompileError):
    """The persistent cache is unusable beyond per-entry repair."""


class NestContractViolation(CompileError):
    """A layer was handed a nest shape outside what it supports.

    The shape vocabulary is `ir.nest_shape` (DESIGN.md §11): every rejection
    names a machine-readable ``code`` (e.g. ``"multi-chain"``,
    ``"imperfect-nest"``, ``"reduction"``, ``"top-level-ops"``), the layer
    that refused (``where``), and the offending task/array in ``detail`` —
    replacing the old reject-by-diagnostic-string sites in ``dataflow.py``
    and ``codegen.py`` so ``CompileResult.diagnostics`` is uniform.
    """

    def __init__(self, code: str, where: str, detail: str):
        self.code = str(code)
        self.where = str(where)
        self.detail = str(detail)
        super().__init__(f"{where}: [{code}] {detail}")

    def as_diagnostic(self) -> dict:
        return {"kind": f"{self.where}-rejection", "code": self.code,
                "detail": self.detail}


@dataclass(frozen=True)
class Diagnostic:
    """One structured static-analysis finding (DESIGN.md §12).

    The same shape as :meth:`NestContractViolation.as_diagnostic` — a
    machine-readable ``code`` (the vocabulary is ``analysis.LINT_CODES`` /
    ``analysis.VALIDATE_CODES``), the program location that triggered it
    (``where``, e.g. ``"harris/Ix[load uid=12]"``), a ``severity`` of
    ``"error"`` (the program or schedule is wrong) or ``"warning"``
    (suspicious but executable), and a human-readable ``detail``.

    Unlike :class:`NestContractViolation` a Diagnostic is a *value*, not an
    exception: linting never aborts compilation, it reports through
    ``CompileResult.diagnostics``.
    """

    code: str
    where: str
    severity: str  # "error" | "warning"
    detail: str

    def sort_key(self) -> tuple:
        """Stable severity-first ordering (errors before warnings)."""
        return (0 if self.severity == "error" else 1,
                self.code, self.where, self.detail)

    def as_dict(self, kind: str = "lint") -> dict:
        return {"kind": kind, "code": self.code, "severity": self.severity,
                "where": self.where, "detail": self.detail}

    def __str__(self) -> str:
        return f"{self.severity}[{self.code}] {self.where}: {self.detail}"


class StaticValidationError(CompileError):
    """The independent static validator (``repro.core.analysis``) proved a
    schedule violates the dependence/port/occupancy contract.

    This means a *miscompile*: the (II, theta) assignment the scheduler
    produced lets a conflicting dynamic-instance pair execute closer than
    its required delay.  Carries the full :class:`~repro.core.analysis.
    Verdict` so callers can inspect every violation witness.
    """

    def __init__(self, program_name: str, verdict):
        self.program_name = str(program_name)
        self.verdict = verdict
        probs = [d for d in verdict.diagnostics if d.severity == "error"]
        head = "; ".join(str(d) for d in probs[:3])
        more = f" (+{len(probs) - 3} more)" if len(probs) > 3 else ""
        super().__init__(
            f"schedule for '{self.program_name}' fails static validation: "
            f"{head}{more}")


class UntraceableFunction(CompileError):
    """The JAX tracing frontend met a function it cannot lower to Program IR.

    Carries the unsupported jaxpr primitive (or structural feature) so
    callers can widen the traced function rather than string-match."""

    def __init__(self, fn_name: str, primitive: str, detail: str = ""):
        self.fn_name = str(fn_name)
        self.primitive = str(primitive)
        self.detail = str(detail)
        super().__init__(
            f"cannot trace '{self.fn_name}': unsupported {self.primitive}"
            + (f" ({self.detail})" if detail else ""))


class UnlowerableProgram(CompileError):
    """The program has no Pallas lowering (``codegen.emit_pallas``).

    Raised with the full list of structural ``reasons`` — each a
    :class:`NestContractViolation` (legacy callers may still pass strings;
    they are wrapped with code ``"legacy"``) — instead of an opaque
    downstream failure.  ``emit_pallas`` additionally records the rejection
    in ``CompileResult.diagnostics`` (kind ``codegen-unlowerable``) so the
    DSE trace shows which design points cannot become kernels.
    """

    def __init__(self, program_name: str, reasons):
        self.program_name = str(program_name)
        self.violations = [
            r if isinstance(r, NestContractViolation)
            else NestContractViolation("legacy", "codegen", str(r))
            for r in reasons]
        self.reasons = [str(r) for r in reasons]
        super().__init__(
            f"program '{self.program_name}' has no Pallas lowering: "
            + "; ".join(self.reasons))

"""Reference interpreters + schedule validator.

Three oracles back the correctness story of the scheduler:

1. ``sequential_exec``  — runs the affine program in original program order
   (the semantics the schedule must preserve).
2. ``timed_exec``       — executes every dynamic op instance at its scheduled
   absolute time, with memory writes committing after wr_latency; produces
   the arrays the *hardware* would produce.
3. ``validate_schedule``— brute-force enumeration of dynamic instance pairs:
   every memory dependence must be separated by its delay, and no two
   accesses may share a (array, bank, port) in the same cycle.

Property tests assert timed_exec == sequential_exec and validate_schedule
passes on randomly generated affine programs.
"""
from __future__ import annotations

import bisect
from collections import defaultdict

import numpy as np

from .ir import ArithOp, ConstOp, LoadOp, Loop, Program, StoreOp
from .scheduler import Schedule

_FNS = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
    "div": lambda a, b: a / b,
    "min": min,
    "max": max,
    "cmp": lambda a, b: float(a > b),
    # unary: the tracing frontend emits these for softmax / decay math
    "exp": lambda a: float(np.exp(np.float64(a))),
}


def make_inputs(p: Program, seed: int = 0) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    return {name: rng.uniform(0.5, 2.0, size=a.shape).astype(np.float64)
            for name, a in p.arrays.items()}


def sequential_exec(p: Program, arrays: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    mem = {k: v.copy() for k, v in arrays.items()}

    def run(items, env):
        for it in items:
            if isinstance(it, Loop):
                for v in range(it.lb, it.ub):
                    env2 = dict(env)
                    env2[it.ivname] = v
                    run(it.body, env2)
            elif isinstance(it, ConstOp):
                env[it.result] = it.value
            elif isinstance(it, LoadOp):
                idx = tuple(e.eval(env) for e in it.index)
                env[it.result] = mem[it.array][idx]
            elif isinstance(it, StoreOp):
                idx = tuple(e.eval(env) for e in it.index)
                mem[it.array][idx] = env[it.value]
            elif isinstance(it, ArithOp):
                env[it.result] = _FNS[it.fn](*[env[a] for a in it.args])
        return env

    run(p.body, {})
    return mem


# ---------------------------------------------------------------------------
# Dynamic-instance enumeration
# ---------------------------------------------------------------------------


def _instances(p: Program, s: Schedule):
    """Yield (op, env, abs_time, seq_key) for every dynamic op instance."""

    def rec(items, env, anc):
        for pos, it in enumerate(items):
            if isinstance(it, Loop):
                for v in range(it.lb, it.ub):
                    env2 = dict(env)
                    env2[it.ivname] = v
                    yield from rec(it.body, env2, anc + [(it, v, pos)])
            else:
                # matches the dependence-ILP convention T = theta + sum(II*iv)
                t = s.theta[it.uid] + sum(s.iis[l.uid] * v for l, v, _ in anc)
                seq = tuple(x for _, v, ps in anc for x in (ps, v)) + (pos,)
                yield it, env, t, seq

    yield from rec(p.body, {}, [])


def timed_exec(p: Program, s: Schedule,
               arrays: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    mem = {k: v.copy() for k, v in arrays.items()}
    events = sorted(_instances(p, s), key=lambda e: (e[2], e[3]))
    # committed writes: (array, idx) -> list[(commit_time, value)] in commit order
    ssa: dict[tuple, float] = {}  # (ssa name, iteration env key) is implicit:
    # we store values per (op uid, env items of its ancestors) via seq key env.

    # Simpler: evaluate lazily with per-instance env dict carried in events.
    # pending[(arr, idx)] = (commit_times sorted ascending, values) — events
    # are processed in (t, seq) order and every write to one key shares the
    # array's wr_latency, so commits arrive already sorted and appends keep
    # the invariant; a read is then one bisect instead of a linear rescan
    # (the old O(writes) scan per load made large differential tests O(n^2)).
    pending: dict[tuple, tuple[list[int], list[float]]] = {}

    def read_mem(arr, idx, t):
        entry = pending.get((arr, idx))
        if entry is None:
            return mem[arr][idx]
        times, vals = entry
        k = bisect.bisect_right(times, t)
        # ties on commit time: bisect_right lands after the last equal
        # entry, so the most recently issued write wins (same rule as the
        # final-value reduction below)
        return mem[arr][idx] if k == 0 else vals[k - 1]

    # op uid -> ivnames visible at its region (for cross-region SSA lookups)
    ivscope: dict[int, tuple[str, ...]] = {}
    for node, anc in p.walk():
        ivscope[node.uid] = tuple(l.ivname for l in anc)

    values: dict[tuple[int, tuple], float] = {}

    def vkey(op_uid, env):
        return (op_uid, tuple((n, env[n]) for n in ivscope[op_uid]))

    def lookup(name, env):
        d = _def_of(p, name)
        return values[vkey(d.uid, env)]

    for op, env, t, _ in events:
        if isinstance(op, ConstOp):
            values[vkey(op.uid, env)] = op.value
        elif isinstance(op, LoadOp):
            idx = tuple(e.eval(env) for e in op.index)
            values[vkey(op.uid, env)] = read_mem(op.array, idx, t)
        elif isinstance(op, ArithOp):
            args = [lookup(a, env) for a in op.args]
            values[vkey(op.uid, env)] = _FNS[op.fn](*args)
        elif isinstance(op, StoreOp):
            idx = tuple(e.eval(env) for e in op.index)
            v = lookup(op.value, env)
            commit = t + p.arrays[op.array].wr_latency
            times, vals = pending.setdefault((op.array, idx), ([], []))
            if times and commit < times[-1]:  # defensive; see invariant above
                k = bisect.bisect_right(times, commit)
                times.insert(k, commit)
                vals.insert(k, v)
            else:
                times.append(commit)
                vals.append(v)

    for (arr, idx), (times, vals) in pending.items():
        # final value = last committed write (ties: most recently issued)
        mem[arr][idx] = vals[-1]
    return mem


def _def_of(p: Program, name: str):
    # cache lives on the Program instance (id()-keyed caches are unsound:
    # CPython reuses addresses after GC)
    cache = getattr(p, "_def_cache", None)
    if cache is None:
        cache = {}
        for node, _ in p.walk():
            if not isinstance(node, Loop) and node.result is not None:
                cache[node.result] = node
        p._def_cache = cache
    return cache[name]


# ---------------------------------------------------------------------------
# Brute-force validator
# ---------------------------------------------------------------------------


def validate_schedule(p: Program, s: Schedule) -> list[str]:
    """Return a list of violations (empty = valid).  Exponential in program
    size — use on small/reduced programs (tests) only."""
    violations = []
    mem_events = []  # (array, idx, is_write, t, seq, port, uid)
    for op, env, t, seq in _instances(p, s):
        if isinstance(op, (LoadOp, StoreOp)):
            idx = tuple(e.eval(env) for e in op.index)
            mem_events.append((op.array, idx, isinstance(op, StoreOp), t, seq,
                               op.port, op.uid))

    by_addr = defaultdict(list)
    for ev in mem_events:
        by_addr[(ev[0], ev[1])].append(ev)
    for key, evs in by_addr.items():
        evs.sort(key=lambda e: e[4])  # sequential order
        for i in range(len(evs)):
            for j in range(i + 1, len(evs)):
                a, b = evs[i], evs[j]
                if not (a[2] or b[2]):
                    continue
                arr = p.arrays[a[0]]
                if a[2] and not b[2]:
                    delay = arr.wr_latency  # RAW
                else:
                    delay = 1  # WAR / WAW
                if b[3] < a[3] + delay:
                    violations.append(
                        f"dep violation {key}: seq-earlier t={a[3]} "
                        f"(write={a[2]}) vs later t={b[3]} (write={b[2]})")

    # port conflicts: same (array, bank, port) in the same cycle
    by_cycle = defaultdict(list)
    for arr_name, idx, is_w, t, seq, port, uid in mem_events:
        arr = p.arrays[arr_name]
        if arr.kind == "reg":
            continue
        bank = tuple(idx[d] for d in arr.partition)
        by_cycle[(arr_name, bank, port, t)].append(uid)
    for key, uids in by_cycle.items():
        if len(uids) > 1:
            violations.append(f"port conflict on {key[0]} bank={key[1]} "
                              f"port={key[2]} cycle={key[3]}: ops {uids}")
    return violations

"""Pipeline-parallel schedule synthesis via the paper's ILP scheduler.

The mapping (DESIGN.md §3): a pipeline-parallel training step IS a dataflow
program —

    FPGA loop nest            <->  per-stage microbatch loop
    intermediate array        <->  ACT[stage][microbatch] / GRAD[...]
    memory port conflict      <->  a device executes one stage-op per tick
    intra-loop II             <->  steady-state ticks per microbatch
    producer-consumer overlap <->  fwd/bwd interleaving + cross-stage overlap

Each stage contributes ONE loop over microbatches whose body holds both the
forward and (optionally) backward op for that (stage, microbatch); a
single-port per-device "DEV_s" array serializes same-device ops exactly like
a BRAM port.  The ILP then *derives* a 1F1B-class schedule (affine in m)
instead of hard-coding one, and handles non-SPSC stage graphs — e.g. an
encoder output consumed by every decoder stage's cross-attention — which is
precisely the pattern Vitis-style FIFO dataflow cannot express (§2).

The executor in repro/parallel/pipeline.py realizes the derived schedule with
shard_map + lax.ppermute.
"""
from __future__ import annotations

from dataclasses import dataclass

from .autotune import compile_program
from .ir import ProgramBuilder


@dataclass
class PipelineSchedule:
    n_stages: int
    n_microbatches: int
    fwd_start: list[int]        # theta of fwd op per stage (ticks)
    bwd_start: list[int]        # theta of bwd op per stage (empty if fwd-only)
    ii: int                     # steady-state ticks per microbatch
    latency: int                # makespan in ticks
    peak_live_activations: int  # max simultaneously-live ACT[s][m]

    def fwd_tick(self, s: int, m: int) -> int:
        return self.fwd_start[s] + m * self.ii

    def bwd_tick(self, s: int, m: int) -> int:
        return self.bwd_start[s] + m * self.ii


def _peak_live(intervals) -> int:
    """Max overlap of live [born, dies] activation intervals (dies inclusive),
    by event-sweep: +1 at birth, -1 just after death."""
    events = []
    for born, dies in intervals:
        events.append((born, 1))
        events.append((dies + 1, -1))
    live = peak = 0
    for _, d in sorted(events):
        live += d
        peak = max(peak, live)
    return peak


def _build_program(S: int, M: int, t_f: int, t_b: int, backward: bool,
                   cross_from=None):
    """One loop over microbatches; the body is the topologically-ordered
    dataflow of one microbatch (full forward chain, then full backward
    chain), so the sequential semantics the scheduler must preserve are the
    true dependences.  ``cross_from``: stage index whose output every later
    stage also consumes (encoder output -> decoder cross-attention): a
    multi-consumer channel that FIFO dataflow cannot express."""
    b = ProgramBuilder("pp", op_delays={"add": 0, "mul": 1, "div": 1,
                                        "sub": 1, "const": 0})
    for s in range(S + 1):
        b.array(f"ACT{s}", (M,), kind="reg", rd_latency=0, wr_latency=1)
        if backward:
            b.array(f"GRAD{s}", (M,), kind="reg", rd_latency=0, wr_latency=1)
    for s in range(S):
        # one single-port scratchpad per device: the execution-slot resource
        b.array(f"DEV{s}", (1,), ports=("rw",), rd_latency=1, wr_latency=1)

    def occupy(s, val, ticks, fn):
        """fn-tagged chain of `ticks` unit ops, each claiming DEV_s for one
        tick (a t-tick stage op keeps its device busy t ticks)."""
        for _ in range(ticks):
            val = b.arith(fn, val, b.const(1.0))
            b.store(f"DEV{s}", val, 0)
        return val

    with b.loop("m", 0, M) as m:
        for s in range(S):
            x = b.load(f"ACT{s}", m)
            if cross_from is not None and s > cross_from:
                e = b.load(f"ACT{cross_from + 1}", m)
                x = b.add(x, e)
            y = occupy(s, x, t_f, "mul")        # fwd compute, t_f ticks
            b.store(f"ACT{s + 1}", y, m)
        if backward:
            # loss gradient ties bwd to fwd (dependency only — folded into
            # the last stage's bwd op, so it claims no device tick)
            g = b.arith("sub", b.load(f"ACT{S}", m), b.const(0.0))
            b.store(f"GRAD{S}", g, m)
            for s in range(S - 1, -1, -1):
                g = b.load(f"GRAD{s + 1}", m)
                a = b.load(f"ACT{s}", m)        # stashed activation
                gg = occupy(s, b.add(g, a), t_b, "div")  # bwd, t_b ticks
                b.store(f"GRAD{s}", gg, m)
    return b.build()


def synthesize(S: int, M: int, *, t_f: int = 1, t_b: int = 2,
               backward: bool = True, cross_from=None) -> PipelineSchedule:
    p = _build_program(S, M, t_f, t_b, backward, cross_from)
    sched = compile_program(p)
    loops = p.loops()
    ii = max(sched.iis[l.uid] for l in loops)

    # locate fwd (mul) and bwd (div) ops per stage, in emission order
    from .ir import ArithOp, Loop

    fwd_start, bwd_start = [], []
    body = [n for n in p.body if isinstance(n, Loop)][0].body
    muls = [sched.theta[op.uid] for op in body
            if isinstance(op, ArithOp) and op.fn == "mul"]
    divs = [sched.theta[op.uid] for op in body
            if isinstance(op, ArithOp) and op.fn == "div"]
    fwd_start = [muls[i * t_f] for i in range(S)]  # first unit of each chain
    if backward:
        bwd_start = [divs[i * t_b] for i in range(S)]
        bwd_start.reverse()  # emitted S-1..0, report as 0..S-1

    # peak live ACT values (activation-memory pressure, the 1F1B metric):
    # ACT[s][m] is born at stage s's fwd and dies at its own bwd (stashed
    # activation), or at the next stage's fwd when there is no backward.
    intervals = []
    for s in range(S):
        for m in range(M):
            born = fwd_start[s] + m * ii
            if backward:
                dies = bwd_start[s] + m * ii
            else:
                dies = (fwd_start[s + 1] + m * ii) if s + 1 < S else born + 1
            intervals.append((born, dies))
    peak = _peak_live(intervals)

    return PipelineSchedule(
        n_stages=S, n_microbatches=M, fwd_start=fwd_start,
        bwd_start=bwd_start, ii=ii, latency=sched.completion_time(),
        peak_live_activations=peak)


def synthesize_interleaved(S: int, V: int, M: int, *, t_f: int = 1,
                           t_b: int = 2) -> PipelineSchedule:
    """Interleaved (virtual-stage) pipeline: each device hosts V model chunks
    (chunk c runs on device c % S, megatron-style).  The SAME device-port
    machinery schedules it — the only change is the DEV index mapping — and
    the ILP discovers the shorter fill/drain that interleaving buys."""
    b = ProgramBuilder("ppi", op_delays={"add": 0, "mul": 1, "div": 1,
                                         "sub": 1, "const": 0})
    C = S * V
    for c in range(C + 1):
        b.array(f"ACT{c}", (M,), kind="reg", rd_latency=0, wr_latency=1)
        b.array(f"GRAD{c}", (M,), kind="reg", rd_latency=0, wr_latency=1)
    for s in range(S):
        b.array(f"DEV{s}", (1,), ports=("rw",), rd_latency=1, wr_latency=1)

    def occupy(dev, val, ticks, fn):
        for _ in range(ticks):
            val = b.arith(fn, val, b.const(1.0))
            b.store(f"DEV{dev}", val, 0)
        return val

    with b.loop("m", 0, M) as m:
        for c in range(C):
            x = b.load(f"ACT{c}", m)
            y = occupy(c % S, x, t_f, "mul")
            b.store(f"ACT{c + 1}", y, m)
        g = b.arith("sub", b.load(f"ACT{C}", m), b.const(0.0))
        b.store(f"GRAD{C}", g, m)
        for c in range(C - 1, -1, -1):
            g = b.load(f"GRAD{c + 1}", m)
            a = b.load(f"ACT{c}", m)
            gg = occupy(c % S, b.add(g, a), t_b, "div")
            b.store(f"GRAD{c}", gg, m)
    p = b.build()
    sched = compile_program(p)
    loop = p.loops()[0]
    ii = sched.iis[loop.uid]

    from .ir import ArithOp

    muls = [sched.theta[op.uid] for op in loop.body
            if isinstance(op, ArithOp) and op.fn == "mul"]
    divs = [sched.theta[op.uid] for op in loop.body
            if isinstance(op, ArithOp) and op.fn == "div"]
    fwd_start = [muls[c * t_f] for c in range(C)]
    bwd_start = list(reversed([divs[i * t_b] for i in range(C)]))
    peak = _peak_live((fwd_start[c] + m_ * ii, bwd_start[c] + m_ * ii)
                      for c in range(C) for m_ in range(M))
    return PipelineSchedule(
        n_stages=C, n_microbatches=M, fwd_start=fwd_start,
        bwd_start=bwd_start, ii=ii, latency=sched.completion_time(),
        peak_live_activations=peak)


def gpipe_latency(S: int, M: int, t_f: int = 1, t_b: int = 2) -> int:
    """All-forward-then-all-backward with stage pipelining (the runtime-
    synchronized baseline): fwd fill+steady + bwd fill+steady."""
    return (M + S - 1) * t_f + (M + S - 1) * t_b


def sequential_latency(S: int, M: int, t_f: int = 1, t_b: int = 2) -> int:
    return M * S * (t_f + t_b)

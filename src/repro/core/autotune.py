"""The paper's auto-tuner (§3.1) + the resource-aware DSE driver.

Auto-tuner: binary search for the smallest feasible II of every loop that
lacks a programmer-specified ``pipeline`` II.

Feasibility of an II assignment = the scheduling system admits a solution
(Bellman-Ford finds no positive cycle) and loop-counter occupancy holds.
Loops are tuned innermost-first.  Each probe is incremental (DESIGN.md §5):
DepAnalysis enumerated the conflicting pairs once and caches each pair's
edge on the IIs of the loops in its iteration vectors, so a probe that
moves one loop's II only re-solves the dependences touching that loop —
and those via the closed-form fast path, not branch-and-bound.

DSE (``explore``, DESIGN.md §6): the scheduler finds the best schedule for
a *fixed* program, but the paper's headline wins depend on program shape.
``explore(p, budget)`` searches semantics-preserving transform pipelines
(fuse / partition / unroll / tile from ``transforms``), compiles every
candidate through the incremental scheduler, and returns the minimum-latency
schedule whose ``resources()`` stay under the budget — turning the repo from
"schedule one program" into "compile a workload".
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

from .deps import DepAnalysis
from .ir import Loop, Program
from .scheduler import Schedule, check_loop_occupancy, feasible, schedule
from .transforms import (ArrayPartition, FuseProducerConsumer, LoopTile,
                         LoopUnroll, Pass, PassManager)


def _loops_with_depth(p: Program) -> list[tuple[Loop, int]]:
    return [(n, len(anc)) for n, anc in p.walk() if isinstance(n, Loop)]


def _seq_ii_bound(p: Program, loop: Loop) -> int:
    """A conservative (sequential-execution) II upper bound, bottom-up."""
    total = 1
    for item in loop.body:
        if isinstance(item, Loop):
            total += item.trip * _seq_ii_bound(p, item)
        else:
            total += p.op_latency(item)
    return total


def _occupancy_floor(loop: Loop, iis: dict[int, int]) -> int:
    lo = 1
    for item in loop.body:
        if isinstance(item, Loop):
            lo = max(lo, item.trip * iis[item.uid])
    return lo


def autotune(p: Program, dep: Optional[DepAnalysis] = None,
             verbose: bool = False) -> dict[int, int]:
    """Return loop uid -> II (programmer-specified IIs respected)."""
    dep = dep or DepAnalysis(p)
    loops = _loops_with_depth(p)
    iis: dict[int, int] = {}
    tunable: list[Loop] = []
    for loop, _ in loops:
        if loop.ii is not None:
            iis[loop.uid] = loop.ii
        else:
            iis[loop.uid] = _seq_ii_bound(p, loop)
            tunable.append(loop)

    # innermost-first (deepest), then program order
    depth = {l.uid: d for l, d in loops}
    tunable.sort(key=lambda l: -depth[l.uid])

    for loop in tunable:
        lo = _occupancy_floor(loop, iis)
        hi = max(lo, iis[loop.uid])

        def probe(ii: int) -> bool:
            iis[loop.uid] = ii
            return feasible(p, iis, dep)

        # ensure hi feasible (double if the conservative bound still fails,
        # e.g. due to cross-nest port serialization pressure)
        guard = 0
        while not probe(hi) and guard < 8:
            hi *= 2
            guard += 1
        best = hi
        while lo <= hi:
            mid = (lo + hi) // 2
            if probe(mid):
                best = mid
                hi = mid - 1
            else:
                lo = mid + 1
        iis[loop.uid] = best
        if verbose:
            print(f"  autotune: loop {loop.ivname} II={best}")

    assert check_loop_occupancy(p, iis)
    assert feasible(p, iis, dep), "autotuned IIs must be feasible"
    return iis


def compile_program(p: Program, verbose: bool = False) -> Schedule:
    """Full pipeline: dependence analysis -> II autotune -> scheduling ILP."""
    dep = DepAnalysis(p)
    iis = autotune(p, dep, verbose=verbose)
    s = schedule(p, iis, dep)
    assert s.feasible
    return s


# ---------------------------------------------------------------------------
# Resource-aware design-space exploration (DESIGN.md §6)
# ---------------------------------------------------------------------------


@dataclass
class DSECandidate:
    """One explored point: a transform pipeline + its compiled schedule."""

    desc: str                     # human-readable pipeline description
    passes: tuple[Pass, ...]
    program: Program
    schedule: Schedule
    latency: int
    res: dict[str, float]         # resources(program, schedule, "ours")
    within_budget: bool


@dataclass
class DSEResult:
    baseline: DSECandidate
    best: DSECandidate
    candidates: list[DSECandidate] = field(default_factory=list)
    budget: dict[str, float] = field(default_factory=dict)

    @property
    def speedup(self) -> float:
        return self.baseline.latency / self.best.latency

    def table(self) -> list[tuple[str, int, float, float, bool]]:
        """(desc, latency, bram_bytes, dsp, within_budget) rows, best first."""
        rows = [(c.desc, c.latency, c.res["bram_bytes"], c.res["dsp"],
                 c.within_budget) for c in self.candidates]
        rows.sort(key=lambda r: (not r[4], r[1], r[2], r[3]))
        return rows


def _budget_key(res: dict[str, float], budget: dict[str, float]) -> bool:
    return all(res.get(k, 0.0) <= v + 1e-9 for k, v in budget.items())


def _unroll_factors_for(p: Program, factors: Sequence[int]) -> list[int]:
    """Factors that partially unroll at least one innermost loop."""
    out = []
    inner = [l for l in p.loops()
             if not any(isinstance(ch, Loop) for ch in l.body)]
    for f in factors:
        if any(l.trip % f == 0 and l.trip // f >= 1 and not l.unroll
               for l in inner):
            out.append(f)
    return out


def _tile_moves(p: Program, sizes: Sequence[int]) -> list[LoopTile]:
    """One tiling move per size, strip-mining every top-level loop it
    divides (order-preserving, so always legal)."""
    moves = []
    tops = [it for it in p.body if isinstance(it, Loop)]
    for s in sizes:
        cfg = {l.ivname: s for l in tops if l.trip % s == 0 and l.trip // s >= 2}
        if cfg:
            moves.append(LoopTile(cfg))
    return moves


def explore(p: Program, budget: Optional[dict[str, float]] = None, *,
            unroll_factors: Sequence[int] = (2, 4),
            tile_sizes: Sequence[int] = (4,),
            max_candidates: int = 24,
            verify: bool = True,
            validate: bool = False,
            seeds: Sequence[int] = (0,),
            verbose: bool = False) -> DSEResult:
    """Resource-aware DSE over transform pipelines.

    ``budget`` maps resource names (keys of ``dataflow.resources``:
    ``bram_bytes`` / ``dsp`` / ``ff_bits`` / ``lut``) to ceilings; missing
    keys are unconstrained (unknown keys raise).  ``budget=None`` means
    *iso-resource*: the baseline program's own BRAM and DSP become the
    ceiling, so any winner is faster at equal-or-lower memory/datapath
    cost.  If NO candidate (baseline included) fits the budget, the overall
    min-latency candidate is returned with ``within_budget=False`` — check
    the flag when passing a tight explicit budget.

    Every candidate pipeline is verified by differential execution
    (``verify=True``, PassManager contract) before it is compiled; with
    ``validate=True`` the winner's schedule additionally passes the
    brute-force ``validate_schedule``/``timed_exec`` oracles (small
    programs only — it enumerates dynamic instances).

    Search: every single move, then greedy composition on top of the best
    within-budget candidate, bounded by ``max_candidates`` compilations.
    """
    from .dataflow import resources

    def measure(desc: str, passes: Sequence[Pass],
                base: Optional[Program] = None,
                base_passes: Sequence[Pass] = ()) -> Optional[DSECandidate]:
        """Apply ``passes`` on top of ``base`` (an already-verified
        intermediate, default the original program) so greedy composition
        does not re-apply and re-verify the whole frontier prefix —
        equivalence to ``p`` is transitive through the verified base."""
        start = base if base is not None else p
        pm = PassManager(passes, verify=verify, seeds=seeds)
        q = pm.run(start)
        if passes and (q is start or not pm.reports[-1].changed):
            # the pipeline (or its newest move) applied nothing: the result
            # is identical to an already-measured candidate — don't compile
            # it again or record a duplicate under a longer desc
            return None
        s = compile_program(q)
        res = resources(q, s, "ours")
        return DSECandidate(
            desc=desc or "baseline", passes=tuple(base_passes) + tuple(passes),
            program=q, schedule=s, latency=s.completion_time(), res=res,
            within_budget=True)

    baseline = measure("baseline", [])
    if budget is None:
        budget = {"bram_bytes": baseline.res["bram_bytes"],
                  "dsp": baseline.res["dsp"]}
    budget = dict(budget)
    unknown = set(budget) - set(baseline.res)
    if unknown:
        raise ValueError(
            f"unknown budget resource(s) {sorted(unknown)}; "
            f"valid keys: {sorted(baseline.res)}")
    baseline.within_budget = _budget_key(baseline.res, budget)

    moves: list[tuple[str, Pass]] = [
        # shift-and-peel fusion (mismatched bounds fuse too) plus the
        # equal-bounds-only variant: peeling trades prologue nests for core
        # overlap, which is not always the latency winner — enumerate both
        ("fuse", FuseProducerConsumer()),
        ("fuse(noshift)", FuseProducerConsumer(enable_shift=False)),
        ("partition", ArrayPartition()),
    ]
    moves += [(f"unroll(x{f})", LoopUnroll(f))
              for f in _unroll_factors_for(p, unroll_factors)]
    moves += [(t.name, t) for t in _tile_moves(p, tile_sizes)]

    candidates: list[DSECandidate] = [baseline]
    seen_descs = {"baseline"}
    compiles = 1

    def try_pipeline(descs: list[str], passes: list[Pass],
                     base: Optional[Program] = None,
                     base_passes: Sequence[Pass] = ()) -> Optional[DSECandidate]:
        nonlocal compiles
        desc = " | ".join(descs)
        if desc in seen_descs or compiles >= max_candidates:
            return None
        seen_descs.add(desc)
        c = measure(desc, passes, base=base, base_passes=base_passes)
        if c is not None:
            compiles += 1  # only actual compilations count against the cap
            c.within_budget = _budget_key(c.res, budget)
            candidates.append(c)
            if verbose:
                print(f"  dse: {desc}: latency={c.latency} res={c.res} "
                      f"{'OK' if c.within_budget else 'OVER-BUDGET'}")
        return c

    # level 1: every single move
    for desc, mv in moves:
        try_pipeline([desc], [mv])

    # greedy composition: extend the best within-budget pipeline so far
    def best_of(cands):
        ok = [c for c in cands if c.within_budget]
        pool = ok or cands
        return min(pool, key=lambda c: (c.latency, c.res["bram_bytes"],
                                        c.res["dsp"], c.res["ff_bits"]))

    frontier = best_of(candidates)
    while compiles < max_candidates:
        base_descs = frontier.desc.split(" | ") if frontier.passes else []
        # tile moves are re-derived from the frontier program: fusion renames
        # loops, so tiling the *fused* nest (the knob the Pallas kernel layer
        # reads as its block size) is only reachable this way
        level_moves = moves + [
            (t.name, t) for t in _tile_moves(frontier.program, tile_sizes)
            if t.name not in {d for d, _ in moves}]
        for desc, mv in level_moves:
            if desc not in base_descs:
                try_pipeline(base_descs + [desc], [mv],
                             base=frontier.program,
                             base_passes=frontier.passes)
        nxt = best_of(candidates)
        if nxt is frontier:
            break
        frontier = nxt

    best = best_of(candidates)
    if validate:
        # explicit raises (not bare asserts): these oracles must survive -O
        from .sim import (make_inputs, sequential_exec, timed_exec,
                          validate_schedule)
        violations = validate_schedule(best.program, best.schedule)
        if violations:
            raise AssertionError(
                f"DSE winner '{best.desc}' fails validate_schedule: "
                f"{violations[:5]}")
        import numpy as np
        inp = make_inputs(best.program, seeds[0])
        got = timed_exec(best.program, best.schedule, inp)
        want = sequential_exec(best.program, inp)
        for k in want:
            if not np.allclose(got[k], want[k], rtol=1e-12, atol=0):
                raise AssertionError(
                    f"DSE winner '{best.desc}': timed_exec differs from "
                    f"sequential_exec on array {k}")
    return DSEResult(baseline=baseline, best=best, candidates=candidates,
                     budget=budget)

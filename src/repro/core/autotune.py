"""The paper's auto-tuner (§3.1): binary search for the smallest feasible II
of every loop that lacks a programmer-specified ``pipeline`` II.

Feasibility of an II assignment = the scheduling system admits a solution
(Bellman-Ford finds no positive cycle) and loop-counter occupancy holds.
Loops are tuned innermost-first.  Each probe is incremental (DESIGN.md §5):
DepAnalysis enumerated the conflicting pairs once and caches each pair's
edge on the IIs of the loops in its iteration vectors, so a probe that
moves one loop's II only re-solves the dependences touching that loop —
and those via the closed-form fast path, not branch-and-bound.
"""
from __future__ import annotations

from typing import Optional

from .deps import DepAnalysis
from .ir import Loop, Program
from .scheduler import Schedule, check_loop_occupancy, feasible, schedule


def _loops_with_depth(p: Program) -> list[tuple[Loop, int]]:
    return [(n, len(anc)) for n, anc in p.walk() if isinstance(n, Loop)]


def _seq_ii_bound(p: Program, loop: Loop) -> int:
    """A conservative (sequential-execution) II upper bound, bottom-up."""
    total = 1
    for item in loop.body:
        if isinstance(item, Loop):
            total += item.trip * _seq_ii_bound(p, item)
        else:
            total += p.op_latency(item)
    return total


def _occupancy_floor(loop: Loop, iis: dict[int, int]) -> int:
    lo = 1
    for item in loop.body:
        if isinstance(item, Loop):
            lo = max(lo, item.trip * iis[item.uid])
    return lo


def autotune(p: Program, dep: Optional[DepAnalysis] = None,
             verbose: bool = False) -> dict[int, int]:
    """Return loop uid -> II (programmer-specified IIs respected)."""
    dep = dep or DepAnalysis(p)
    loops = _loops_with_depth(p)
    iis: dict[int, int] = {}
    tunable: list[Loop] = []
    for loop, _ in loops:
        if loop.ii is not None:
            iis[loop.uid] = loop.ii
        else:
            iis[loop.uid] = _seq_ii_bound(p, loop)
            tunable.append(loop)

    # innermost-first (deepest), then program order
    depth = {l.uid: d for l, d in loops}
    tunable.sort(key=lambda l: -depth[l.uid])

    for loop in tunable:
        lo = _occupancy_floor(loop, iis)
        hi = max(lo, iis[loop.uid])

        def probe(ii: int) -> bool:
            iis[loop.uid] = ii
            return feasible(p, iis, dep)

        # ensure hi feasible (double if the conservative bound still fails,
        # e.g. due to cross-nest port serialization pressure)
        guard = 0
        while not probe(hi) and guard < 8:
            hi *= 2
            guard += 1
        best = hi
        while lo <= hi:
            mid = (lo + hi) // 2
            if probe(mid):
                best = mid
                hi = mid - 1
            else:
                lo = mid + 1
        iis[loop.uid] = best
        if verbose:
            print(f"  autotune: loop {loop.ivname} II={best}")

    assert check_loop_occupancy(p, iis)
    assert feasible(p, iis, dep), "autotuned IIs must be feasible"
    return iis


def compile_program(p: Program, verbose: bool = False) -> Schedule:
    """Full pipeline: dependence analysis -> II autotune -> scheduling ILP."""
    dep = DepAnalysis(p)
    iis = autotune(p, dep, verbose=verbose)
    s = schedule(p, iis, dep)
    assert s.feasible
    return s

"""The paper's auto-tuner (§3.1) + the resource-aware DSE driver.

Auto-tuner: binary search for the smallest feasible II of every loop that
lacks a programmer-specified ``pipeline`` II.

Feasibility of an II assignment = the scheduling system admits a solution
(Bellman-Ford finds no positive cycle) and loop-counter occupancy holds.
Loops are tuned innermost-first.  Each probe is incremental (DESIGN.md §5):
DepAnalysis enumerated the conflicting pairs once and caches each pair's
edge on the IIs of the loops in its iteration vectors, so a probe that
moves one loop's II only re-solves the dependences touching that loop —
and those via the closed-form fast path, not branch-and-bound.

DSE (``pareto_explore``, DESIGN.md §6): the scheduler finds the best
schedule for a *fixed* program, but the paper's headline wins depend on
program shape.  The search layer explores semantics-preserving transform
pipelines (fuse / partition / unroll / tile from ``transforms``), compiles
every candidate through the incremental scheduler, and maintains a
dominance-pruned archive over the objective space (latency, BRAM, DSP, FF)
— the Fig. 9 trade-off curve — expanded frontier-first rather than by
single-best hill climbing.  The declarative entry point is
``repro.core.hls.compile`` (api.py); ``explore``/``compile_program`` live
on as deprecated shims there.
"""
from __future__ import annotations

import heapq
import time
from dataclasses import dataclass, field
from typing import Optional, Sequence, Union

from . import faults
from .cache import (CacheStore, fingerprint, get_store, pack_schedule,
                    unpack_schedule)
from .deps import DepAnalysis
from .errors import CompileError, ScheduleInfeasible
from .ir import Loop, Program
from .scheduler import Schedule, check_loop_occupancy, feasible, schedule
from .transforms import (ArrayPartition, FuseProducerConsumer, LoopTile,
                         LoopUnroll, Pass, PassManager, TransformError)


def _loops_with_depth(p: Program) -> list[tuple[Loop, int]]:
    return [(n, len(anc)) for n, anc in p.walk() if isinstance(n, Loop)]


def _seq_ii_bound(p: Program, loop: Loop) -> int:
    """A conservative (sequential-execution) II upper bound, bottom-up."""
    total = 1
    for item in loop.body:
        if isinstance(item, Loop):
            total += item.trip * _seq_ii_bound(p, item)
        else:
            total += p.op_latency(item)
    return total


def _occupancy_floor(loop: Loop, iis: dict[int, int]) -> int:
    lo = 1
    for item in loop.body:
        if isinstance(item, Loop):
            lo = max(lo, item.trip * iis[item.uid])
    return lo


def autotune(p: Program, dep: Optional[DepAnalysis] = None,
             verbose: bool = False) -> dict[int, int]:
    """Return loop uid -> II (programmer-specified IIs respected)."""
    dep = dep or DepAnalysis(p)
    loops = _loops_with_depth(p)
    iis: dict[int, int] = {}
    tunable: list[Loop] = []
    for loop, _ in loops:
        if loop.ii is not None:
            iis[loop.uid] = loop.ii
        else:
            iis[loop.uid] = _seq_ii_bound(p, loop)
            tunable.append(loop)

    # innermost-first (deepest), then program order
    depth = {l.uid: d for l, d in loops}
    tunable.sort(key=lambda l: -depth[l.uid])

    for loop in tunable:
        lo = _occupancy_floor(loop, iis)
        hi = max(lo, iis[loop.uid])

        def probe(ii: int) -> bool:
            iis[loop.uid] = ii
            return feasible(p, iis, dep)

        # ensure hi feasible (double if the conservative bound still fails,
        # e.g. due to cross-nest port serialization pressure)
        guard = 0
        while not probe(hi) and guard < 8:
            hi *= 2
            guard += 1
        best = hi
        while lo <= hi:
            mid = (lo + hi) // 2
            if probe(mid):
                best = mid
                hi = mid - 1
            else:
                lo = mid + 1
        iis[loop.uid] = best
        if verbose:
            print(f"  autotune: loop {loop.ivname} II={best}")

    if not check_loop_occupancy(p, iis) or not feasible(p, iis, dep):
        # unreachable on an exact analysis (the binary search only accepts
        # feasible probes); conservative degraded dependence bounds can in
        # principle leave no feasible II — fail honestly, never return a
        # schedule that was not proven feasible
        raise ScheduleInfeasible(
            "autotuned IIs are not feasible"
            + (" (degraded dependence bounds)" if getattr(
                dep, "degradations", None) else ""))
    return iis


def compile_program(p: Program, verbose: bool = False) -> Schedule:
    """Full pipeline: dependence analysis -> II autotune -> scheduling ILP."""
    dep = DepAnalysis(p)
    iis = autotune(p, dep, verbose=verbose)
    s = schedule(p, iis, dep)
    if not s.feasible:
        raise ScheduleInfeasible("scheduling failed for autotuned IIs")
    return s


# ---------------------------------------------------------------------------
# Design-space exploration (DESIGN.md §6): candidates + objective space
# ---------------------------------------------------------------------------

# The objective space of the Pareto search: scheduled latency plus the
# Fig. 9 resource axes the paper trades it against.
PARETO_METRICS = ("latency", "bram_bytes", "dsp", "ff_bits")


@dataclass
class DSECandidate:
    """One explored design point: a transform pipeline + its compiled
    schedule, resource vector and search status.  (Exported from the
    declarative front end as ``hls.DesignPoint``.)"""

    desc: str                     # human-readable pipeline description
    passes: tuple[Pass, ...]
    program: Program
    schedule: Schedule
    latency: int
    res: dict[str, float]         # dataflow.resources(program, schedule, mode)
    within_budget: bool
    status: str = ""              # "baseline" | "frontier" | "dominated by
    #                               <desc>" | "over budget: <violations>"
    cached: bool = False          # rehydrated from the persistent cache
    # "degraded" when a truncated solver forced conservative bounds anywhere
    # in this candidate's transform legality checks or schedule (DESIGN.md §9)
    provenance: str = "exact"
    diags: tuple = field(default=(), repr=False, compare=False)
    _obj: Optional[tuple] = field(default=None, repr=False, compare=False)

    def metric(self, key: str) -> float:
        return float(self.latency) if key == "latency" else float(self.res[key])

    def objectives(self, keys: Sequence[str] = PARETO_METRICS) -> tuple:
        # latency/res are fixed at construction, so the default objective
        # tuple is computed once — every archive dominance check used to
        # recompute it (part of the O(n^2 log n) requeue hot spot)
        if keys is PARETO_METRICS:
            if self._obj is None:
                self._obj = tuple(self.metric(k) for k in keys)
            return self._obj
        return tuple(self.metric(k) for k in keys)


def dominates(u: Sequence[float], v: Sequence[float],
              tol: float = 1e-9) -> bool:
    """Pareto dominance: <= on every axis, < on at least one."""
    return all(a <= b + tol for a, b in zip(u, v)) and \
        any(a < b - tol for a, b in zip(u, v))


@dataclass
class DSEResult:
    """Legacy result shape of the deprecated ``explore`` shim (the
    declarative path returns ``hls.CompileResult``).  ``frontier`` and
    ``rejections`` are populated by the Pareto engine underneath."""

    baseline: DSECandidate
    best: DSECandidate
    candidates: list[DSECandidate] = field(default_factory=list)
    budget: dict[str, float] = field(default_factory=dict)
    frontier: list[DSECandidate] = field(default_factory=list)
    rejections: list[tuple[str, str]] = field(default_factory=list)

    @property
    def speedup(self) -> float:
        """baseline latency / best latency; 1.0 for degenerate (zero-cycle)
        baselines so an empty or fully-rejected search never divides by
        zero — check ``rejections`` / ``explain()`` for why."""
        if self.best.latency <= 0 or self.baseline.latency <= 0:
            return 1.0
        return self.baseline.latency / self.best.latency

    def table(self) -> list[tuple[str, int, float, float, bool]]:
        """(desc, latency, bram_bytes, dsp, within_budget) rows, best first."""
        rows = [(c.desc, c.latency, c.res["bram_bytes"], c.res["dsp"],
                 c.within_budget) for c in self.candidates]
        rows.sort(key=lambda r: (not r[4], r[1], r[2], r[3]))
        return rows

    def explain(self) -> str:
        """Per-candidate accept/reject report (see CompileResult.explain)."""
        lines = []
        for c in self.candidates:
            lines.append(
                f"{c.desc}: latency={c.latency} "
                + " ".join(f"{k}={c.res[k]:g}" for k in
                           ("bram_bytes", "dsp", "ff_bits"))
                + f" [{c.status or ('ok' if c.within_budget else 'over budget')}]")
        for desc, reason in self.rejections:
            if not any(c.desc == desc for c in self.candidates):
                lines.append(f"{desc}: [{reason}]")
        return "\n".join(lines)


def _budget_key(res: dict[str, float], budget: dict[str, float]) -> bool:
    return all(res.get(k, 0.0) <= v + 1e-9 for k, v in budget.items())


def _unroll_factors_for(p: Program, factors: Sequence[int]) -> list[int]:
    """Factors that partially unroll at least one innermost loop."""
    out = []
    inner = [l for l in p.loops()
             if not any(isinstance(ch, Loop) for ch in l.body)]
    for f in factors:
        if any(l.trip % f == 0 and l.trip // f >= 1 and not l.unroll
               for l in inner):
            out.append(f)
    return out


def _tile_moves(p: Program, sizes: Sequence[int]) -> list[LoopTile]:
    """One tiling move per size, strip-mining every top-level loop it
    divides (order-preserving, so always legal)."""
    moves = []
    tops = [it for it in p.body if isinstance(it, Loop)
            and it.tile_block is None]  # don't re-strip an existing tile
    for s in sizes:
        cfg = {l.ivname: s for l in tops if l.trip % s == 0 and l.trip // s >= 2}
        if cfg:
            moves.append(LoopTile(cfg))
    return moves


def _pipeline_text(passes: Sequence[Pass]) -> Optional[str]:
    """The textual form of ``passes`` for cache keys, or None when a pass
    falls outside the textual grammar (then the candidate is uncacheable)."""
    from .pipeline_parse import print_pipeline
    try:
        return print_pipeline(list(passes))
    except Exception:
        return None


def _candidate_key(p: Program, all_passes: Sequence[Pass], mode: str,
                   incremental: bool, n_new: int) -> Optional[str]:
    """Persistent-cache key of one candidate measurement: the program
    fingerprint x full pipeline text x resource mode, plus the no-op
    detection flavor (it decides whether the entry means None)."""
    text = _pipeline_text(all_passes)
    if text is None:
        return None
    return fingerprint(p, pipeline=text, mode=mode,
                       extra=f"cand;inc={int(bool(incremental))};new={n_new}")


def _rehydrate_candidate(entry: dict, p: Program, desc: str,
                         passes: Sequence[Pass], start: Program,
                         base_passes: Sequence[Pass], verify: bool,
                         incremental: bool) -> Optional[DSECandidate]:
    """Rebuild a DSECandidate from a cache entry by re-applying the passes
    (cheap: no differential check) and unpacking the stored schedule onto
    the result.  Raises ValueError when the entry does not fit this program
    — the caller then treats it as a miss and recompiles."""
    from .dataflow import ResourceVector

    if entry.get("noop"):
        return None
    if verify and not entry.get("verified"):
        raise ValueError("cached entry was never differentially verified")
    pm = PassManager(passes, verify=False)
    q = pm.run(start)
    if passes and (q is start or
                   (incremental and not pm.reports[-1].changed)):
        raise ValueError("cached entry disagrees: pass application no-ops")
    s = unpack_schedule(q, entry["schedule"])
    return DSECandidate(
        desc=desc or "baseline", passes=tuple(base_passes) + tuple(passes),
        program=q, schedule=s, latency=int(entry["latency"]),
        res=ResourceVector(**entry["res"]), within_budget=True, cached=True)


def _probe_candidate_cache(store: Optional[CacheStore], key: Optional[str],
                           p: Program, desc: str, passes: Sequence[Pass],
                           start: Program, base_passes: Sequence[Pass],
                           verify: bool, incremental: bool):
    """(hit, candidate_or_None).  Never compiles; a stale or unverified
    entry reads as a miss."""
    if store is None or key is None:
        return False, None
    entry = store.get(key)
    if entry is None:
        return False, None
    try:
        return True, _rehydrate_candidate(entry, p, desc, passes, start,
                                          base_passes, verify, incremental)
    except (ValueError, KeyError, TypeError):
        return False, None


def _degrading(events: Sequence[dict]) -> bool:
    return any(e.get("kind") in faults.DEGRADING_KINDS for e in events)


def dedupe_diagnostics(entries: Sequence[dict]) -> list[dict]:
    """Collapse repeated identical diagnostics across DSE candidates.

    Two entries are "identical" when every field except the reporting
    ``candidate`` (and any prior ``count``) matches — e.g. the same solver
    gap on the same (src, snk, carry) site resurfacing in every candidate
    that re-analyzes the nest.  The first occurrence is kept (stable
    order) and gains a ``count`` when it swallowed duplicates, so
    ``explain()`` and machine consumers see each distinct fact once."""
    out: list[dict] = []
    index: dict[tuple, int] = {}
    for e in entries:
        key = tuple(sorted((k, repr(v)) for k, v in e.items()
                           if k not in ("candidate", "count")))
        i = index.get(key)
        if i is None:
            index[key] = len(out)
            out.append(dict(e))
        else:
            out[i]["count"] = (out[i].get("count") or 1) + \
                (e.get("count") or 1)
    return out


def _store_candidate(store: Optional[CacheStore], key: Optional[str],
                     c: Optional[DSECandidate], verify: bool) -> None:
    if store is None or key is None:
        return
    if c is not None and c.provenance != "exact":
        return  # degraded measurements must never poison the cache
    if c is None:
        store.put(key, {"noop": True})
        return
    store.put(key, {"noop": False, "verified": bool(verify),
                    "latency": int(c.latency),
                    "res": {k: float(v) for k, v in c.res.items()},
                    "schedule": pack_schedule(c.schedule)})


def measure_candidate(p: Program, desc: str, passes: Sequence[Pass], *,
                      base: Optional[Program] = None,
                      base_passes: Sequence[Pass] = (),
                      verify: bool = True, seeds: Sequence[int] = (0,),
                      mode: str = "ours",
                      incremental: bool = True,
                      store: Optional[CacheStore] = None
                      ) -> Optional[DSECandidate]:
    """Apply ``passes`` on top of ``base`` (an already-verified
    intermediate, default the original program ``p``), compile, and cost.
    Incremental composition does not re-apply and re-verify the whole
    pipeline prefix — equivalence to ``p`` is transitive through the
    verified base.

    Returns None for a no-op: under ``incremental=True`` (the DSE's
    one-move-at-a-time composition) when the NEWEST move applied nothing —
    the result would duplicate an already-measured candidate; under
    ``incremental=False`` (a caller-specified fixed pipeline) only when
    the WHOLE pipeline applied nothing — a fixed pipeline whose last pass
    happens not to fire must still yield the earlier passes' design.

    ``store`` enables the persistent compile cache: a usable entry skips
    the differential check and the scheduling ILP entirely (passes are
    still re-applied, unverified — equivalence was discharged when the
    entry was created, and the entry says so via its ``verified`` flag)."""
    from .dataflow import resources

    start = base if base is not None else p
    key = None
    if store is not None:
        key = _candidate_key(p, tuple(base_passes) + tuple(passes), mode,
                             incremental, len(tuple(passes)))
        hit, c = _probe_candidate_cache(store, key, p, desc, passes, start,
                                        base_passes, verify, incremental)
        if hit:
            return c
    ev0 = faults.event_count()  # degradations recorded while measuring
    pm = PassManager(passes, verify=verify, seeds=seeds)
    q = pm.run(start)
    if passes and (q is start or
                   (incremental and not pm.reports[-1].changed)):
        if not _degrading(faults.events_since(ev0)):
            # a *degraded* no-op verdict (e.g. a conservatively refused
            # fusion) must not be persisted as the pipeline's truth
            _store_candidate(store, key, None, verify)
        return None
    s = compile_program(q)
    res = resources(q, s, mode)
    diags = tuple(faults.events_since(ev0))
    prov = ("degraded"
            if s.provenance == "degraded" or _degrading(diags) else "exact")
    c = DSECandidate(
        desc=desc or "baseline", passes=tuple(base_passes) + tuple(passes),
        program=q, schedule=s, latency=s.completion_time(), res=res,
        within_budget=True, provenance=prov, diags=diags)
    _store_candidate(store, key, c, verify)
    return c


def validate_candidate(c: DSECandidate, seeds: Sequence[int] = (0,)) -> None:
    """Brute-force oracles for a DSE winner: ``validate_schedule`` plus
    ``timed_exec`` vs ``sequential_exec`` (small programs only — this
    enumerates dynamic instances).  Raises AssertionError explicitly so the
    check survives ``python -O``."""
    from .sim import (make_inputs, sequential_exec, timed_exec,
                      validate_schedule)
    violations = validate_schedule(c.program, c.schedule)
    if violations:
        raise AssertionError(
            f"DSE winner '{c.desc}' fails validate_schedule: "
            f"{violations[:5]}")
    import numpy as np
    inp = make_inputs(c.program, seeds[0])
    got = timed_exec(c.program, c.schedule, inp)
    want = sequential_exec(c.program, inp)
    for k in want:
        if not np.allclose(got[k], want[k], rtol=1e-12, atol=0):
            raise AssertionError(
                f"DSE winner '{c.desc}': timed_exec differs from "
                f"sequential_exec on array {k}")


# Move families the search can draw from (SearchConfig.moves selects a
# subset — e.g. the Pallas stencil sweep excludes "partition", a knob the
# kernel's VMEM line buffer cannot express).
MOVE_FAMILIES = ("fuse", "partition", "unroll", "tile")


def _single_moves(p: Program, families: Sequence[str],
                  unroll_factors: Sequence[int],
                  tile_sizes: Sequence[int]) -> list[tuple[str, Pass]]:
    moves: list[tuple[str, Pass]] = []
    unknown = set(families) - set(MOVE_FAMILIES)
    if unknown:
        raise ValueError(f"unknown move families {sorted(unknown)}; "
                         f"valid: {MOVE_FAMILIES}")
    if "fuse" in families:
        # shift-and-peel fusion (mismatched bounds fuse too) plus the
        # equal-bounds-only variant: peeling trades prologue nests for core
        # overlap, which is not always the latency winner — enumerate both
        moves += [("fuse", FuseProducerConsumer()),
                  ("fuse(noshift)", FuseProducerConsumer(enable_shift=False))]
    if "partition" in families:
        moves.append(("partition", ArrayPartition()))
    if "unroll" in families:
        moves += [(f"unroll(x{f})", LoopUnroll(f))
                  for f in _unroll_factors_for(p, unroll_factors)]
    if "tile" in families:
        moves += [(t.name, t) for t in _tile_moves(p, tile_sizes)]
    return moves


# ---------------------------------------------------------------------------
# Expansion-base selection: hypervolume contribution + lazy-invalidation queue
# ---------------------------------------------------------------------------


def _hv(points: Sequence[tuple], ref: tuple) -> float:
    """Exact hypervolume (minimization) of the union of boxes ``[p, ref]``
    by recursive dimension sweeping — fine for the DSE's <= ~16-point,
    4-axis archives.  Points not strictly below ``ref`` contribute nothing."""
    pts = sorted(p for p in points if all(x < r for x, r in zip(p, ref)))
    if not pts:
        return 0.0
    if len(ref) == 1:
        return ref[0] - pts[0][0]
    vol = 0.0
    for i, p in enumerate(pts):
        hi = pts[i + 1][0] if i + 1 < len(pts) else ref[0]
        if hi > p[0]:
            vol += (hi - p[0]) * _hv([q[1:] for q in pts[:i + 1]], ref[1:])
    return vol


def _hv_contributions(archive: Sequence[DSECandidate]) -> dict[int, float]:
    """id(candidate) -> hypervolume contribution over the archive-normalized
    objective space (each axis scaled to the archive's [min, max] span, ref
    1.1 per axis, so no axis's units dominate and frontier extremes always
    contribute)."""
    if not archive:
        return {}
    objs = [a.objectives() for a in archive]
    lo = [min(col) for col in zip(*objs)]
    hi = [max(col) for col in zip(*objs)]
    span = [h - l if h > l else 1.0 for l, h in zip(lo, hi)]
    pts = [tuple((x - l) / s for x, l, s in zip(o, lo, span)) for o in objs]
    ref = tuple(1.1 for _ in lo)
    total = _hv(pts, ref)
    return {id(a): total - _hv(pts[:i] + pts[i + 1:], ref)
            for i, a in enumerate(archive)}


class _ExpansionQueue:
    """Unexpanded archive members, pending frontier expansion.

    Replaces the sort-every-iteration list (O(n^2 log n) across a run) with
    a heap for the classic lowest-latency-first selector, or a live list
    scanned by hypervolume contribution for ``selector="hv"``.  Dominated
    members are invalidated *lazily*: ``insert`` only flips their status
    and ``pop`` skips them — no O(n) ``list.remove`` on the hot path."""

    SELECTORS = ("latency", "hv")

    def __init__(self, selector: str = "latency"):
        if selector not in self.SELECTORS:
            raise ValueError(f"unknown selector {selector!r}; "
                             f"valid: {self.SELECTORS}")
        self.selector = selector
        self._heap: list[tuple] = []
        self._live: list[DSECandidate] = []
        self._n = 0                      # insertion order = tie break

    def push(self, c: DSECandidate) -> None:
        self._n += 1
        if self.selector == "latency":
            heapq.heappush(self._heap,
                           (c.latency, c.res["bram_bytes"], self._n, c))
        else:
            self._live.append(c)

    @staticmethod
    def _stale(c: DSECandidate) -> bool:
        return c.status.startswith("dominated")

    def pop(self, archive: Sequence[DSECandidate]) -> Optional[DSECandidate]:
        if self.selector == "latency":
            while self._heap:
                *_, c = heapq.heappop(self._heap)
                if not self._stale(c):
                    return c
            return None
        self._live = [c for c in self._live if not self._stale(c)]
        if not self._live:
            return None
        contrib = _hv_contributions(archive)
        best_i, best_v = 0, None
        for i, c in enumerate(self._live):
            # an over-budget root is the only queued member outside the
            # archive — it must be expanded first (it is the only base)
            v = contrib.get(id(c), float("inf"))
            if best_v is None or v > best_v + 1e-12:
                best_i, best_v = i, v
        return self._live.pop(best_i)


def _macro_moves(base_program: Program, families: Sequence[str],
                 unroll_factors: Sequence[int],
                 tile_sizes: Sequence[int]) -> list[tuple[str, list[Pass]]]:
    """Composite single-step moves: fuse the chain, then immediately tile or
    unroll the *fused* nests — "fuse>tile{...}" / "fuse>unroll(xF)".  A
    fuse+tile frontier point then costs ONE compile instead of two expansion
    waves, which is what reaches deep pipelines within a tight
    ``max_candidates`` cap.  The tile/unroll knobs are derived from a cheap
    structural probe of the fused program (pass application only, no
    scheduling): fused loop names are deterministic per apply, so the real
    measurement reproduces them."""
    if "fuse" not in families:
        return []
    try:
        fused = FuseProducerConsumer().apply(base_program)
    except TransformError:
        return []
    if fused is base_program:
        return []
    out: list[tuple[str, list[Pass]]] = []
    if "tile" in families:
        out += [(f"fuse>{t.name}", [FuseProducerConsumer(), t])
                for t in _tile_moves(fused, tile_sizes)]
    if "unroll" in families:
        out += [(f"fuse>unroll(x{f})", [FuseProducerConsumer(), LoopUnroll(f)])
                for f in _unroll_factors_for(fused, unroll_factors)]
    return out


# ---------------------------------------------------------------------------
# Parallel wave measurement (ProcessPoolExecutor fan-out)
# ---------------------------------------------------------------------------


def _bump_uid_counter(p: Program) -> None:
    """Make the process-local uid counter safe after unpickling a program:
    nodes a worker creates must not collide with the program's existing
    uids (a spawn-start worker's counter begins at 0)."""
    import itertools

    from . import ir
    top = max((n.uid for n, _ in p.walk()), default=-1)
    nxt = next(ir._uid)
    ir._uid = itertools.count(max(top + 1, nxt + 1))


def _measure_worker(payload: tuple):
    """Pool entry point for one cold candidate measurement.  Workers never
    touch the persistent store — the parent owns cache probing/writing, so
    the on-disk state is single-writer per explore call.  Returns
    ``(candidate_or_None, worker_events)`` so degradations behind a None
    (no-op) verdict still reach the parent."""
    program, desc, passes, base_passes, verify, seeds, mode, attempt = payload
    faults.worker_fault_point(desc, attempt)
    _bump_uid_counter(program)
    ev0 = faults.event_count()
    c = measure_candidate(program, desc, passes, base_passes=base_passes,
                          verify=verify, seeds=seeds, mode=mode)
    return c, tuple(faults.events_since(ev0))


_PENDING = object()     # serial-mode placeholder: measure lazily at replay
_IN_PROCESS = object()  # supervisor verdict: measure in the parent process

WORKER_RETRIES = 2        # faults per candidate before quarantine
WORKER_BACKOFF_S = 0.05   # base of the capped exponential retry backoff
WORKER_BACKOFF_CAP_S = 1.0
POOL_REBUILD_CAP = 6      # pool rebuilds per explore before serial fallback


class _WorkerFault:
    """Replay sentinel: this candidate was quarantined after repeated worker
    faults — recorded in ``rejected`` with a ``worker-fault`` reason, never
    counted as a compile."""

    __slots__ = ("reason",)

    def __init__(self, reason: str):
        self.reason = reason


class _CompileFailed:
    """Replay sentinel: the candidate failed deterministically inside the
    worker (TransformError / CompileError) — the same verdict the serial
    engine reaches, so serial and parallel runs stay bit-identical."""

    __slots__ = ("error",)

    def __init__(self, error: str):
        self.error = error


class _PoolSupervisor:
    """Owns the DSE ProcessPoolExecutor (DESIGN.md §9): per-candidate
    deadlines on ``Future.result``, capped exponential-backoff retries for
    transient faults, hard pool rebuilds on hang/breakage, and quarantine
    after ``WORKER_RETRIES`` strikes.  Created immediately before the
    explore loop's ``try`` and closed in its ``finally`` with
    ``shutdown(cancel_futures=True)``, so a raising insert/selector can't
    leak worker processes."""

    def __init__(self, jobs: int, deadline_s: Optional[float]):
        self.jobs = int(jobs)
        self.deadline_s = deadline_s
        self.rebuilds = 0
        self.events: list[dict] = []
        self.pool = self._make()

    def _make(self):
        try:
            import concurrent.futures as cf
            return cf.ProcessPoolExecutor(max_workers=self.jobs)
        except Exception:
            return None

    def note(self, kind: str, **info) -> None:
        self.events.append({"kind": kind, **info})

    def submit(self, payload):
        if self.pool is None:
            return None
        try:
            return self.pool.submit(_measure_worker, payload)
        except Exception:
            return None

    def rebuild(self) -> None:
        """Tear the (hung or broken) pool down hard and start fresh.  A
        hung worker ignores ``shutdown``, so its process is terminated."""
        self.rebuilds += 1
        old, self.pool = self.pool, None
        if old is not None:
            procs = []
            try:
                procs = list(getattr(old, "_processes", {}).values())
            except Exception:
                pass
            try:
                old.shutdown(wait=False, cancel_futures=True)
            except Exception:
                pass
            for proc in procs:
                try:
                    proc.terminate()
                except Exception:
                    pass
        if self.rebuilds <= POOL_REBUILD_CAP:
            self.pool = self._make()
        else:
            self.note("pool-disabled", rebuilds=self.rebuilds)

    def close(self) -> None:
        if self.pool is not None:
            try:
                self.pool.shutdown(wait=False, cancel_futures=True)
            except Exception:
                pass
            self.pool = None

    def collect(self, fut, make_payload, desc: str) -> tuple:
        """Supervise one candidate's future to a verdict.

        Returns ``("ok", (candidate, worker_events))``,
        ``("quarantine", reason)``, ``("compile-error", message)``, or
        ``("fallback", None)`` (pool unusable: measure in-process)."""
        import concurrent.futures as cf
        from concurrent.futures.process import BrokenProcessPool

        strikes = 0
        attempt = 0
        resubmits = 0
        while True:
            if fut is None:
                return ("fallback", None)
            try:
                return ("ok", fut.result(timeout=self.deadline_s))
            except (TransformError, CompileError) as e:
                return ("compile-error", str(e))
            except cf.CancelledError:
                # collateral of a pool rebuild triggered by a sibling —
                # resubmit the same attempt, no strike
                resubmits += 1
                if resubmits > 2 * POOL_REBUILD_CAP:
                    return ("fallback", None)
                fut = self.submit(make_payload(attempt))
                continue
            except cf.TimeoutError:
                strikes += 1
                self.note("worker-hang", candidate=desc, attempt=attempt,
                          deadline_s=self.deadline_s)
                self.rebuild()
            except BrokenProcessPool:
                strikes += 1
                self.note("pool-broken", candidate=desc, attempt=attempt)
                self.rebuild()
            except BaseException as e:
                strikes += 1
                self.note("worker-crash", candidate=desc, attempt=attempt,
                          error=repr(e))
            if strikes >= WORKER_RETRIES:
                return ("quarantine",
                        f"worker-fault: quarantined after {strikes} faults")
            attempt += 1
            self.note("worker-retry", candidate=desc, attempt=attempt)
            time.sleep(min(WORKER_BACKOFF_S * (2 ** (attempt - 1)),
                           WORKER_BACKOFF_CAP_S))
            fut = self.submit(make_payload(attempt))


def _measure_wave(wave: list, cur: "DSECandidate", p: Program,
                  sup: Optional[_PoolSupervisor],
                  store: Optional[CacheStore], verify: bool,
                  seeds: Sequence[int], mode: str) -> list:
    """Measure one expansion wave (all moves off one base), aligned with
    ``wave``.

    Serial mode (``sup`` is None) returns ``_PENDING`` placeholders so the
    caller measures each move only after its under-cap check — exactly the
    sequential engine's behavior.  Parallel mode probes the cache first,
    fans the misses out across the supervised pool, and collects each
    result under a per-candidate deadline with retry / pool-rebuild /
    quarantine handling; compiles that land beyond the candidate cap are
    discarded at replay, so the merged archive is bit-identical to a
    serial run — faults or not.  A slot may also hold a ``_WorkerFault``
    (quarantined) or ``_CompileFailed`` (deterministic failure) sentinel
    for the replay loop."""
    if sup is None or sup.pool is None:
        return [_PENDING] * len(wave)
    results: list = [None] * len(wave)
    futs: dict[int, tuple] = {}
    for i, (full, mvs) in enumerate(wave):
        key = None
        if store is not None:
            key = _candidate_key(p, tuple(cur.passes) + tuple(mvs), mode,
                                 True, len(mvs))
            hit, c = _probe_candidate_cache(store, key, p, full, mvs,
                                            cur.program, cur.passes, verify,
                                            True)
            if hit:
                results[i] = c
                continue

        def make_payload(attempt: int, full=full, mvs=mvs) -> tuple:
            return (cur.program, full, list(mvs), tuple(cur.passes),
                    verify, tuple(seeds), mode, attempt)

        futs[i] = (sup.submit(make_payload(0)), key, make_payload)
    for i, (fut, key, make_payload) in futs.items():
        full, mvs = wave[i]
        kind, val = sup.collect(fut, make_payload, full)
        if kind == "ok":
            c, wevents = val
            if c is None and wevents:
                # degradations behind a no-op verdict would otherwise be
                # lost with the worker process
                sup.events.extend({**e, "candidate": full} for e in wevents)
            if not _degrading(wevents):
                _store_candidate(store, key, c, verify)
            results[i] = c
        elif kind == "quarantine":
            results[i] = _WorkerFault(val)
        elif kind == "compile-error":
            results[i] = _CompileFailed(val)
        else:  # pool unusable: fall back to in-process measurement
            results[i] = _PENDING
    return results


@dataclass
class ParetoResult:
    """Output of the Pareto-frontier DSE (wrapped by hls.CompileResult)."""

    baseline: DSECandidate
    frontier: list[DSECandidate]            # feasible + non-dominated
    candidates: list[DSECandidate]          # every compiled design point
    rejected: list[tuple[str, str]]         # (desc, reason) — capacity etc.
    caps: dict[str, float]                  # resolved absolute ceilings
    compiles: int
    # structured failure-handling record (DESIGN.md §9): solver gaps,
    # worker retries/quarantines, pool rebuilds, cache repairs
    diagnostics: list[dict] = field(default_factory=list)
    # "degraded" when any diagnostic may have moved the frontier off the
    # fault-free result; recovered faults (retries, repairs) stay "exact"
    provenance: str = "exact"


def _search_signature(caps, rel_caps, moves, unroll_factors, tile_sizes,
                      max_candidates, verify, seeds, selector,
                      macro_moves) -> str:
    """Every knob that shapes the search trajectory, for the whole-frontier
    cache key.  ``jobs`` is deliberately absent: parallel and serial runs
    are bit-identical by contract, so they share entries."""
    return ("pareto"
            f";moves={','.join(moves)}"
            f";uf={tuple(unroll_factors)};ts={tuple(tile_sizes)}"
            f";max={max_candidates};verify={int(bool(verify))}"
            f";seeds={tuple(seeds)};sel={selector}"
            f";macro={int(bool(macro_moves))}"
            f";caps={sorted((caps or {}).items())}"
            f";rel={sorted((rel_caps or {}).items())}")


def _pack_pareto(r: ParetoResult, verify: bool) -> Optional[dict]:
    """The whole ParetoResult as a JSON blob (None when any candidate's
    pipeline falls outside the textual grammar, or when the result is
    degraded — a faulted frontier must never be replayed as the truth)."""
    if r.provenance != "exact":
        return None
    cand_blobs = []
    for c in r.candidates:
        text = _pipeline_text(c.passes)
        if text is None:
            return None
        cand_blobs.append({
            "desc": c.desc, "pipeline": text, "status": c.status,
            "within_budget": bool(c.within_budget), "latency": int(c.latency),
            "res": {k: float(v) for k, v in c.res.items()},
            "schedule": pack_schedule(c.schedule)})
    idx = {id(c): i for i, c in enumerate(r.candidates)}
    return {"verified": bool(verify),
            "candidates": cand_blobs,
            "frontier": [idx[id(c)] for c in r.frontier],
            "rejected": [list(t) for t in r.rejected],
            "caps": {k: float(v) for k, v in r.caps.items()},
            "compiles": int(r.compiles)}


def _unpack_pareto(blob: dict, p: Program) -> ParetoResult:
    """Rehydrate a cached frontier: re-apply each candidate's pipeline
    (unverified — equivalence was discharged on the cold run) and unpack
    its schedule.  Raises on any structural mismatch (stale entry)."""
    from .dataflow import ResourceVector
    from .pipeline_parse import parse_pipeline

    cands = []
    for cb in blob["candidates"]:
        passes = tuple(parse_pipeline(cb["pipeline"]))
        q = PassManager(passes, verify=False).run(p) if passes else p
        s = unpack_schedule(q, cb["schedule"])
        cands.append(DSECandidate(
            desc=cb["desc"], passes=passes, program=q, schedule=s,
            latency=int(cb["latency"]), res=ResourceVector(**cb["res"]),
            within_budget=bool(cb["within_budget"]), status=cb["status"],
            cached=True))
    if not cands:
        raise ValueError("empty cached frontier")
    return ParetoResult(
        baseline=cands[0],
        frontier=[cands[i] for i in blob["frontier"]],
        candidates=cands,
        rejected=[tuple(t) for t in blob["rejected"]],
        caps=dict(blob["caps"]), compiles=int(blob["compiles"]))


def pareto_explore(p: Program, *,
                   caps: Optional[dict[str, float]] = None,
                   rel_caps: Optional[dict[str, float]] = None,
                   moves: Sequence[str] = MOVE_FAMILIES,
                   unroll_factors: Sequence[int] = (2, 4),
                   tile_sizes: Sequence[int] = (4,),
                   max_candidates: int = 24,
                   verify: bool = True,
                   seeds: Sequence[int] = (0,),
                   mode: str = "ours",
                   selector: str = "latency",
                   macro_moves: bool = False,
                   jobs: int = 1,
                   worker_deadline_s: Optional[float] = 60.0,
                   store: Union[CacheStore, str, None] = "auto",
                   verbose: bool = False) -> ParetoResult:
    """Pareto-frontier DSE over transform pipelines (DESIGN.md §6, §8).

    Maintains a dominance-pruned archive over the objective space
    ``PARETO_METRICS`` = (latency, bram_bytes, dsp, ff_bits) and expands it
    frontier-first: an unexpanded archive member is selected (lowest
    latency for ``selector="latency"``, largest hypervolume contribution
    over baseline-span-normalized objectives for ``selector="hv"``) and
    every applicable single move is appended; children that survive
    capacity checks and dominance pruning join the archive and the
    expansion queue.  The search stops when the archive has no unexpanded
    member or ``max_candidates`` compilations were spent.
    ``macro_moves=True`` additionally offers composite fuse>tile /
    fuse>unroll steps (one compile each).

    ``caps`` are absolute resource ceilings, ``rel_caps`` scale the
    BASELINE's own usage (``{"bram_bytes": 1.0}`` = iso-BRAM); violating
    candidates are recorded (with the violated capacities as their reject
    reason) but never enter the archive.  Dominated candidates stay in
    ``candidates`` with a ``dominated by <desc>`` status — that record is
    what ``CompileResult.explain()`` prints.

    ``jobs > 1`` measures each expansion wave on a *supervised*
    ``ProcessPoolExecutor`` with a deterministic merge: the resulting
    archive is bit-identical to a serial run.  The supervisor bounds each
    candidate by ``worker_deadline_s``, retries transient worker faults
    with capped exponential backoff, rebuilds the pool when it hangs or
    breaks, and quarantines candidates that keep faulting (recorded in
    ``rejected`` with a ``worker-fault`` reason); an unusable pool falls
    back to in-process measurement.  Every recovery action lands in
    ``ParetoResult.diagnostics``.
    ``store`` is the persistent compile cache: ``"auto"`` resolves the
    process store (None when ``REPRO_HLS_CACHE=0``), and both whole
    frontiers and individual candidate measurements are keyed on the
    program fingerprint, so a repeat explore is O(lookup).
    """
    from .dataflow import RESOURCE_KEYS

    if store == "auto":
        store = get_store()
    caps_in = dict(caps or {})
    caps = dict(caps_in)
    unknown = (set(caps) | set(rel_caps or {})) - set(RESOURCE_KEYS)
    if unknown:
        raise ValueError(f"unknown capacity resource(s) {sorted(unknown)}; "
                         f"valid keys: {sorted(RESOURCE_KEYS)}")

    fkey = None
    if store is not None:
        fkey = fingerprint(p, pipeline="", mode=mode, extra=_search_signature(
            caps_in, rel_caps, moves, unroll_factors, tile_sizes,
            max_candidates, verify, seeds, selector, macro_moves))
        blob = store.get(fkey)
        if blob is not None and (blob.get("verified") or not verify):
            try:
                return _unpack_pareto(blob, p)
            except (ValueError, KeyError, TypeError, IndexError):
                pass  # stale entry: recompute (the put below overwrites it)

    repairs0 = store.repairs if store is not None else 0
    extra_events: list[dict] = []  # parent-side events not tied to a candidate

    baseline = measure_candidate(p, "baseline", [], verify=verify,
                                 seeds=seeds, mode=mode, store=store)
    for k, scale in (rel_caps or {}).items():
        ceil = scale * baseline.res[k]
        caps[k] = min(caps.get(k, ceil), ceil)

    def fits(c: DSECandidate) -> list[str]:
        return c.res.violations(caps)

    baseline.within_budget = not fits(baseline)
    baseline.status = "baseline"
    candidates = [baseline]
    rejected: list[tuple[str, str]] = []
    archive: list[DSECandidate] = [baseline] if baseline.within_budget else []
    if not archive:
        rejected.append((baseline.desc,
                         "over budget: " + "; ".join(fits(baseline))))
    equeue = _ExpansionQueue(selector)
    equeue.push(baseline)  # expand even an infeasible root
    seen_descs = {"baseline"}
    compiles = 1
    base_moves = _single_moves(p, moves, unroll_factors, tile_sizes)

    sup = None
    if int(jobs) > 1:
        sup = _PoolSupervisor(int(jobs), worker_deadline_s)
        if sup.pool is None:
            sup = None  # graceful serial fallback

    def insert(c: DSECandidate) -> None:
        """Capacity check + dominance-pruned archive insertion."""
        viol = fits(c)
        if viol:
            c.within_budget = False
            c.status = "over budget: " + "; ".join(viol)
            rejected.append((c.desc, c.status))
            return
        vec = c.objectives()
        for a in archive:
            avec = a.objectives()
            if dominates(avec, vec) or avec == vec:
                c.status = f"dominated by {a.desc}"
                return
        newly_dominated = [a for a in archive
                           if dominates(vec, a.objectives())]
        if newly_dominated:
            for a in newly_dominated:
                # flipping the status is what lazily invalidates the
                # queue entry — no O(n) removal here
                a.status = f"dominated by {c.desc}"
            dead = {id(a) for a in newly_dominated}
            archive[:] = [a for a in archive if id(a) not in dead]
        archive.append(c)
        c.status = "frontier"
        equeue.push(c)

    try:
        while compiles < max_candidates:
            cur = equeue.pop(archive)
            if cur is None:
                break
            base_descs = cur.desc.split(" | ") if cur.passes else []
            # tile moves are re-derived from the expansion base: fusion
            # renames loops, so tiling the *fused* nest (the knob the Pallas
            # kernel layer reads as its block size) is only reachable this way
            level_moves = list(base_moves)
            if "tile" in moves:
                level_moves += [
                    (t.name, t) for t in _tile_moves(cur.program, tile_sizes)
                    if t.name not in {d for d, _ in base_moves}]
            if macro_moves and not any(d.startswith("fuse")
                                       for d in base_descs):
                ev0 = faults.event_count()
                level_moves += _macro_moves(cur.program, moves,
                                            unroll_factors, tile_sizes)
                # the structural fuse probe can itself hit a degraded
                # legality check — capture those events here, they belong
                # to no measured candidate
                extra_events.extend(faults.events_since(ev0))
            wave = []
            for desc, mv in level_moves:
                if desc in base_descs:
                    continue
                full = " | ".join(base_descs + [desc])
                if full in seen_descs:
                    continue
                wave.append((full, [mv] if isinstance(mv, Pass)
                             else list(mv)))
            results = _measure_wave(wave, cur, p, sup, store, verify,
                                    seeds, mode)
            # deterministic merge: replay in submission order with the same
            # cap / no-op / insert logic as the serial engine
            for (full, mvs), c in zip(wave, results):
                if full in seen_descs:
                    continue
                if compiles >= max_candidates:
                    break
                seen_descs.add(full)
                if isinstance(c, _WorkerFault):
                    rejected.append((full, c.reason))
                    extra_events.append({"kind": "worker-quarantine",
                                         "candidate": full,
                                         "reason": c.reason})
                    continue
                if isinstance(c, _CompileFailed):
                    rejected.append((full, f"compile-error: {c.error}"))
                    extra_events.append({"kind": "compile-error",
                                         "candidate": full,
                                         "error": c.error})
                    continue
                if c is _PENDING:
                    ev0 = faults.event_count()
                    try:
                        c = measure_candidate(p, full, mvs, base=cur.program,
                                              base_passes=cur.passes,
                                              verify=verify, seeds=seeds,
                                              mode=mode, store=store)
                    except (TransformError, CompileError) as e:
                        rejected.append((full, f"compile-error: {e}"))
                        extra_events.append({"kind": "compile-error",
                                             "candidate": full,
                                             "error": str(e)})
                        continue
                    if c is None:
                        # keep degradations behind a no-op verdict
                        extra_events.extend(
                            {**e, "candidate": full}
                            for e in faults.events_since(ev0))
                if c is None:
                    continue  # the move applied nothing
                compiles += 1
                candidates.append(c)
                insert(c)
                if verbose:
                    print(f"  dse: {full}: latency={c.latency} "
                          f"res={dict(c.res)} [{c.status}]")
    finally:
        if sup is not None:
            sup.close()

    frontier = sorted(archive, key=lambda c: c.objectives())
    diagnostics: list[dict] = []
    for c in candidates:
        diagnostics.extend({**d, "candidate": c.desc} for d in c.diags)
    diagnostics.extend(extra_events)
    if sup is not None:
        diagnostics.extend(sup.events)
    if store is not None and store.repairs > repairs0:
        diagnostics.append({"kind": "cache-repair",
                            "count": store.repairs - repairs0})
    diagnostics = dedupe_diagnostics(diagnostics)
    degraded = (any(c.provenance != "exact" for c in candidates)
                or _degrading(diagnostics))
    result = ParetoResult(baseline=baseline, frontier=frontier,
                          candidates=candidates, rejected=rejected,
                          caps=caps, compiles=compiles,
                          diagnostics=diagnostics,
                          provenance="degraded" if degraded else "exact")
    if store is not None and fkey is not None:
        blob = _pack_pareto(result, verify)  # None for degraded results
        if blob is not None:
            store.put(fkey, blob)
    return result


# ---------------------------------------------------------------------------
# The pre-Pareto greedy driver, kept verbatim as the no-regression oracle:
# benchmarks/run.py pareto and tests/test_api.py compare every new frontier
# against this single-frontier hill climb's winner.
# ---------------------------------------------------------------------------


def _greedy_explore(p: Program, budget: Optional[dict[str, float]] = None, *,
                    unroll_factors: Sequence[int] = (2, 4),
                    tile_sizes: Sequence[int] = (4,),
                    max_candidates: int = 24,
                    verify: bool = True,
                    validate: bool = False,
                    seeds: Sequence[int] = (0,),
                    verbose: bool = False) -> DSEResult:
    """Greedy single-frontier resource-aware DSE (the old ``explore``).

    ``budget=None`` means iso-resource (baseline BRAM/DSP as ceilings);
    search = every single move, then greedy composition on top of the best
    within-budget candidate, bounded by ``max_candidates`` compilations.
    """
    def measure(desc, passes, base=None, base_passes=()):
        return measure_candidate(p, desc, passes, base=base,
                                 base_passes=base_passes, verify=verify,
                                 seeds=seeds)

    baseline = measure("baseline", [])
    if budget is None:
        budget = {"bram_bytes": baseline.res["bram_bytes"],
                  "dsp": baseline.res["dsp"]}
    budget = dict(budget)
    unknown = set(budget) - set(baseline.res)
    if unknown:
        raise ValueError(
            f"unknown budget resource(s) {sorted(unknown)}; "
            f"valid keys: {sorted(baseline.res)}")
    baseline.within_budget = _budget_key(baseline.res, budget)

    moves = _single_moves(p, MOVE_FAMILIES, unroll_factors, tile_sizes)
    candidates: list[DSECandidate] = [baseline]
    seen_descs = {"baseline"}
    compiles = 1

    def try_pipeline(descs, passes, base=None, base_passes=()):
        nonlocal compiles
        desc = " | ".join(descs)
        if desc in seen_descs or compiles >= max_candidates:
            return None
        seen_descs.add(desc)
        c = measure(desc, passes, base=base, base_passes=base_passes)
        if c is not None:
            compiles += 1  # only actual compilations count against the cap
            c.within_budget = _budget_key(c.res, budget)
            candidates.append(c)
            if verbose:
                print(f"  dse: {desc}: latency={c.latency} res={c.res} "
                      f"{'OK' if c.within_budget else 'OVER-BUDGET'}")
        return c

    for desc, mv in moves:
        try_pipeline([desc], [mv])

    def best_of(cands):
        ok = [c for c in cands if c.within_budget]
        pool = ok or cands
        return min(pool, key=lambda c: (c.latency, c.res["bram_bytes"],
                                        c.res["dsp"], c.res["ff_bits"]))

    frontier = best_of(candidates)
    while compiles < max_candidates:
        base_descs = frontier.desc.split(" | ") if frontier.passes else []
        level_moves = moves + [
            (t.name, t) for t in _tile_moves(frontier.program, tile_sizes)
            if t.name not in {d for d, _ in moves}]
        for desc, mv in level_moves:
            if desc not in base_descs:
                try_pipeline(base_descs + [desc], [mv],
                             base=frontier.program,
                             base_passes=frontier.passes)
        nxt = best_of(candidates)
        if nxt is frontier:
            break
        frontier = nxt

    best = best_of(candidates)
    if validate:
        validate_candidate(best, seeds)
    return DSEResult(baseline=baseline, best=best, candidates=candidates,
                     budget=budget)

"""The paper's auto-tuner (§3.1) + the resource-aware DSE driver.

Auto-tuner: binary search for the smallest feasible II of every loop that
lacks a programmer-specified ``pipeline`` II.

Feasibility of an II assignment = the scheduling system admits a solution
(Bellman-Ford finds no positive cycle) and loop-counter occupancy holds.
Loops are tuned innermost-first.  Each probe is incremental (DESIGN.md §5):
DepAnalysis enumerated the conflicting pairs once and caches each pair's
edge on the IIs of the loops in its iteration vectors, so a probe that
moves one loop's II only re-solves the dependences touching that loop —
and those via the closed-form fast path, not branch-and-bound.

DSE (``pareto_explore``, DESIGN.md §6): the scheduler finds the best
schedule for a *fixed* program, but the paper's headline wins depend on
program shape.  The search layer explores semantics-preserving transform
pipelines (fuse / partition / unroll / tile from ``transforms``), compiles
every candidate through the incremental scheduler, and maintains a
dominance-pruned archive over the objective space (latency, BRAM, DSP, FF)
— the Fig. 9 trade-off curve — expanded frontier-first rather than by
single-best hill climbing.  The declarative entry point is
``repro.core.hls.compile`` (api.py); ``explore``/``compile_program`` live
on as deprecated shims there.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

from .deps import DepAnalysis
from .ir import Loop, Program
from .scheduler import Schedule, check_loop_occupancy, feasible, schedule
from .transforms import (ArrayPartition, FuseProducerConsumer, LoopTile,
                         LoopUnroll, Pass, PassManager)


def _loops_with_depth(p: Program) -> list[tuple[Loop, int]]:
    return [(n, len(anc)) for n, anc in p.walk() if isinstance(n, Loop)]


def _seq_ii_bound(p: Program, loop: Loop) -> int:
    """A conservative (sequential-execution) II upper bound, bottom-up."""
    total = 1
    for item in loop.body:
        if isinstance(item, Loop):
            total += item.trip * _seq_ii_bound(p, item)
        else:
            total += p.op_latency(item)
    return total


def _occupancy_floor(loop: Loop, iis: dict[int, int]) -> int:
    lo = 1
    for item in loop.body:
        if isinstance(item, Loop):
            lo = max(lo, item.trip * iis[item.uid])
    return lo


def autotune(p: Program, dep: Optional[DepAnalysis] = None,
             verbose: bool = False) -> dict[int, int]:
    """Return loop uid -> II (programmer-specified IIs respected)."""
    dep = dep or DepAnalysis(p)
    loops = _loops_with_depth(p)
    iis: dict[int, int] = {}
    tunable: list[Loop] = []
    for loop, _ in loops:
        if loop.ii is not None:
            iis[loop.uid] = loop.ii
        else:
            iis[loop.uid] = _seq_ii_bound(p, loop)
            tunable.append(loop)

    # innermost-first (deepest), then program order
    depth = {l.uid: d for l, d in loops}
    tunable.sort(key=lambda l: -depth[l.uid])

    for loop in tunable:
        lo = _occupancy_floor(loop, iis)
        hi = max(lo, iis[loop.uid])

        def probe(ii: int) -> bool:
            iis[loop.uid] = ii
            return feasible(p, iis, dep)

        # ensure hi feasible (double if the conservative bound still fails,
        # e.g. due to cross-nest port serialization pressure)
        guard = 0
        while not probe(hi) and guard < 8:
            hi *= 2
            guard += 1
        best = hi
        while lo <= hi:
            mid = (lo + hi) // 2
            if probe(mid):
                best = mid
                hi = mid - 1
            else:
                lo = mid + 1
        iis[loop.uid] = best
        if verbose:
            print(f"  autotune: loop {loop.ivname} II={best}")

    assert check_loop_occupancy(p, iis)
    assert feasible(p, iis, dep), "autotuned IIs must be feasible"
    return iis


def compile_program(p: Program, verbose: bool = False) -> Schedule:
    """Full pipeline: dependence analysis -> II autotune -> scheduling ILP."""
    dep = DepAnalysis(p)
    iis = autotune(p, dep, verbose=verbose)
    s = schedule(p, iis, dep)
    assert s.feasible
    return s


# ---------------------------------------------------------------------------
# Design-space exploration (DESIGN.md §6): candidates + objective space
# ---------------------------------------------------------------------------

# The objective space of the Pareto search: scheduled latency plus the
# Fig. 9 resource axes the paper trades it against.
PARETO_METRICS = ("latency", "bram_bytes", "dsp", "ff_bits")


@dataclass
class DSECandidate:
    """One explored design point: a transform pipeline + its compiled
    schedule, resource vector and search status.  (Exported from the
    declarative front end as ``hls.DesignPoint``.)"""

    desc: str                     # human-readable pipeline description
    passes: tuple[Pass, ...]
    program: Program
    schedule: Schedule
    latency: int
    res: dict[str, float]         # dataflow.resources(program, schedule, mode)
    within_budget: bool
    status: str = ""              # "baseline" | "frontier" | "dominated by
    #                               <desc>" | "over budget: <violations>"

    def metric(self, key: str) -> float:
        return float(self.latency) if key == "latency" else float(self.res[key])

    def objectives(self, keys: Sequence[str] = PARETO_METRICS) -> tuple:
        return tuple(self.metric(k) for k in keys)


def dominates(u: Sequence[float], v: Sequence[float],
              tol: float = 1e-9) -> bool:
    """Pareto dominance: <= on every axis, < on at least one."""
    return all(a <= b + tol for a, b in zip(u, v)) and \
        any(a < b - tol for a, b in zip(u, v))


@dataclass
class DSEResult:
    """Legacy result shape of the deprecated ``explore`` shim (the
    declarative path returns ``hls.CompileResult``).  ``frontier`` and
    ``rejections`` are populated by the Pareto engine underneath."""

    baseline: DSECandidate
    best: DSECandidate
    candidates: list[DSECandidate] = field(default_factory=list)
    budget: dict[str, float] = field(default_factory=dict)
    frontier: list[DSECandidate] = field(default_factory=list)
    rejections: list[tuple[str, str]] = field(default_factory=list)

    @property
    def speedup(self) -> float:
        """baseline latency / best latency; 1.0 for degenerate (zero-cycle)
        baselines so an empty or fully-rejected search never divides by
        zero — check ``rejections`` / ``explain()`` for why."""
        if self.best.latency <= 0 or self.baseline.latency <= 0:
            return 1.0
        return self.baseline.latency / self.best.latency

    def table(self) -> list[tuple[str, int, float, float, bool]]:
        """(desc, latency, bram_bytes, dsp, within_budget) rows, best first."""
        rows = [(c.desc, c.latency, c.res["bram_bytes"], c.res["dsp"],
                 c.within_budget) for c in self.candidates]
        rows.sort(key=lambda r: (not r[4], r[1], r[2], r[3]))
        return rows

    def explain(self) -> str:
        """Per-candidate accept/reject report (see CompileResult.explain)."""
        lines = []
        for c in self.candidates:
            lines.append(
                f"{c.desc}: latency={c.latency} "
                + " ".join(f"{k}={c.res[k]:g}" for k in
                           ("bram_bytes", "dsp", "ff_bits"))
                + f" [{c.status or ('ok' if c.within_budget else 'over budget')}]")
        for desc, reason in self.rejections:
            if not any(c.desc == desc for c in self.candidates):
                lines.append(f"{desc}: [{reason}]")
        return "\n".join(lines)


def _budget_key(res: dict[str, float], budget: dict[str, float]) -> bool:
    return all(res.get(k, 0.0) <= v + 1e-9 for k, v in budget.items())


def _unroll_factors_for(p: Program, factors: Sequence[int]) -> list[int]:
    """Factors that partially unroll at least one innermost loop."""
    out = []
    inner = [l for l in p.loops()
             if not any(isinstance(ch, Loop) for ch in l.body)]
    for f in factors:
        if any(l.trip % f == 0 and l.trip // f >= 1 and not l.unroll
               for l in inner):
            out.append(f)
    return out


def _tile_moves(p: Program, sizes: Sequence[int]) -> list[LoopTile]:
    """One tiling move per size, strip-mining every top-level loop it
    divides (order-preserving, so always legal)."""
    moves = []
    tops = [it for it in p.body if isinstance(it, Loop)
            and it.tile_block is None]  # don't re-strip an existing tile
    for s in sizes:
        cfg = {l.ivname: s for l in tops if l.trip % s == 0 and l.trip // s >= 2}
        if cfg:
            moves.append(LoopTile(cfg))
    return moves


def measure_candidate(p: Program, desc: str, passes: Sequence[Pass], *,
                      base: Optional[Program] = None,
                      base_passes: Sequence[Pass] = (),
                      verify: bool = True, seeds: Sequence[int] = (0,),
                      mode: str = "ours",
                      incremental: bool = True) -> Optional[DSECandidate]:
    """Apply ``passes`` on top of ``base`` (an already-verified
    intermediate, default the original program ``p``), compile, and cost.
    Incremental composition does not re-apply and re-verify the whole
    pipeline prefix — equivalence to ``p`` is transitive through the
    verified base.

    Returns None for a no-op: under ``incremental=True`` (the DSE's
    one-move-at-a-time composition) when the NEWEST move applied nothing —
    the result would duplicate an already-measured candidate; under
    ``incremental=False`` (a caller-specified fixed pipeline) only when
    the WHOLE pipeline applied nothing — a fixed pipeline whose last pass
    happens not to fire must still yield the earlier passes' design."""
    from .dataflow import resources

    start = base if base is not None else p
    pm = PassManager(passes, verify=verify, seeds=seeds)
    q = pm.run(start)
    if passes and (q is start or
                   (incremental and not pm.reports[-1].changed)):
        return None
    s = compile_program(q)
    res = resources(q, s, mode)
    return DSECandidate(
        desc=desc or "baseline", passes=tuple(base_passes) + tuple(passes),
        program=q, schedule=s, latency=s.completion_time(), res=res,
        within_budget=True)


def validate_candidate(c: DSECandidate, seeds: Sequence[int] = (0,)) -> None:
    """Brute-force oracles for a DSE winner: ``validate_schedule`` plus
    ``timed_exec`` vs ``sequential_exec`` (small programs only — this
    enumerates dynamic instances).  Raises AssertionError explicitly so the
    check survives ``python -O``."""
    from .sim import (make_inputs, sequential_exec, timed_exec,
                      validate_schedule)
    violations = validate_schedule(c.program, c.schedule)
    if violations:
        raise AssertionError(
            f"DSE winner '{c.desc}' fails validate_schedule: "
            f"{violations[:5]}")
    import numpy as np
    inp = make_inputs(c.program, seeds[0])
    got = timed_exec(c.program, c.schedule, inp)
    want = sequential_exec(c.program, inp)
    for k in want:
        if not np.allclose(got[k], want[k], rtol=1e-12, atol=0):
            raise AssertionError(
                f"DSE winner '{c.desc}': timed_exec differs from "
                f"sequential_exec on array {k}")


# Move families the search can draw from (SearchConfig.moves selects a
# subset — e.g. the Pallas stencil sweep excludes "partition", a knob the
# kernel's VMEM line buffer cannot express).
MOVE_FAMILIES = ("fuse", "partition", "unroll", "tile")


def _single_moves(p: Program, families: Sequence[str],
                  unroll_factors: Sequence[int],
                  tile_sizes: Sequence[int]) -> list[tuple[str, Pass]]:
    moves: list[tuple[str, Pass]] = []
    unknown = set(families) - set(MOVE_FAMILIES)
    if unknown:
        raise ValueError(f"unknown move families {sorted(unknown)}; "
                         f"valid: {MOVE_FAMILIES}")
    if "fuse" in families:
        # shift-and-peel fusion (mismatched bounds fuse too) plus the
        # equal-bounds-only variant: peeling trades prologue nests for core
        # overlap, which is not always the latency winner — enumerate both
        moves += [("fuse", FuseProducerConsumer()),
                  ("fuse(noshift)", FuseProducerConsumer(enable_shift=False))]
    if "partition" in families:
        moves.append(("partition", ArrayPartition()))
    if "unroll" in families:
        moves += [(f"unroll(x{f})", LoopUnroll(f))
                  for f in _unroll_factors_for(p, unroll_factors)]
    if "tile" in families:
        moves += [(t.name, t) for t in _tile_moves(p, tile_sizes)]
    return moves


@dataclass
class ParetoResult:
    """Output of the Pareto-frontier DSE (wrapped by hls.CompileResult)."""

    baseline: DSECandidate
    frontier: list[DSECandidate]            # feasible + non-dominated
    candidates: list[DSECandidate]          # every compiled design point
    rejected: list[tuple[str, str]]         # (desc, reason) — capacity etc.
    caps: dict[str, float]                  # resolved absolute ceilings
    compiles: int


def pareto_explore(p: Program, *,
                   caps: Optional[dict[str, float]] = None,
                   rel_caps: Optional[dict[str, float]] = None,
                   moves: Sequence[str] = MOVE_FAMILIES,
                   unroll_factors: Sequence[int] = (2, 4),
                   tile_sizes: Sequence[int] = (4,),
                   max_candidates: int = 24,
                   verify: bool = True,
                   seeds: Sequence[int] = (0,),
                   mode: str = "ours",
                   verbose: bool = False) -> ParetoResult:
    """Pareto-frontier DSE over transform pipelines (DESIGN.md §6).

    Maintains a dominance-pruned archive over the objective space
    ``PARETO_METRICS`` = (latency, bram_bytes, dsp, ff_bits) and expands it
    frontier-first: the still-unexpanded archive member with the lowest
    latency gets every applicable single move appended; children that
    survive capacity checks and dominance pruning join the archive and the
    expansion queue.  The search stops when the archive has no unexpanded
    member or ``max_candidates`` compilations were spent.

    ``caps`` are absolute resource ceilings, ``rel_caps`` scale the
    BASELINE's own usage (``{"bram_bytes": 1.0}`` = iso-BRAM); violating
    candidates are recorded (with the violated capacities as their reject
    reason) but never enter the archive.  Dominated candidates stay in
    ``candidates`` with a ``dominated by <desc>`` status — that record is
    what ``CompileResult.explain()`` prints.
    """
    from .dataflow import RESOURCE_KEYS

    caps = dict(caps or {})
    unknown = (set(caps) | set(rel_caps or {})) - set(RESOURCE_KEYS)
    if unknown:
        raise ValueError(f"unknown capacity resource(s) {sorted(unknown)}; "
                         f"valid keys: {sorted(RESOURCE_KEYS)}")

    baseline = measure_candidate(p, "baseline", [], verify=verify,
                                 seeds=seeds, mode=mode)
    for k, scale in (rel_caps or {}).items():
        ceil = scale * baseline.res[k]
        caps[k] = min(caps.get(k, ceil), ceil)

    def fits(c: DSECandidate) -> list[str]:
        return c.res.violations(caps)

    baseline.within_budget = not fits(baseline)
    baseline.status = "baseline"
    candidates = [baseline]
    rejected: list[tuple[str, str]] = []
    archive: list[DSECandidate] = [baseline] if baseline.within_budget else []
    if not archive:
        rejected.append((baseline.desc,
                         "over budget: " + "; ".join(fits(baseline))))
    queue: list[DSECandidate] = [baseline]  # expand even an infeasible root
    seen_descs = {"baseline"}
    compiles = 1
    base_moves = _single_moves(p, moves, unroll_factors, tile_sizes)

    def insert(c: DSECandidate) -> None:
        """Capacity check + dominance-pruned archive insertion."""
        viol = fits(c)
        if viol:
            c.within_budget = False
            c.status = "over budget: " + "; ".join(viol)
            rejected.append((c.desc, c.status))
            return
        vec = c.objectives()
        for a in archive:
            avec = a.objectives()
            if dominates(avec, vec) or avec == vec:
                c.status = f"dominated by {a.desc}"
                return
        newly_dominated = [a for a in archive
                           if dominates(vec, a.objectives())]
        for a in newly_dominated:
            a.status = f"dominated by {c.desc}"
            if a in queue:
                queue.remove(a)
        archive[:] = [a for a in archive if a not in newly_dominated]
        archive.append(c)
        c.status = "frontier"
        queue.append(c)

    while queue and compiles < max_candidates:
        # frontier-first: expand the most promising (lowest-latency)
        # non-dominated point next
        queue.sort(key=lambda c: (c.latency, c.res["bram_bytes"]))
        cur = queue.pop(0)
        base_descs = cur.desc.split(" | ") if cur.passes else []
        # tile moves are re-derived from the expansion base: fusion renames
        # loops, so tiling the *fused* nest (the knob the Pallas kernel
        # layer reads as its block size) is only reachable this way
        level_moves = base_moves
        if "tile" in moves:
            level_moves = base_moves + [
                (t.name, t) for t in _tile_moves(cur.program, tile_sizes)
                if t.name not in {d for d, _ in base_moves}]
        for desc, mv in level_moves:
            if desc in base_descs:
                continue
            full = " | ".join(base_descs + [desc])
            if full in seen_descs:
                continue
            if compiles >= max_candidates:
                break
            seen_descs.add(full)
            c = measure_candidate(p, full, [mv], base=cur.program,
                                  base_passes=cur.passes, verify=verify,
                                  seeds=seeds, mode=mode)
            if c is None:
                continue  # the move applied nothing
            compiles += 1
            candidates.append(c)
            insert(c)
            if verbose:
                print(f"  dse: {full}: latency={c.latency} res={dict(c.res)} "
                      f"[{c.status}]")

    frontier = sorted(archive, key=lambda c: c.objectives())
    return ParetoResult(baseline=baseline, frontier=frontier,
                        candidates=candidates, rejected=rejected,
                        caps=caps, compiles=compiles)


# ---------------------------------------------------------------------------
# The pre-Pareto greedy driver, kept verbatim as the no-regression oracle:
# benchmarks/run.py pareto and tests/test_api.py compare every new frontier
# against this single-frontier hill climb's winner.
# ---------------------------------------------------------------------------


def _greedy_explore(p: Program, budget: Optional[dict[str, float]] = None, *,
                    unroll_factors: Sequence[int] = (2, 4),
                    tile_sizes: Sequence[int] = (4,),
                    max_candidates: int = 24,
                    verify: bool = True,
                    validate: bool = False,
                    seeds: Sequence[int] = (0,),
                    verbose: bool = False) -> DSEResult:
    """Greedy single-frontier resource-aware DSE (the old ``explore``).

    ``budget=None`` means iso-resource (baseline BRAM/DSP as ceilings);
    search = every single move, then greedy composition on top of the best
    within-budget candidate, bounded by ``max_candidates`` compilations.
    """
    def measure(desc, passes, base=None, base_passes=()):
        return measure_candidate(p, desc, passes, base=base,
                                 base_passes=base_passes, verify=verify,
                                 seeds=seeds)

    baseline = measure("baseline", [])
    if budget is None:
        budget = {"bram_bytes": baseline.res["bram_bytes"],
                  "dsp": baseline.res["dsp"]}
    budget = dict(budget)
    unknown = set(budget) - set(baseline.res)
    if unknown:
        raise ValueError(
            f"unknown budget resource(s) {sorted(unknown)}; "
            f"valid keys: {sorted(baseline.res)}")
    baseline.within_budget = _budget_key(baseline.res, budget)

    moves = _single_moves(p, MOVE_FAMILIES, unroll_factors, tile_sizes)
    candidates: list[DSECandidate] = [baseline]
    seen_descs = {"baseline"}
    compiles = 1

    def try_pipeline(descs, passes, base=None, base_passes=()):
        nonlocal compiles
        desc = " | ".join(descs)
        if desc in seen_descs or compiles >= max_candidates:
            return None
        seen_descs.add(desc)
        c = measure(desc, passes, base=base, base_passes=base_passes)
        if c is not None:
            compiles += 1  # only actual compilations count against the cap
            c.within_budget = _budget_key(c.res, budget)
            candidates.append(c)
            if verbose:
                print(f"  dse: {desc}: latency={c.latency} res={c.res} "
                      f"{'OK' if c.within_budget else 'OVER-BUDGET'}")
        return c

    for desc, mv in moves:
        try_pipeline([desc], [mv])

    def best_of(cands):
        ok = [c for c in cands if c.within_budget]
        pool = ok or cands
        return min(pool, key=lambda c: (c.latency, c.res["bram_bytes"],
                                        c.res["dsp"], c.res["ff_bits"]))

    frontier = best_of(candidates)
    while compiles < max_candidates:
        base_descs = frontier.desc.split(" | ") if frontier.passes else []
        level_moves = moves + [
            (t.name, t) for t in _tile_moves(frontier.program, tile_sizes)
            if t.name not in {d for d, _ in moves}]
        for desc, mv in level_moves:
            if desc not in base_descs:
                try_pipeline(base_descs + [desc], [mv],
                             base=frontier.program,
                             base_passes=frontier.passes)
        nxt = best_of(candidates)
        if nxt is frontier:
            break
        frontier = nxt

    best = best_of(candidates)
    if validate:
        validate_candidate(best, seeds)
    return DSEResult(baseline=baseline, best=best, candidates=candidates,
                     budget=budget)

"""The paper's benchmark suite (§5.1) as affine programs.

  * unsharp mask      — 32x32 patch, blur-x/blur-y/sharpen/mask (4 nests)
  * harris corners    — 32x32, gradients + windowed sums + response (6 nests)
  * DUS               — 32x32 down-then-up-sample, 4 nests, the Vitis killer
                        (window reads ==> read order != write order)
  * optical flow      — 32x32 Lucas-Kanade single scale (9 nests)
  * 2mm               — 8x8 polybench, intermediate written to a function arg
  * fig1 conv chain   — the paper's motivating example
  * fig3 conv1d       — the paper's scheduling example (II must be 7)

Image arrays are completely partitioned (both dims) which is the paper's
supported ``array_partition`` mode; weights are folded constants (as a
``bind_op``-style simplification).  Op latencies are the paper's
(fp add/sub 5, mul 4, ld/st 1).
"""
from __future__ import annotations

from .ir import Program, ProgramBuilder

# Two storage presets:
#  * "reg":  complete partitioning of both dims (register arrays) — every
#    access parallel; the aggressive design point.
#  * "bram": row-partitioned block RAM with one write + three read ports
#    (port replication), the paper-era design point where consumers contend
#    on memory ports and the port pseudo-dependences bite.
_PRESETS = {
    "reg": dict(partition=(0, 1), ports=("w", "r")),
    "bram": dict(partition=(0,), ports=("w", "r", "r", "r")),
}



# ---------------------------------------------------------------------------


def fig3_conv1d() -> Program:
    b = ProgramBuilder("fig3_conv1d")
    b.array("A", (16,), ports=("w", "r"), is_arg=True)
    b.array("B", (17,), ports=("r",), is_arg=True)
    b.array("W", (2,), ports=("r",), is_arg=True)
    with b.loop("i", 0, 16) as i:
        with b.loop("j", 0, 2) as j:
            acc = b.load("A", i)
            x = b.load("B", i + j)
            w = b.load("W", j)
            s = b.add(acc, b.mul(x, w))
            b.store("A", s, i)
    return b.build()


def fig1_conv_chain(n: int = 8, storage: str = "reg") -> Program:
    """Two chained 2x2 convolutions (the paper's Fig. 1)."""
    b = ProgramBuilder("fig1_conv_chain")
    b.array("image", (n + 2, n + 2), is_arg=True, **_PRESETS[storage])
    b.array("convX", (n + 1, n + 1), **_PRESETS[storage])
    b.array("convY", (n, n), is_arg=True, **_PRESETS[storage])
    w = [[0.25, 0.5], [0.125, 0.0625]]
    for src, dst, tag, extent in (("image", "convX", "p", n + 1),
                                  ("convX", "convY", "c", n)):
        with b.loop(f"{tag}i", 0, extent) as i:
            with b.loop(f"{tag}j", 0, extent) as j:
                prods = []
                for u in range(2):
                    for v in range(2):
                        x = b.load(src, i + u, j + v)
                        prods.append(b.mul(x, b.const(w[u][v])))
                b.store(dst, b.sum_tree(prods), i, j)
    return b.build()


# ---------------------------------------------------------------------------
# benchmark helpers
# ---------------------------------------------------------------------------


def _stencil3x3(b, tag, dst, srcs, weights, H, W, combine="sum"):
    """dst[i][j] = sum_{u,v} w[u][v] * prod(srcs at [i+u][j+v])."""
    with b.loop(f"{tag}i", 0, H) as i:
        with b.loop(f"{tag}j", 0, W) as j:
            prods = []
            for u in range(3):
                for v in range(3):
                    if weights[u][v] == 0.0:
                        continue
                    vals = [b.load(s, i + u, j + v) for s in srcs]
                    term = vals[0]
                    for extra in vals[1:]:
                        term = b.mul(term, extra)
                    if weights[u][v] != 1.0:
                        term = b.mul(term, b.const(weights[u][v]))
                    prods.append(term)
            b.store(dst, b.sum_tree(prods), i, j)


_BOX = [[1.0] * 3 for _ in range(3)]
_GAUSS = [[0.0625, 0.125, 0.0625], [0.125, 0.25, 0.125], [0.0625, 0.125, 0.0625]]


def unsharp(n: int = 32, storage: str = "reg") -> Program:
    b = ProgramBuilder("unsharp")
    b.array("img", (n + 2, n + 2), is_arg=True, **_PRESETS[storage])
    b.array("bx", (n + 2, n), **_PRESETS[storage])          # blur-x (rows keep padding)
    b.array("by", (n, n), **_PRESETS[storage])
    b.array("sharp", (n, n), **_PRESETS[storage])
    b.array("out", (n, n), is_arg=True, **_PRESETS[storage])
    # blur-x: 3-tap along columns
    with b.loop("bxi", 0, n + 2) as i:
        with b.loop("bxj", 0, n) as j:
            t = [b.mul(b.load("img", i, j + v), b.const(c))
                 for v, c in ((0, 0.25), (1, 0.5), (2, 0.25))]
            b.store("bx", b.sum_tree(t), i, j)
    # blur-y: 3-tap along rows
    with b.loop("byi", 0, n) as i:
        with b.loop("byj", 0, n) as j:
            t = [b.mul(b.load("bx", i + u, j), b.const(c))
                 for u, c in ((0, 0.25), (1, 0.5), (2, 0.25))]
            b.store("by", b.sum_tree(t), i, j)
    # sharpen: (1+w)*img - w*blur   (pointwise, img is a second consumer)
    with b.loop("shi", 0, n) as i:
        with b.loop("shj", 0, n) as j:
            o = b.load("img", i + 1, j + 1)
            g = b.load("by", i, j)
            s = b.sub(b.mul(o, b.const(1.6)), b.mul(g, b.const(0.6)))
            b.store("sharp", s, i, j)
    # mask: out = img + k*(sharp - img)   (multi-consumer on img and sharp)
    with b.loop("mki", 0, n) as i:
        with b.loop("mkj", 0, n) as j:
            o = b.load("img", i + 1, j + 1)
            s = b.load("sharp", i, j)
            d = b.sub(s, o)
            b.store("out", b.add(o, b.mul(d, b.const(0.8))), i, j)
    return b.build()


def harris(n: int = 32, storage: str = "reg") -> Program:
    b = ProgramBuilder("harris")
    b.array("img", (n + 4, n + 4), is_arg=True, **_PRESETS[storage])
    b.array("Ix", (n + 2, n + 2), **_PRESETS[storage])
    b.array("Iy", (n + 2, n + 2), **_PRESETS[storage])
    b.array("Sxx", (n, n), **_PRESETS[storage])
    b.array("Syy", (n, n), **_PRESETS[storage])
    b.array("Sxy", (n, n), **_PRESETS[storage])
    b.array("R", (n, n), is_arg=True, **_PRESETS[storage])
    # gradients (central difference)
    for tag, dst, (du, dv) in (("gx", "Ix", (0, 1)), ("gy", "Iy", (1, 0))):
        with b.loop(f"{tag}i", 0, n + 2) as i:
            with b.loop(f"{tag}j", 0, n + 2) as j:
                p = b.load("img", i + 1 + du, j + 1 + dv)
                m = b.load("img", i + 1 - du, j + 1 - dv)
                b.store(dst, b.mul(b.sub(p, m), b.const(0.5)), i, j)
    # structure tensor: 3x3 window sums of products (multi-consumer Ix, Iy)
    _stencil3x3(b, "sxx", "Sxx", ["Ix", "Ix"], _BOX, n, n)
    _stencil3x3(b, "syy", "Syy", ["Iy", "Iy"], _BOX, n, n)
    _stencil3x3(b, "sxy", "Sxy", ["Ix", "Iy"], _BOX, n, n)
    # response R = det - k * trace^2
    with b.loop("ri", 0, n) as i:
        with b.loop("rj", 0, n) as j:
            xx = b.load("Sxx", i, j)
            yy = b.load("Syy", i, j)
            xy = b.load("Sxy", i, j)
            det = b.sub(b.mul(xx, yy), b.mul(xy, xy))
            tr = b.add(xx, yy)
            r = b.sub(det, b.mul(b.mul(tr, tr), b.const(0.04)))
            b.store("R", r, i, j)
    return b.build()


def dus(n: int = 32, storage: str = "reg") -> Program:
    """Downsample (blur + decimate) then upsample (linear interp), per axis.
    Four loop nests; the window reads break Vitis' same-order rule."""
    b = ProgramBuilder("dus")
    h = n // 2
    b.array("img", (n + 3, n + 3), is_arg=True, **_PRESETS[storage])
    b.array("dx", (n + 3, h + 1), **_PRESETS[storage])   # downsampled along x
    b.array("d", (h + 1, h + 1), **_PRESETS[storage])    # downsampled both axes
    b.array("uy", (n, h + 1), **_PRESETS[storage])       # upsampled along y
    b.array("out", (n, n), is_arg=True, partition=(0, 1), ports=("w",))
    # down-x: dx[i][j] = 0.25*img[i][2j] + 0.5*img[i][2j+1] + 0.25*img[i][2j+2]
    with b.loop("dxi", 0, n + 3) as i:
        with b.loop("dxj", 0, h + 1) as j:
            t = [b.mul(b.load("img", i, j * 2 + v), b.const(c))
                 for v, c in ((0, 0.25), (1, 0.5), (2, 0.25))]
            b.store("dx", b.sum_tree(t), i, j)
    # down-y
    with b.loop("dyi", 0, h + 1) as i:
        with b.loop("dyj", 0, h + 1) as j:
            t = [b.mul(b.load("dx", i * 2 + u, j), b.const(c))
                 for u, c in ((0, 0.25), (1, 0.5), (2, 0.25))]
            b.store("d", b.sum_tree(t), i, j)
    # up-y: even rows copy, odd rows interpolate (two affine stores)
    with b.loop("uyi", 0, h) as i:
        with b.loop("uyj", 0, h + 1) as j:
            a = b.load("d", i, j)
            c = b.load("d", i + 1, j)
            b.store("uy", a, i * 2, j)
            b.store("uy", b.mul(b.add(a, c), b.const(0.5)), i * 2 + 1, j)
    # up-x
    with b.loop("uxi", 0, n) as i:
        with b.loop("uxj", 0, h) as j:
            a = b.load("uy", i, j)
            c = b.load("uy", i, j + 1)
            b.store("out", a, i, j * 2)
            b.store("out", b.mul(b.add(a, c), b.const(0.5)), i, j * 2 + 1)
    return b.build()


def optical_flow(n: int = 32, storage: str = "reg") -> Program:
    """Lucas-Kanade dense optical flow, single scale (§5.1)."""
    b = ProgramBuilder("optical_flow")
    b.array("f1", (n + 4, n + 4), is_arg=True, **_PRESETS[storage])
    b.array("f2", (n + 4, n + 4), is_arg=True, **_PRESETS[storage])
    for nm in ("Ix", "Iy", "It"):
        b.array(nm, (n + 2, n + 2), **_PRESETS[storage])
    for nm in ("Sxx", "Syy", "Sxy", "Sxt", "Syt"):
        b.array(nm, (n, n), **_PRESETS[storage])
    b.array("u", (n, n), is_arg=True, **_PRESETS[storage])
    b.array("v", (n, n), is_arg=True, **_PRESETS[storage])
    # gradients on frame 1 + temporal difference
    for tag, dst, (du, dv) in (("gx", "Ix", (0, 1)), ("gy", "Iy", (1, 0))):
        with b.loop(f"{tag}i", 0, n + 2) as i:
            with b.loop(f"{tag}j", 0, n + 2) as j:
                p = b.load("f1", i + 1 + du, j + 1 + dv)
                m = b.load("f1", i + 1 - du, j + 1 - dv)
                b.store(dst, b.mul(b.sub(p, m), b.const(0.5)), i, j)
    with b.loop("gti", 0, n + 2) as i:
        with b.loop("gtj", 0, n + 2) as j:
            a = b.load("f2", i + 1, j + 1)
            c = b.load("f1", i + 1, j + 1)
            b.store("It", b.sub(a, c), i, j)
    # window sums (products folded into the window nests; multi-consumer)
    _stencil3x3(b, "sxx", "Sxx", ["Ix", "Ix"], _BOX, n, n)
    _stencil3x3(b, "syy", "Syy", ["Iy", "Iy"], _BOX, n, n)
    _stencil3x3(b, "sxy", "Sxy", ["Ix", "Iy"], _BOX, n, n)
    _stencil3x3(b, "sxt", "Sxt", ["Ix", "It"], _BOX, n, n)
    _stencil3x3(b, "syt", "Syt", ["Iy", "It"], _BOX, n, n)
    # solve the 2x2 system per pixel
    with b.loop("svi", 0, n) as i:
        with b.loop("svj", 0, n) as j:
            xx = b.load("Sxx", i, j)
            yy = b.load("Syy", i, j)
            xy = b.load("Sxy", i, j)
            xt = b.load("Sxt", i, j)
            yt = b.load("Syt", i, j)
            det = b.sub(b.mul(xx, yy), b.mul(xy, xy))
            un = b.sub(b.mul(xy, yt), b.mul(yy, xt))
            vn = b.sub(b.mul(xy, xt), b.mul(xx, yt))
            b.store("u", b.div(un, det), i, j)
            b.store("v", b.div(vn, det), i, j)
    return b.build()


def two_mm(m: int = 8, storage: str = "reg") -> Program:
    """tmp = A@B ; D = tmp@C — both written to function arguments, so Vitis
    dataflow is inapplicable even after SPSC conversion (§5.2)."""
    b = ProgramBuilder("two_mm")
    b.array("A", (m, m), is_arg=True, ports=("r", "r"))
    b.array("B", (m, m), is_arg=True, ports=("r", "r"))
    b.array("C", (m, m), is_arg=True, ports=("r", "r"))
    b.array("tmp", (m, m), is_arg=True, ports=("w", "r"))   # pre-zeroed arg
    b.array("D", (m, m), is_arg=True, ports=("w", "r"))     # pre-zeroed arg
    for tag, (x, w, dst) in (("p", ("A", "B", "tmp")), ("c", ("tmp", "C", "D"))):
        with b.loop(f"{tag}i", 0, m) as i:
            with b.loop(f"{tag}j", 0, m) as j:
                with b.loop(f"{tag}k", 0, m) as k:
                    acc = b.load(dst, i, j)
                    prod = b.mul(b.load(x, i, k), b.load(w, k, j))
                    b.store(dst, b.add(acc, prod), i, j)
    return b.build()


# ---------------------------------------------------------------------------
# Mismatched-bounds producer-consumer chains (shift-and-peel fusion targets).
#
# Each is a two-nest chain whose consumer nest has strictly smaller (or
# stride-scaled) bounds than its producer, so equal-bounds fusion cannot
# apply — the shapes the paper's Fig. 1-3 motivating example is made of.
# ---------------------------------------------------------------------------


def blur_chain(n: int = 32, storage: str = "reg", taps: int = 3) -> Program:
    """blur-x -> blur-y (the paper's motivating stencil chain): the producer
    covers ``n + taps - 1`` rows, the consumer ``n`` — fusing needs a
    consumer shift of ``taps - 1`` rows and a peeled prologue."""
    b = ProgramBuilder("blur_chain")
    m = n + taps - 1
    w = [1.0 / (2 ** abs(t - (taps - 1) // 2) + 1) for t in range(taps)]
    b.array("img", (m, m), is_arg=True, **_PRESETS[storage])
    b.array("bx", (m, n), **_PRESETS[storage])
    b.array("by", (n, n), is_arg=True, **_PRESETS[storage])
    with b.loop("bxi", 0, m) as i:
        with b.loop("bxj", 0, n) as j:
            t = [b.mul(b.load("img", i, j + v), b.const(w[v]))
                 for v in range(taps)]
            b.store("bx", b.sum_tree(t), i, j)
    with b.loop("byi", 0, n) as i:
        with b.loop("byj", 0, n) as j:
            t = [b.mul(b.load("bx", i + u, j), b.const(w[u]))
                 for u in range(taps)]
            b.store("by", b.sum_tree(t), i, j)
    return b.build()


def conv_pool(n: int = 32, storage: str = "reg") -> Program:
    """3x3 conv then 2x2 max-pool (stride 2): the consumer runs at HALF the
    producer's rate (index coefficient 2), so the legal shift is n/2 and the
    fused core interleaves one pool row per conv row."""
    assert n % 2 == 0, n
    h = n // 2
    b = ProgramBuilder("conv_pool")
    b.array("img", (n + 2, n + 2), is_arg=True, **_PRESETS[storage])
    b.array("conv", (n, n), **_PRESETS[storage])
    b.array("pool", (h, h), is_arg=True, **_PRESETS[storage])
    _stencil3x3(b, "cv", "conv", ["img"], _GAUSS, n, n)
    with b.loop("pli", 0, h) as i:
        with b.loop("plj", 0, h) as j:
            vals = [b.load("conv", i * 2 + u, j * 2 + v)
                    for u in range(2) for v in range(2)]
            m = vals[0]
            for v in vals[1:]:
                m = b.arith("max", m, v)
            b.store("pool", m, i, j)
    return b.build()


def gradient_harris(n: int = 32, storage: str = "reg") -> Program:
    """Gradient field then a 3x3-window Harris-style response: the gradient
    nest covers ``(n+2)^2``, the response ``n^2`` — a two-level shift of
    (2, 2) with peeled prologues at both levels."""
    b = ProgramBuilder("gradient_harris")
    b.array("img", (n + 4, n + 4), is_arg=True, **_PRESETS[storage])
    b.array("G", (n + 2, n + 2), **_PRESETS[storage])
    b.array("R", (n, n), is_arg=True, **_PRESETS[storage])
    with b.loop("gi", 0, n + 2) as i:
        with b.loop("gj", 0, n + 2) as j:
            gx = b.sub(b.load("img", i + 1, j + 2), b.load("img", i + 1, j))
            gy = b.sub(b.load("img", i + 2, j + 1), b.load("img", i, j + 1))
            b.store("G", b.mul(b.add(gx, gy), b.const(0.5)), i, j)
    with b.loop("ri", 0, n) as i:
        with b.loop("rj", 0, n) as j:
            terms = [b.load("G", i + u, j + v)
                     for u in range(3) for v in range(3)]
            s = b.sum_tree(terms)
            q = b.sum_tree([b.mul(t, t) for t in terms])
            b.store("R", b.sub(q, b.mul(b.mul(s, s), b.const(0.04))), i, j)
    return b.build()


def correlated_chain(n: int = 32, storage: str = "reg") -> Program:
    """Producer/consumer with CORRELATED access distances: the consumer
    reads ``mid`` at (i+2, j) and (i, j+5), so the dependence-distance
    vectors are (2, 0) and (0, 5).  The lexicographic-minimum legal shift
    is their lex-maximum (2, 0); per-level componentwise maxima would
    overshoot to (2, 5), delaying every row by five columns and peeling
    five producer columns per row for nothing — the regression this chain
    pins (ROADMAP: lexicographic-minimum fusion shift)."""
    b = ProgramBuilder("correlated_chain")
    b.array("img", (n + 3, n + 6), is_arg=True, **_PRESETS[storage])
    b.array("mid", (n + 2, n + 5), **_PRESETS[storage])
    b.array("out", (n, n), is_arg=True, **_PRESETS[storage])
    with b.loop("mi", 0, n + 2) as i:
        with b.loop("mj", 0, n + 5) as j:
            v = b.add(b.load("img", i, j), b.load("img", i + 1, j + 1))
            b.store("mid", b.mul(v, b.const(0.5)), i, j)
    with b.loop("oi", 0, n) as i:
        with b.loop("oj", 0, n) as j:
            a = b.load("mid", i + 2, j)
            c = b.load("mid", i, j + 5)
            d = b.sub(b.mul(a, b.const(0.75)), b.mul(c, b.const(0.25)))
            b.store("out", d, i, j)
    return b.build()


BENCHMARKS = {
    "unsharp": unsharp,
    "harris": harris,
    "dus": dus,
    "optical_flow": optical_flow,
    "two_mm": two_mm,
}

# Mismatched-bounds stencil chains: the shift-and-peel fusion benchmark set
# (kept out of BENCHMARKS so the paper-figure tables stay comparable across
# PRs; benchmarks/run.py records them in BENCH_fusion.json).
CHAIN_BENCHMARKS = {
    "blur_chain": blur_chain,
    "conv_pool": conv_pool,
    "gradient_harris": gradient_harris,
    "correlated_chain": correlated_chain,
}

# The paper's primary contribution: an ILP-based HLS scheduler performing
# multi-dimensional (intra-loop + producer-consumer) pipelining, plus its
# applications inside the JAX framework (pipeline-parallel schedule synthesis,
# collective/compute overlap, Pallas line-buffer sizing).
from .ir import (AffExpr, ArrayDecl, ArithOp, ConstOp, LoadOp, Loop, Program,
                 ProgramBuilder, StoreOp, aff, iv, normalize)
from .ilp import solve_ilp, solve_lp, brute_force_ilp
from .deps import DepAnalysis, DepEdge
from .scheduler import Schedule, schedule, feasible, emit_hir
from .autotune import autotune, compile_program

__all__ = [
    "AffExpr", "ArrayDecl", "ArithOp", "ConstOp", "LoadOp", "Loop", "Program",
    "ProgramBuilder", "StoreOp", "aff", "iv", "normalize",
    "solve_ilp", "solve_lp", "brute_force_ilp",
    "DepAnalysis", "DepEdge", "Schedule", "schedule", "feasible", "emit_hir",
    "autotune", "compile_program",
]

# The paper's primary contribution: an ILP-based HLS scheduler performing
# multi-dimensional (intra-loop + producer-consumer) pipelining, plus its
# applications inside the JAX framework (pipeline-parallel schedule synthesis,
# collective/compute overlap, Pallas line-buffer sizing).
#
# The blessed compilation entry point is the declarative front end
# ``repro.core.hls`` (api.py): ``hls.compile(program, spec)``.  The old
# ``compile_program``/``explore`` names remain importable from this package
# but are deprecated shims — accessing them emits one DeprecationWarning
# (see DESIGN.md §6 MIGRATION).
import warnings as _warnings

from .ir import (AffExpr, ArrayDecl, ArithOp, ConstOp, LoadOp, Loop, Program,
                 ProgramBuilder, StoreOp, aff, iv, normalize)
from .ilp import solve_ilp, solve_lp, brute_force_ilp
from . import faults
from .errors import (CacheFault, CompileError, Diagnostic,
                     NestContractViolation, ScheduleInfeasible,
                     SolverTruncated, StaticValidationError,
                     UnlowerableProgram, UntraceableFunction, WorkerFault)
from .analysis import Verdict, lint, validate_static
from .codegen import PallasKernel, lower_program
from .deps import DepAnalysis, DepEdge
from .scheduler import Schedule, schedule, feasible, emit_hir
from .transforms import (ArrayPartition, FuseProducerConsumer, LoopTile,
                         LoopUnroll, Normalize, Pass, PassManager,
                         PassVerificationError, PASS_TAGS, ToSPSC, TRANSFORMS,
                         differential_check, to_spsc)
from .pipeline_parse import (PipelineSyntaxError, parse_pipeline,
                             print_pipeline)
from .dataflow import ResourceVector
from .cache import (SCHEDULER_SALT, CacheStore, cache_enabled, fingerprint,
                    get_store, pack_schedule, program_text, unpack_schedule)
from .autotune import (DSECandidate, DSEResult, MOVE_FAMILIES, PARETO_METRICS,
                       ParetoResult, autotune, dominates, pareto_explore)
from . import api as hls
from .api import (CompileResult, CompileSpec, Constraint, DesignPoint,
                  Objective, SearchConfig, Target, constraint, minimize)

__all__ = [
    "AffExpr", "ArrayDecl", "ArithOp", "ConstOp", "LoadOp", "Loop", "Program",
    "ProgramBuilder", "StoreOp", "aff", "iv", "normalize",
    "solve_ilp", "solve_lp", "brute_force_ilp",
    "DepAnalysis", "DepEdge", "Schedule", "schedule", "feasible", "emit_hir",
    "Pass", "PassManager", "PassVerificationError", "TRANSFORMS", "PASS_TAGS",
    "Normalize", "LoopUnroll", "LoopTile", "ArrayPartition",
    "FuseProducerConsumer", "ToSPSC", "to_spsc", "differential_check",
    "parse_pipeline", "print_pipeline", "PipelineSyntaxError",
    "ResourceVector", "SCHEDULER_SALT", "CacheStore", "cache_enabled",
    "fingerprint", "get_store", "pack_schedule", "program_text",
    "unpack_schedule",
    "autotune", "DSECandidate", "DSEResult",
    "pareto_explore", "ParetoResult", "dominates", "PARETO_METRICS",
    "MOVE_FAMILIES",
    "hls", "CompileSpec", "CompileResult", "Target", "Objective",
    "Constraint", "constraint", "minimize", "SearchConfig", "DesignPoint",
    "faults", "CompileError", "ScheduleInfeasible", "SolverTruncated",
    "WorkerFault", "CacheFault", "UnlowerableProgram", "UntraceableFunction",
    "NestContractViolation", "Diagnostic", "StaticValidationError",
    "Verdict", "lint", "validate_static",
    "PallasKernel", "lower_program",
    # tracing frontend, served lazily (importing it pulls in jax):
    "trace", "TracedProgram",
    # deprecated shims, served lazily with a DeprecationWarning:
    "compile_program", "explore",
]

_DEPRECATED = {
    "compile_program": "hls.compile(p, pipeline=()).best.schedule",
    "explore": 'hls.compile(p, constraints=("bram <= 1.0x baseline", '
               '"dsp <= 1.0x baseline"))',
}


def __getattr__(name: str):
    """PEP 562 lazy attributes: the deprecated entry points keep working
    (``from repro.core import compile_program, explore``) but warn once per
    import site; internal code imports the primitives from their modules
    directly and never pays the warning."""
    if name in _DEPRECATED:
        _warnings.warn(
            f"repro.core.{name} is deprecated; use repro.core."
            f"{_DEPRECATED[name]} instead (DESIGN.md §6 MIGRATION)",
            DeprecationWarning, stacklevel=2)
        from . import api
        return getattr(api, name)
    if name in ("trace", "TracedProgram"):
        # lazy: the frontend imports jax, which the scheduler-only paths
        # never need to pay for
        from . import frontend
        return getattr(frontend, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

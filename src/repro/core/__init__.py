# The paper's primary contribution: an ILP-based HLS scheduler performing
# multi-dimensional (intra-loop + producer-consumer) pipelining, plus its
# applications inside the JAX framework (pipeline-parallel schedule synthesis,
# collective/compute overlap, Pallas line-buffer sizing).
from .ir import (AffExpr, ArrayDecl, ArithOp, ConstOp, LoadOp, Loop, Program,
                 ProgramBuilder, StoreOp, aff, iv, normalize)
from .ilp import solve_ilp, solve_lp, brute_force_ilp
from .deps import DepAnalysis, DepEdge
from .scheduler import Schedule, schedule, feasible, emit_hir
from .transforms import (ArrayPartition, FuseProducerConsumer, LoopTile,
                         LoopUnroll, Normalize, Pass, PassManager,
                         PassVerificationError, ToSPSC, TRANSFORMS,
                         differential_check, to_spsc)
from .autotune import (DSECandidate, DSEResult, autotune, compile_program,
                       explore)

__all__ = [
    "AffExpr", "ArrayDecl", "ArithOp", "ConstOp", "LoadOp", "Loop", "Program",
    "ProgramBuilder", "StoreOp", "aff", "iv", "normalize",
    "solve_ilp", "solve_lp", "brute_force_ilp",
    "DepAnalysis", "DepEdge", "Schedule", "schedule", "feasible", "emit_hir",
    "Pass", "PassManager", "PassVerificationError", "TRANSFORMS",
    "Normalize", "LoopUnroll", "LoopTile", "ArrayPartition",
    "FuseProducerConsumer", "ToSPSC", "to_spsc", "differential_check",
    "autotune", "compile_program", "explore", "DSECandidate", "DSEResult",
]

"""Deterministic fault-injection harness (DESIGN.md §9).

The solver (`ilp.solve_ilp`), the DSE pool workers (`autotune._measure_worker`)
and the persistent cache (`cache.CacheStore.get/put`) each consult this module
at well-defined fault points.  An active :class:`FaultPlan` decides — purely
from its seed and the *content* of the fault point, never from wall-clock time
or process identity — whether the fault fires.  That makes chaos runs
reproducible: the same plan against the same workload injects the same faults
regardless of scheduling order, worker count, or which process asks.

Activation is process-transitive: :func:`inject` installs the plan in-process
*and* exports it through ``REPRO_HLS_FAULTS`` so fork/spawn pool workers
observe the same plan.

The module also carries a process-local diagnostics stream (:func:`note`):
degradations, retries, quarantines and cache repairs are recorded here and
surfaced on ``CompileResult.diagnostics``.  Events recorded inside a pool
worker travel back to the parent attached to the measured candidate.
"""
from __future__ import annotations

import contextlib
import hashlib
import json
import os
import time
from dataclasses import dataclass
from typing import Iterator, Optional

ENV_VAR = "REPRO_HLS_FAULTS"

#: event kinds that mean "the result may legitimately diverge from a
#: fault-free run" — anything else (retries, repairs, rebuilds) is recovered
#: transparently and must not change the frontier.
DEGRADING_KINDS = frozenset({
    "solver-degraded",
    "fusion-hazard-degraded",
    "dep-distance-degraded",
    "worker-quarantine",
    "compile-error",
    # the static validator could not *prove* the winner safe (truncated
    # emptiness checks, e.g. under injected solver deadlines)
    "validate-unresolved",
})


class InjectedWorkerCrash(RuntimeError):
    """Raised inside a pool worker when the ``worker_crash`` fault fires."""


@dataclass(frozen=True)
class FaultPlan:
    """A seeded, declarative fault schedule.

    Rates are probabilities in [0, 1]; 0 disables a site, 1 always fires.
    ``crash_attempts``/``hang_attempts`` optionally restrict worker faults to
    specific retry attempts (empty = every attempt), which lets tests script
    "fails once, then recovers" deterministically.  ``script`` maps a site
    name to exact per-process call indices and overrides the rate for that
    site entirely.
    """
    seed: int = 0
    solver_timeout: float = 0.0
    worker_crash: float = 0.0
    worker_crash_hard: float = 0.0
    worker_hang: float = 0.0
    cache_corrupt: float = 0.0
    hang_seconds: float = 30.0
    crash_attempts: tuple[int, ...] = ()
    hang_attempts: tuple[int, ...] = ()
    script: tuple[tuple[str, tuple[int, ...]], ...] = ()

    def rate(self, site: str) -> float:
        return float(getattr(self, site, 0.0))

    def to_json(self) -> str:
        return json.dumps({
            "seed": self.seed,
            "solver_timeout": self.solver_timeout,
            "worker_crash": self.worker_crash,
            "worker_crash_hard": self.worker_crash_hard,
            "worker_hang": self.worker_hang,
            "cache_corrupt": self.cache_corrupt,
            "hang_seconds": self.hang_seconds,
            "crash_attempts": list(self.crash_attempts),
            "hang_attempts": list(self.hang_attempts),
            "script": [[s, list(idxs)] for s, idxs in self.script],
        }, separators=(",", ":"))

    @staticmethod
    def from_json(raw: str) -> "FaultPlan":
        d = json.loads(raw)
        return FaultPlan(
            seed=int(d.get("seed", 0)),
            solver_timeout=float(d.get("solver_timeout", 0.0)),
            worker_crash=float(d.get("worker_crash", 0.0)),
            worker_crash_hard=float(d.get("worker_crash_hard", 0.0)),
            worker_hang=float(d.get("worker_hang", 0.0)),
            cache_corrupt=float(d.get("cache_corrupt", 0.0)),
            hang_seconds=float(d.get("hang_seconds", 30.0)),
            crash_attempts=tuple(int(a) for a in d.get("crash_attempts", [])),
            hang_attempts=tuple(int(a) for a in d.get("hang_attempts", [])),
            script=tuple((str(s), tuple(int(i) for i in idxs))
                         for s, idxs in d.get("script", [])),
        )


_ACTIVE: Optional[FaultPlan] = None
_ACTIVE_LOADED = False  # fresh (spawned) processes lazily read ENV_VAR once
_COUNTERS: dict[str, int] = {}
_EVENTS: list[dict] = []
_EVENTS_CAP = 4096


def active() -> Optional[FaultPlan]:
    """The plan in effect for this process, if any."""
    global _ACTIVE, _ACTIVE_LOADED
    if not _ACTIVE_LOADED:
        _ACTIVE_LOADED = True
        raw = os.environ.get(ENV_VAR)
        if raw:
            try:
                _ACTIVE = FaultPlan.from_json(raw)
            except Exception:
                _ACTIVE = None
    return _ACTIVE


@contextlib.contextmanager
def inject(*, seed: int = 0, solver_timeout: float = 0.0,
           worker_crash: float = 0.0, worker_crash_hard: float = 0.0,
           worker_hang: float = 0.0, cache_corrupt: float = 0.0,
           hang_seconds: float = 30.0,
           crash_attempts: tuple[int, ...] = (),
           hang_attempts: tuple[int, ...] = (),
           script: tuple[tuple[str, tuple[int, ...]], ...] = (),
           ) -> Iterator[FaultPlan]:
    """Activate a fault plan for the dynamic extent of the ``with`` block."""
    plan = FaultPlan(seed=seed, solver_timeout=solver_timeout,
                     worker_crash=worker_crash,
                     worker_crash_hard=worker_crash_hard,
                     worker_hang=worker_hang, cache_corrupt=cache_corrupt,
                     hang_seconds=hang_seconds,
                     crash_attempts=tuple(crash_attempts),
                     hang_attempts=tuple(hang_attempts),
                     script=tuple((s, tuple(i)) for s, i in script))
    global _ACTIVE, _ACTIVE_LOADED
    prev, prev_loaded = _ACTIVE, _ACTIVE_LOADED
    prev_env = os.environ.get(ENV_VAR)
    prev_counters = dict(_COUNTERS)
    _ACTIVE, _ACTIVE_LOADED = plan, True
    _COUNTERS.clear()
    os.environ[ENV_VAR] = plan.to_json()
    try:
        yield plan
    finally:
        _ACTIVE, _ACTIVE_LOADED = prev, prev_loaded
        _COUNTERS.clear()
        _COUNTERS.update(prev_counters)
        if prev_env is None:
            os.environ.pop(ENV_VAR, None)
        else:
            os.environ[ENV_VAR] = prev_env


def reset() -> None:
    """Deactivate any plan and clear counters/events (test hygiene)."""
    global _ACTIVE, _ACTIVE_LOADED
    _ACTIVE = None
    _ACTIVE_LOADED = False
    _COUNTERS.clear()
    _EVENTS.clear()


def should_fire(site: str, key: Optional[str] = None) -> bool:
    """Decide whether the fault at ``site`` fires for this consultation.

    With a ``key`` the decision is a pure function of (seed, site, key), so
    identical work items get identical faults in every process.  Without a
    key the per-process call counter stands in.  A ``script`` entry for the
    site overrides the rate with exact call indices.
    """
    plan = active()
    if plan is None:
        return False
    n = _COUNTERS.get(site, 0)
    _COUNTERS[site] = n + 1
    for s, idxs in plan.script:
        if s == site:
            return n in idxs
    rate = plan.rate(site)
    if rate <= 0.0:
        return False
    if rate >= 1.0:
        return True
    tag = key if key is not None else str(n)
    h = hashlib.sha256(f"{plan.seed}|{site}|{tag}".encode()).digest()
    return int.from_bytes(h[:8], "big") / 2.0 ** 64 < rate


def worker_fault_point(desc: str, attempt: int) -> None:
    """Fault point at pool-worker task entry (crash / hard-crash / hang)."""
    plan = active()
    if plan is None:
        return
    key = f"{desc}#a{attempt}"
    if not plan.crash_attempts or attempt in plan.crash_attempts:
        if should_fire("worker_crash_hard", key=key):
            os._exit(3)
        if should_fire("worker_crash", key=key):
            raise InjectedWorkerCrash(
                f"injected worker crash: {desc} (attempt {attempt})")
    if not plan.hang_attempts or attempt in plan.hang_attempts:
        if should_fire("worker_hang", key=key):
            time.sleep(plan.hang_seconds)


def note(kind: str, **info) -> None:
    """Record a diagnostic event in the process-local stream."""
    if len(_EVENTS) >= _EVENTS_CAP:
        del _EVENTS[:_EVENTS_CAP // 2]
    _EVENTS.append({"kind": kind, **info})


def event_count() -> int:
    return len(_EVENTS)


def events_since(mark: int) -> list[dict]:
    return [dict(e) for e in _EVENTS[mark:]]

"""ILP-scheduled compute/communication overlap (DESIGN.md §3).

The ring all-gather matmul in parallel/collective_matmul.py interleaves one
ICI hop with one MXU matmul per step.  Here the interleave is *derived* with
the paper's scheduler: the ICI link and the MXU are single-port memories,
each ring step is one loop iteration whose body sends chunk k (ICI port) and
multiplies chunk k-1 (MXU port, RAW-dependent on the previous receive).  The
scheduler proves II = 1 (send and matmul overlap) — while a naive dependence
chain (gather fully, then multiply) costs II = 2.
"""
from __future__ import annotations

from dataclasses import dataclass

from .autotune import compile_program
from .ir import ProgramBuilder


@dataclass
class OverlapPlan:
    n_steps: int
    ii: int                  # ticks per ring step (1 = fully overlapped)
    latency: int
    serial_latency: int      # gather-then-compute baseline

    @property
    def overlap_speedup(self) -> float:
        return self.serial_latency / self.latency


def plan_ring_overlap(n_steps: int, *, send_ticks: int = 1,
                      mm_ticks: int = 1) -> OverlapPlan:
    b = ProgramBuilder("ring_overlap",
                       op_delays={"mul": 1, "add": 1, "const": 0})
    # single-port resources: the ICI link and the MXU.  Multi-tick sends /
    # matmuls occupy their port for every tick (unit-op chains, same trick
    # as pipeline_ilp).  The CHUNK handoff has wr_latency 0: the transfer
    # time itself is the send chain.
    b.array("CHUNK", (n_steps + 1,), kind="reg", rd_latency=0, wr_latency=0)
    b.array("OUT", (n_steps,), kind="reg", rd_latency=0, wr_latency=1)
    b.array("ICI", (1,), ports=("rw",))
    b.array("MXU", (1,), ports=("rw",))
    with b.loop("k", 0, n_steps) as k:
        c = b.load("CHUNK", k)
        sent = c
        for _ in range(send_ticks):         # ppermute hop (ICI port)
            sent = b.add(sent, b.const(0.0))
            b.store("ICI", sent, 0)
        b.store("CHUNK", sent, k + 1)
        y = c
        for _ in range(mm_ticks):           # matmul on the held chunk (MXU)
            y = b.mul(y, b.const(1.0))
            b.store("MXU", y, 0)
        b.store("OUT", y, k)
    p = b.build()
    s = compile_program(p)
    loop = p.loops()[0]
    ii = s.iis[loop.uid]
    # serial baseline: every send completes before any matmul starts
    serial = n_steps * send_ticks + n_steps * mm_ticks
    return OverlapPlan(n_steps=n_steps, ii=ii, latency=s.completion_time(),
                       serial_latency=serial)

"""Self-contained LP / ILP solvers used by the HLS scheduler.

This container ships no scipy/pulp/ortools, so the paper's two ILP classes
(memory-dependence ILPs and the scheduling ILP) are solved with our own
numpy dense-tableau two-phase simplex (Bland's rule, cycle-safe) wrapped in
a depth-first branch-and-bound for integrality.  Problems are small (tens of
variables); the scheduling system itself is solved as a difference-constraint
graph (see scheduler.py) and only falls back to this LP for the
delay-register-minimization objective.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

TOL = 1e-7


@dataclass
class LPResult:
    status: str  # "optimal" | "infeasible" | "unbounded" | "iteration_limit"
    x: Optional[np.ndarray]
    fun: Optional[float]

    @property
    def ok(self) -> bool:
        return self.status == "optimal"


def _pivot(T: np.ndarray, basis: list[int], row: int, col: int) -> None:
    T[row] = T[row] / T[row, col]
    factor = T[:, col].copy()
    factor[row] = 0.0
    T -= np.outer(factor, T[row])
    # outer-product update can leave tiny residue in the pivot column
    T[:, col] = 0.0
    T[row, col] = 1.0
    basis[row] = col


def _simplex_core(T: np.ndarray, basis: list[int], c_full: np.ndarray,
                  maxiter: int) -> str:
    """Primal simplex on tableau T (m x (n+1), RHS in last column).

    ``basis`` holds the basic column of each row and is updated in place.
    Bland's rule (lowest-index entering / leaving) guarantees termination.
    """
    m, ncols = T.shape
    n = ncols - 1
    for _ in range(maxiter):
        cB = c_full[basis]
        reduced = c_full[:n] - cB @ T[:, :n]
        candidates = np.where(reduced < -TOL)[0]
        if candidates.size == 0:
            return "optimal"
        enter = int(candidates[0])  # Bland: lowest index
        col = T[:, enter]
        pos = np.where(col > TOL)[0]
        if pos.size == 0:
            return "unbounded"
        ratios = T[pos, n] / col[pos]
        best = ratios.min()
        ties = pos[np.where(ratios <= best + 1e-12)[0]]
        # Bland: leave the basic variable with the lowest index
        leave_row = int(ties[np.argmin(np.asarray(basis)[ties])])
        _pivot(T, basis, leave_row, enter)
    return "iteration_limit"


def solve_lp(c: Sequence[float],
             A_ub: Optional[np.ndarray] = None,
             b_ub: Optional[np.ndarray] = None,
             A_eq: Optional[np.ndarray] = None,
             b_eq: Optional[np.ndarray] = None,
             maxiter: int = 50000) -> LPResult:
    """minimize c@x  s.t.  A_ub@x <= b_ub,  A_eq@x == b_eq,  x >= 0."""
    c = np.asarray(c, dtype=np.float64)
    n = c.shape[0]
    rows = []
    rhs = []
    kinds = []  # "ub" | "eq"
    if A_ub is not None and len(A_ub):
        A_ub = np.asarray(A_ub, dtype=np.float64).reshape(-1, n)
        b_ub = np.asarray(b_ub, dtype=np.float64).ravel()
        for i in range(A_ub.shape[0]):
            rows.append(A_ub[i])
            rhs.append(b_ub[i])
            kinds.append("ub")
    if A_eq is not None and len(A_eq):
        A_eq = np.asarray(A_eq, dtype=np.float64).reshape(-1, n)
        b_eq = np.asarray(b_eq, dtype=np.float64).ravel()
        for i in range(A_eq.shape[0]):
            rows.append(A_eq[i])
            rhs.append(b_eq[i])
            kinds.append("eq")
    m = len(rows)
    if m == 0:
        # unconstrained besides x >= 0
        if np.any(c < -TOL):
            return LPResult("unbounded", None, None)
        return LPResult("optimal", np.zeros(n), 0.0)

    A = np.asarray(rows, dtype=np.float64)
    b = np.asarray(rhs, dtype=np.float64)

    # normalize to b >= 0
    n_slack = sum(1 for k in kinds if k == "ub")
    # columns: x (n) | slacks (n_slack) | artificials (<= m) | rhs
    slack_cols = {}
    j = n
    for i, k in enumerate(kinds):
        if k == "ub":
            slack_cols[i] = j
            j += 1
    flipped = b < -TOL
    total_pre_art = n + n_slack
    T = np.zeros((m, total_pre_art + m + 1), dtype=np.float64)
    basis: list[int] = [-1] * m
    art_cols: list[int] = []
    next_art = total_pre_art
    for i in range(m):
        row = A[i].copy()
        bi = b[i]
        sgn = 1.0
        if flipped[i]:
            row = -row
            bi = -bi
            sgn = -1.0
        T[i, :n] = row
        T[i, -1] = bi
        if kinds[i] == "ub":
            T[i, slack_cols[i]] = sgn  # flipped <= becomes >=, slack sign flips
        # does this row have a usable identity column (its slack with +1)?
        if kinds[i] == "ub" and sgn > 0:
            basis[i] = slack_cols[i]
        else:
            T[i, next_art] = 1.0
            basis[i] = next_art
            art_cols.append(next_art)
            next_art += 1
    used_cols = next_art
    T = T[:, list(range(used_cols)) + [T.shape[1] - 1]]
    ncols = T.shape[1] - 1

    if art_cols:
        c1 = np.zeros(ncols)
        for ac in art_cols:
            c1[ac] = 1.0
        status = _simplex_core(T, basis, c1, maxiter)
        if status != "optimal":
            return LPResult(status, None, None)
        obj1 = float(c1[basis] @ T[:, -1])
        if obj1 > 1e-6:
            return LPResult("infeasible", None, None)
        # drive remaining artificials out of the basis
        for i in range(m):
            if basis[i] in art_cols:
                # pivot on any non-artificial column with nonzero entry
                done = False
                for jcol in range(ncols):
                    if jcol in art_cols:
                        continue
                    if abs(T[i, jcol]) > 1e-9:
                        _pivot(T, basis, i, jcol)
                        done = True
                        break
                if not done:
                    # redundant row; harmless — leave artificial at zero
                    pass
        # forbid artificials from re-entering by giving them +inf-ish cost 0 and
        # zeroing their columns
        for ac in art_cols:
            T[:, ac] = 0.0

    c2 = np.zeros(ncols)
    c2[:n] = c
    status = _simplex_core(T, basis, c2, maxiter)
    if status != "optimal":
        return LPResult(status, None, None)
    x = np.zeros(ncols)
    for i in range(m):
        x[basis[i]] = T[i, -1]
    xs = x[:n]
    return LPResult("optimal", xs, float(c @ xs))


@dataclass
class ILPResult:
    status: str
    x: Optional[np.ndarray]
    fun: Optional[float]

    @property
    def ok(self) -> bool:
        return self.status == "optimal"


def solve_ilp(c: Sequence[float],
              A_ub: Optional[np.ndarray] = None,
              b_ub: Optional[np.ndarray] = None,
              A_eq: Optional[np.ndarray] = None,
              b_eq: Optional[np.ndarray] = None,
              bounds: Optional[Sequence[tuple[int, int]]] = None,
              max_nodes: int = 4000) -> ILPResult:
    """Minimize c@x over integer x with optional per-variable (lo, hi) bounds.

    Branch-and-bound over the LP relaxation.  Variables default to x >= 0; pass
    ``bounds`` to shift/cap them (bounds may be negative; we shift internally).
    """
    c = np.asarray(c, dtype=np.float64)
    n = c.shape[0]
    if bounds is None:
        bounds = [(0, None)] * n
    los = np.array([b[0] for b in bounds], dtype=np.float64)
    # shift x = y + lo  =>  y >= 0
    A_ub_l = [] if A_ub is None else [np.asarray(A_ub, np.float64).reshape(-1, n)]
    b_ub_l = [] if b_ub is None else [np.asarray(b_ub, np.float64).ravel()]
    if A_ub_l:
        b_ub_l = [b_ub_l[0] - A_ub_l[0] @ los]
    A_eq_s = None
    b_eq_s = None
    if A_eq is not None and len(A_eq):
        A_eq_s = np.asarray(A_eq, np.float64).reshape(-1, n)
        b_eq_s = np.asarray(b_eq, np.float64).ravel() - A_eq_s @ los
    # upper bounds become rows
    ub_rows = []
    ub_rhs = []
    for i, (lo, hi) in enumerate(bounds):
        if hi is not None:
            r = np.zeros(n)
            r[i] = 1.0
            ub_rows.append(r)
            ub_rhs.append(hi - lo)
    if ub_rows:
        A_ub_l.append(np.asarray(ub_rows))
        b_ub_l.append(np.asarray(ub_rhs, np.float64))
    A0 = np.vstack(A_ub_l) if A_ub_l else None
    b0 = np.concatenate(b_ub_l) if b_ub_l else None

    best_val = math.inf
    best_x: Optional[np.ndarray] = None
    const_shift = float(c @ los)

    stack = [(A0, b0)]
    nodes = 0
    status_seen_feasible = False
    while stack and nodes < max_nodes:
        nodes += 1
        A_cur, b_cur = stack.pop()
        res = solve_lp(c, A_cur, b_cur, A_eq_s, b_eq_s)
        if res.status == "unbounded":
            return ILPResult("unbounded", None, None)
        if not res.ok:
            continue
        if res.fun is not None and res.fun >= best_val - 1e-9:
            continue  # bound
        x = res.x
        frac_idx = -1
        worst = 0.0
        for i in range(n):
            f = abs(x[i] - round(x[i]))
            if f > 1e-6 and f > worst:
                worst = f
                frac_idx = i
        if frac_idx < 0:
            xi = np.round(x).astype(np.int64)
            val = float(c @ xi)
            status_seen_feasible = True
            if val < best_val:
                best_val = val
                best_x = xi
            continue
        lo_branch = math.floor(x[frac_idx])
        # x[frac] <= floor
        r = np.zeros(n)
        r[frac_idx] = 1.0
        A1 = r[None, :] if A_cur is None else np.vstack([A_cur, r])
        b1 = np.array([lo_branch]) if b_cur is None else np.concatenate([b_cur, [lo_branch]])
        # x[frac] >= ceil  ->  -x <= -(ceil)
        A2 = (-r)[None, :] if A_cur is None else np.vstack([A_cur, -r])
        b2 = np.array([-(lo_branch + 1)]) if b_cur is None else np.concatenate(
            [b_cur, [-(lo_branch + 1)]])
        stack.append((A1, b1))
        stack.append((A2, b2))

    if best_x is None:
        return ILPResult("infeasible" if not status_seen_feasible else "iteration_limit",
                         None, None)
    return ILPResult("optimal", best_x + los.astype(np.int64), best_val + const_shift)


def brute_force_ilp(c, A_ub=None, b_ub=None, A_eq=None, b_eq=None, bounds=None):
    """Exhaustive reference for tests (tiny bounded problems only)."""
    import itertools

    c = np.asarray(c, dtype=np.float64)
    n = len(c)
    assert bounds is not None and all(b[1] is not None for b in bounds)
    best = None
    bx = None
    for pt in itertools.product(*[range(lo, hi + 1) for lo, hi in bounds]):
        x = np.asarray(pt, dtype=np.float64)
        if A_ub is not None and len(A_ub) and np.any(np.asarray(A_ub) @ x > np.asarray(b_ub) + 1e-9):
            continue
        if A_eq is not None and len(A_eq) and np.any(np.abs(np.asarray(A_eq) @ x - np.asarray(b_eq)) > 1e-9):
            continue
        v = float(c @ x)
        if best is None or v < best:
            best = v
            bx = np.asarray(pt, dtype=np.int64)
    if best is None:
        return ILPResult("infeasible", None, None)
    return ILPResult("optimal", bx, best)

"""Self-contained LP / ILP solvers used by the HLS scheduler.

This container ships no scipy/pulp/ortools, so the paper's two ILP classes
(memory-dependence ILPs and the scheduling ILP) are solved with our own
numpy dense-tableau two-phase simplex (Bland's rule, cycle-safe) wrapped in
a depth-first branch-and-bound for integrality.  Problems are small (tens of
variables); the scheduling system itself is solved as a difference-constraint
graph (see scheduler.py) and only falls back to this LP for the
delay-register-minimization objective.
"""
from __future__ import annotations

import hashlib
import math
import time
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from . import faults

TOL = 1e-7

#: process-wide default wall-clock budget for a single ``solve_ilp`` call,
#: in seconds.  ``None`` (the default) preserves the historical behaviour of
#: running until ``max_nodes``; callers with latency contracts pass
#: ``deadline_s`` explicitly.
DEFAULT_DEADLINE_S: Optional[float] = None


@dataclass
class LPResult:
    status: str  # "optimal" | "infeasible" | "unbounded" | "iteration_limit"
    x: Optional[np.ndarray]
    fun: Optional[float]

    @property
    def ok(self) -> bool:
        return self.status == "optimal"


def _pivot(T: np.ndarray, basis: list[int], row: int, col: int) -> None:
    T[row] = T[row] / T[row, col]
    factor = T[:, col].copy()
    factor[row] = 0.0
    T -= np.outer(factor, T[row])
    # outer-product update can leave tiny residue in the pivot column
    T[:, col] = 0.0
    T[row, col] = 1.0
    basis[row] = col


def _simplex_core(T: np.ndarray, basis: list[int], c_full: np.ndarray,
                  maxiter: int) -> str:
    """Primal simplex on tableau T (m x (n+1), RHS in last column).

    ``basis`` holds the basic column of each row and is updated in place.
    Bland's rule (lowest-index entering / leaving) guarantees termination.
    """
    m, ncols = T.shape
    n = ncols - 1
    for _ in range(maxiter):
        cB = c_full[basis]
        reduced = c_full[:n] - cB @ T[:, :n]
        candidates = np.where(reduced < -TOL)[0]
        if candidates.size == 0:
            return "optimal"
        enter = int(candidates[0])  # Bland: lowest index
        col = T[:, enter]
        pos = np.where(col > TOL)[0]
        if pos.size == 0:
            return "unbounded"
        ratios = T[pos, n] / col[pos]
        best = ratios.min()
        ties = pos[np.where(ratios <= best + 1e-12)[0]]
        # Bland: leave the basic variable with the lowest index
        leave_row = int(ties[np.argmin(np.asarray(basis)[ties])])
        _pivot(T, basis, leave_row, enter)
    return "iteration_limit"


def solve_lp(c: Sequence[float],
             A_ub: Optional[np.ndarray] = None,
             b_ub: Optional[np.ndarray] = None,
             A_eq: Optional[np.ndarray] = None,
             b_eq: Optional[np.ndarray] = None,
             maxiter: int = 50000) -> LPResult:
    """minimize c@x  s.t.  A_ub@x <= b_ub,  A_eq@x == b_eq,  x >= 0."""
    c = np.asarray(c, dtype=np.float64)
    n = c.shape[0]
    rows = []
    rhs = []
    kinds = []  # "ub" | "eq"
    if A_ub is not None and len(A_ub):
        A_ub = np.asarray(A_ub, dtype=np.float64).reshape(-1, n)
        b_ub = np.asarray(b_ub, dtype=np.float64).ravel()
        for i in range(A_ub.shape[0]):
            rows.append(A_ub[i])
            rhs.append(b_ub[i])
            kinds.append("ub")
    if A_eq is not None and len(A_eq):
        A_eq = np.asarray(A_eq, dtype=np.float64).reshape(-1, n)
        b_eq = np.asarray(b_eq, dtype=np.float64).ravel()
        for i in range(A_eq.shape[0]):
            rows.append(A_eq[i])
            rhs.append(b_eq[i])
            kinds.append("eq")
    m = len(rows)
    if m == 0:
        # unconstrained besides x >= 0
        if np.any(c < -TOL):
            return LPResult("unbounded", None, None)
        return LPResult("optimal", np.zeros(n), 0.0)

    A = np.asarray(rows, dtype=np.float64)
    b = np.asarray(rhs, dtype=np.float64)

    # normalize to b >= 0
    n_slack = sum(1 for k in kinds if k == "ub")
    # columns: x (n) | slacks (n_slack) | artificials (<= m) | rhs
    slack_cols = {}
    j = n
    for i, k in enumerate(kinds):
        if k == "ub":
            slack_cols[i] = j
            j += 1
    flipped = b < -TOL
    total_pre_art = n + n_slack
    T = np.zeros((m, total_pre_art + m + 1), dtype=np.float64)
    basis: list[int] = [-1] * m
    art_cols: list[int] = []
    next_art = total_pre_art
    for i in range(m):
        row = A[i].copy()
        bi = b[i]
        sgn = 1.0
        if flipped[i]:
            row = -row
            bi = -bi
            sgn = -1.0
        T[i, :n] = row
        T[i, -1] = bi
        if kinds[i] == "ub":
            T[i, slack_cols[i]] = sgn  # flipped <= becomes >=, slack sign flips
        # does this row have a usable identity column (its slack with +1)?
        if kinds[i] == "ub" and sgn > 0:
            basis[i] = slack_cols[i]
        else:
            T[i, next_art] = 1.0
            basis[i] = next_art
            art_cols.append(next_art)
            next_art += 1
    used_cols = next_art
    T = T[:, list(range(used_cols)) + [T.shape[1] - 1]]
    ncols = T.shape[1] - 1

    if art_cols:
        c1 = np.zeros(ncols)
        for ac in art_cols:
            c1[ac] = 1.0
        status = _simplex_core(T, basis, c1, maxiter)
        if status != "optimal":
            return LPResult(status, None, None)
        obj1 = float(c1[basis] @ T[:, -1])
        if obj1 > 1e-6:
            return LPResult("infeasible", None, None)
        # drive remaining artificials out of the basis
        for i in range(m):
            if basis[i] in art_cols:
                # pivot on any non-artificial column with nonzero entry
                done = False
                for jcol in range(ncols):
                    if jcol in art_cols:
                        continue
                    if abs(T[i, jcol]) > 1e-9:
                        _pivot(T, basis, i, jcol)
                        done = True
                        break
                if not done:
                    # redundant row; harmless — leave artificial at zero
                    pass
        # forbid artificials from re-entering by giving them +inf-ish cost 0 and
        # zeroing their columns
        for ac in art_cols:
            T[:, ac] = 0.0

    c2 = np.zeros(ncols)
    c2[:n] = c
    status = _simplex_core(T, basis, c2, maxiter)
    if status != "optimal":
        return LPResult(status, None, None)
    x = np.zeros(ncols)
    for i in range(m):
        x[basis[i]] = T[i, -1]
    xs = x[:n]
    return LPResult("optimal", xs, float(c @ xs))


@dataclass
class ILPResult:
    """Outcome of a branch-and-bound search.

    Status lattice (DESIGN.md §9):

    - ``"optimal"``    — tree exhausted, or the incumbent meets the root LP
      bound; ``x``/``fun`` are the proven optimum.
    - ``"feasible"``   — search truncated (deadline or node cap) with an
      incumbent in hand; ``fun`` is an upper bound on the optimum, ``bound``
      a lower bound, ``gap = fun - bound`` the optimality gap.
    - ``"timeout"``    — search truncated before any incumbent was found;
      ``bound`` still carries the root LP lower bound when available.
      NOT a verdict about feasibility.
    - ``"infeasible"`` — the fully-explored tree proves no integer point
      satisfies the constraints.
    - ``"unbounded"``  — the relaxation is unbounded below.
    """
    status: str
    x: Optional[np.ndarray]
    fun: Optional[float]
    bound: Optional[float] = None  # best proven lower bound on the optimum
    gap: Optional[float] = None    # fun - bound when both are known
    nodes: int = 0                 # branch-and-bound nodes expanded

    @property
    def ok(self) -> bool:
        return self.status == "optimal"

    @property
    def truncated(self) -> bool:
        """True when the search was cut off before reaching a verdict."""
        return self.status in ("feasible", "timeout")


def _presolve(n: int,
              A_ub: Optional[np.ndarray], b_ub: Optional[np.ndarray],
              A_eq: Optional[np.ndarray], b_eq: Optional[np.ndarray],
              bounds: Sequence[tuple]) -> Optional[tuple]:
    """ILP presolve: singleton-row elimination + interval bound tightening.

    Exploits integrality (floor/ceil on derived bounds).  Returns None when
    infeasibility is proven, else (A_ub, b_ub, A_eq, b_eq, bounds) with rows
    dropped/simplified and bounds tightened.  Fixed variables (lo == hi) are
    substituted out of the rows but kept as columns so indices are stable.
    """
    los = [float(b[0]) for b in bounds]
    his = [math.inf if b[1] is None else float(b[1]) for b in bounds]
    eq = ([] if A_eq is None or not len(A_eq) else
          [(np.array(A_eq[i], dtype=np.float64), float(b_eq[i]))
           for i in range(len(A_eq))])
    ub = ([] if A_ub is None or not len(A_ub) else
          [(np.array(A_ub[i], dtype=np.float64), float(b_ub[i]))
           for i in range(len(A_ub))])

    for _ in range(12):  # tightening passes (fixpoint or cap)
        changed = False
        for rows, is_eq in ((eq, True), (ub, False)):
            kept = []
            for row, rhs in rows:
                nz = np.flatnonzero(np.abs(row) > TOL)
                # substitute fixed variables into the rhs
                fixed = [j for j in nz if his[j] - los[j] < TOL]
                if fixed:
                    for j in fixed:
                        rhs -= row[j] * los[j]
                        row[j] = 0.0
                    nz = np.flatnonzero(np.abs(row) > TOL)
                    changed = True
                if nz.size == 0:
                    if (abs(rhs) > 1e-6) if is_eq else (rhs < -1e-6):
                        return None
                    continue  # trivially satisfied row
                if nz.size == 1:
                    j = int(nz[0])
                    a = row[j]
                    if is_eq:
                        v = rhs / a
                        if abs(v - round(v)) > 1e-6:
                            return None
                        v = round(v)
                        if v < los[j] - TOL or v > his[j] + TOL:
                            return None
                        los[j] = his[j] = v
                    elif a > 0:
                        his[j] = min(his[j], math.floor(rhs / a + 1e-9))
                    else:
                        los[j] = max(los[j], math.ceil(rhs / a - 1e-9))
                    changed = True
                    continue  # row absorbed into the bounds
                # interval-arithmetic tightening of each variable in the row
                act_lo = act_hi = 0.0
                for j in nz:
                    a = row[j]
                    if a > 0:
                        act_lo += a * los[j]
                        act_hi += a * his[j]
                    else:
                        act_lo += a * his[j]
                        act_hi += a * los[j]
                if act_lo > rhs + 1e-6 or (is_eq and act_hi < rhs - 1e-6):
                    return None
                for j in nz:
                    a = row[j]
                    # residual activity of the other terms
                    o_lo = act_lo - (a * los[j] if a > 0 else a * his[j])
                    o_hi = act_hi - (a * his[j] if a > 0 else a * los[j])
                    if not math.isfinite(o_lo):
                        continue
                    if a > 0:
                        new_hi = math.floor((rhs - o_lo) / a + 1e-9)
                        if new_hi < his[j]:
                            his[j] = new_hi
                            changed = True
                        if is_eq and math.isfinite(o_hi):
                            new_lo = math.ceil((rhs - o_hi) / a - 1e-9)
                            if new_lo > los[j]:
                                los[j] = new_lo
                                changed = True
                    else:
                        new_lo = math.ceil((rhs - o_lo) / a - 1e-9)
                        if new_lo > los[j]:
                            los[j] = new_lo
                            changed = True
                        if is_eq and math.isfinite(o_hi):
                            new_hi = math.floor((rhs - o_hi) / a + 1e-9)
                            if new_hi < his[j]:
                                his[j] = new_hi
                                changed = True
                kept.append((row, rhs))
            rows[:] = kept
        if any(los[j] > his[j] + TOL for j in range(n)):
            return None
        if not changed:
            break

    A_eq2 = np.asarray([r for r, _ in eq]) if eq else None
    b_eq2 = np.asarray([b for _, b in eq]) if eq else None
    A_ub2 = np.asarray([r for r, _ in ub]) if ub else None
    b_ub2 = np.asarray([b for _, b in ub]) if ub else None
    bounds2 = [(int(los[j]), None if math.isinf(his[j]) else int(his[j]))
               for j in range(n)]
    return A_ub2, b_ub2, A_eq2, b_eq2, bounds2


def _problem_key(c, A_ub, b_ub, A_eq, b_eq, bounds) -> str:
    """Content digest of a solve_ilp call, for deterministic fault firing."""
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(c, dtype=np.float64).tobytes())
    for arr in (A_ub, b_ub, A_eq, b_eq):
        h.update(b"|")
        if arr is not None:
            h.update(np.ascontiguousarray(
                np.asarray(arr, dtype=np.float64)).tobytes())
    h.update(repr(list(bounds)).encode())
    return h.hexdigest()[:16]


def solve_ilp(c: Sequence[float],
              A_ub: Optional[np.ndarray] = None,
              b_ub: Optional[np.ndarray] = None,
              A_eq: Optional[np.ndarray] = None,
              b_eq: Optional[np.ndarray] = None,
              bounds: Optional[Sequence[tuple[int, int]]] = None,
              max_nodes: int = 4000,
              deadline_s: Optional[float] = None) -> ILPResult:
    """Minimize c@x over integer x with optional per-variable (lo, hi) bounds.

    Presolve (singleton rows, bound tightening) then branch-and-bound over
    the LP relaxation, exiting early when the root LP is already integral or
    an incumbent matches the root bound.  Variables default to x >= 0; pass
    ``bounds`` to shift/cap them (bounds may be negative; we shift internally).

    ``deadline_s`` is a wall-clock budget (falls back to the module-level
    ``DEFAULT_DEADLINE_S``).  An exceeded budget — like an exceeded
    ``max_nodes`` — yields an *anytime* answer: ``"feasible"`` with the
    incumbent and bound gap, or ``"timeout"`` with just the root bound.
    The root node is always expanded, so a bound is produced whenever the
    relaxation is solvable.
    """
    c = np.asarray(c, dtype=np.float64)
    n = c.shape[0]
    if bounds is None:
        bounds = [(0, None)] * n
    if A_ub is not None and len(A_ub):
        A_ub = np.asarray(A_ub, np.float64).reshape(-1, n)
    if A_eq is not None and len(A_eq):
        A_eq = np.asarray(A_eq, np.float64).reshape(-1, n)
    budget = deadline_s if deadline_s is not None else DEFAULT_DEADLINE_S
    t0 = time.monotonic() if budget is not None else 0.0
    # injected fault: the deadline strikes right after the root LP
    # relaxation — a bound but no incumbent, the tightest truncation a real
    # anytime run can produce (real budgets additionally accept an integral
    # root, which is why the fault path must refuse it: root-integral
    # problems would otherwise never truncate)
    forced_timeout = (faults.active() is not None and faults.should_fire(
        "solver_timeout", key=_problem_key(c, A_ub, b_ub, A_eq, b_eq,
                                           bounds)))
    pre = _presolve(n, A_ub, b_ub, A_eq, b_eq, bounds)
    if pre is None:
        return ILPResult("infeasible", None, None)
    A_ub, b_ub, A_eq, b_eq, bounds = pre
    if all(hi is not None and lo == hi for lo, hi in bounds):
        # presolve fixed every variable; verify any rows it left behind
        x = np.asarray([lo for lo, _ in bounds], dtype=np.int64)
        if A_ub is not None and np.any(A_ub @ x > np.asarray(b_ub) + 1e-6):
            return ILPResult("infeasible", None, None)
        if A_eq is not None and np.any(np.abs(A_eq @ x - np.asarray(b_eq)) > 1e-6):
            return ILPResult("infeasible", None, None)
        return ILPResult("optimal", x, float(c @ x))
    los = np.array([b[0] for b in bounds], dtype=np.float64)
    # shift x = y + lo  =>  y >= 0  (presolve already normalized the arrays)
    A_ub_l = [] if A_ub is None else [A_ub]
    b_ub_l = [] if A_ub is None else [np.asarray(b_ub, np.float64) - A_ub @ los]
    A_eq_s = A_eq
    b_eq_s = None if A_eq is None else np.asarray(b_eq, np.float64) - A_eq @ los
    # upper bounds become rows
    ub_rows = []
    ub_rhs = []
    for i, (lo, hi) in enumerate(bounds):
        if hi is not None:
            r = np.zeros(n)
            r[i] = 1.0
            ub_rows.append(r)
            ub_rhs.append(hi - lo)
    if ub_rows:
        A_ub_l.append(np.asarray(ub_rows))
        b_ub_l.append(np.asarray(ub_rhs, np.float64))
    A0 = np.vstack(A_ub_l) if A_ub_l else None
    b0 = np.concatenate(b_ub_l) if b_ub_l else None

    best_val = math.inf
    best_x: Optional[np.ndarray] = None
    const_shift = float(c @ los)

    stack = [(A0, b0)]
    nodes = 0
    root_bound: Optional[float] = None
    proven = False  # incumbent met the root LP bound: optimal despite stack
    cut = False     # search truncated (deadline or injected fault)
    while stack and nodes < max_nodes:
        if nodes > 0 and budget is not None and \
                time.monotonic() - t0 >= budget:
            cut = True
            break  # deadline: fall through to the anytime summary
        nodes += 1
        A_cur, b_cur = stack.pop()
        res = solve_lp(c, A_cur, b_cur, A_eq_s, b_eq_s)
        if nodes == 1 and res.ok:
            root_bound = res.fun  # LP relaxation bound: proves optimality early
            if forced_timeout:
                cut = True
                break
        if res.status == "unbounded":
            return ILPResult("unbounded", None, None, nodes=nodes)
        if not res.ok:
            continue
        if res.fun is not None and res.fun >= best_val - 1e-9:
            continue  # bound
        x = res.x
        frac_idx = -1
        worst = 0.0
        for i in range(n):
            f = abs(x[i] - round(x[i]))
            if f > 1e-6 and f > worst:
                worst = f
                frac_idx = i
        if frac_idx < 0:
            xi = np.round(x).astype(np.int64)
            val = float(c @ xi)
            if val < best_val:
                best_val = val
                best_x = xi
                if root_bound is not None and best_val <= root_bound + 1e-6:
                    proven = True
                    break  # incumbent meets the root LP bound: optimal
            continue
        lo_branch = math.floor(x[frac_idx])
        # x[frac] <= floor
        r = np.zeros(n)
        r[frac_idx] = 1.0
        A1 = r[None, :] if A_cur is None else np.vstack([A_cur, r])
        b1 = np.array([lo_branch]) if b_cur is None else np.concatenate([b_cur, [lo_branch]])
        # x[frac] >= ceil  ->  -x <= -(ceil)
        A2 = (-r)[None, :] if A_cur is None else np.vstack([A_cur, -r])
        b2 = np.array([-(lo_branch + 1)]) if b_cur is None else np.concatenate(
            [b_cur, [-(lo_branch + 1)]])
        stack.append((A1, b1))
        stack.append((A2, b2))

    # the root LP optimum (plus the shift) is a valid lower bound on the
    # integer optimum for the whole tree
    bound_out = None if root_bound is None else root_bound + const_shift
    if best_x is None:
        if stack or cut:
            # truncated search (deadline, node cap or injected fault) with
            # work left and no incumbent — NOT a verdict about feasibility
            return ILPResult("timeout", None, None, bound=bound_out,
                             nodes=nodes)
        return ILPResult("infeasible", None, None, nodes=nodes)
    fun = best_val + const_shift
    x_out = best_x + los.astype(np.int64)
    if (stack or cut) and not proven:
        # incumbent in hand but the tree was cut off: honest "feasible" with
        # the optimality gap, never a claimed optimum
        gap = None if bound_out is None else max(0.0, fun - bound_out)
        return ILPResult("feasible", x_out, fun, bound=bound_out, gap=gap,
                         nodes=nodes)
    return ILPResult("optimal", x_out, fun, bound=bound_out, gap=0.0,
                     nodes=nodes)


def brute_force_ilp(c, A_ub=None, b_ub=None, A_eq=None, b_eq=None, bounds=None):
    """Exhaustive reference for tests (tiny bounded problems only)."""
    import itertools

    c = np.asarray(c, dtype=np.float64)
    n = len(c)
    assert bounds is not None and all(b[1] is not None for b in bounds)
    best = None
    bx = None
    for pt in itertools.product(*[range(lo, hi + 1) for lo, hi in bounds]):
        x = np.asarray(pt, dtype=np.float64)
        if A_ub is not None and len(A_ub) and np.any(np.asarray(A_ub) @ x > np.asarray(b_ub) + 1e-9):
            continue
        if A_eq is not None and len(A_eq) and np.any(np.abs(np.asarray(A_eq) @ x - np.asarray(b_eq)) > 1e-9):
            continue
        v = float(c @ x)
        if best is None or v < best:
            best = v
            bx = np.asarray(pt, dtype=np.int64)
    if best is None:
        return ILPResult("infeasible", None, None)
    return ILPResult("optimal", bx, best)

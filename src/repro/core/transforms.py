"""Pass-based program transforms (DESIGN.md §6).

The compile path used to be a hard-coded 3-step flow with exactly one ad-hoc
transform (``to_spsc``, hand-rolled inside ``dataflow.py``).  HIDA-style
dataflow HLS compilers get their leverage from a *transform + DSE* layer
above the scheduler; this module is that layer's transform half.

A ``Pass`` is a pure function ``Program -> Program`` (the input is never
mutated) with a semantics-preservation obligation: for every pass ``T``,

    sequential_exec(p, x) == sequential_exec(T(p), x)    for all inputs x

restricted to the arrays of ``p`` (a pass may introduce fresh arrays — e.g.
``ToSPSC``'s copies — but those must be dead on entry).  ``PassManager``
optionally discharges the obligation by differential execution after every
pass (``verify=True``); the DSE driver (``autotune.explore``) runs every
candidate pipeline under that mode.

Transforms:

  * ``Normalize``             — expand ``unroll``-marked loops (ir.normalize
                                as a pass; the builder already runs it).
  * ``LoopUnroll(factor)``    — partial unroll: strip-mine by ``factor`` and
                                inline the inner copies.  Execution order is
                                unchanged, so semantics are preserved by
                                construction.
  * ``LoopTile(sizes)``       — strip-mine named loops into outer/inner
                                pairs (order-preserving tiling; profitable
                                as a phase-ordering knob for the scheduler's
                                occupancy constraint).
  * ``ArrayPartition(dims)``  — rewrite ``ArrayDecl.partition``/``ports`` so
                                the scheduler's port pseudo-dependences see
                                banked parallelism.  Pure metadata.
  * ``FuseProducerConsumer``  — merge adjacent top-level nests when an
                                exact ILP legality check proves no
                                dependence is reversed; mismatched bounds
                                fuse by SHIFTING the consumer by the
                                per-level max dependence distance and
                                PEELING the iterations outside the shifted
                                intersection (DESIGN.md §6 shift-and-peel).
  * ``ToSPSC``                — the paper's §5.2 benchmark transformation
                                (migrated here from ``dataflow.py``).
"""
from __future__ import annotations

import copy
import itertools
import math
import time
from dataclasses import dataclass, replace as dc_replace
from typing import Callable, Optional, Sequence

import numpy as np

from . import faults
from .ilp import solve_ilp
from .ir import (AffExpr, ArithOp, ConstOp, LoadOp, Loop, Program, StoreOp,
                 aff, iv, nest_shape, normalize)


# ---------------------------------------------------------------------------
# Cloning / substitution helpers
# ---------------------------------------------------------------------------


def clone_program(p: Program) -> Program:
    """Deep copy without the interpreter's per-instance def cache (it maps
    SSA names to op *objects* and would go stale under rewriting)."""
    q = copy.deepcopy(p)
    q.__dict__.pop("_def_cache", None)
    return q


class _Namer:
    """Fresh-name factory for SSA values and ivs cloned by a transform."""

    def __init__(self, tag: str):
        self.tag = tag
        self._n = itertools.count()

    def __call__(self, old: str) -> str:
        return f"{old}_{self.tag}{next(self._n)}"


def _subst_all(e: AffExpr, sub: dict[str, AffExpr]) -> AffExpr:
    for k, v in sub.items():
        e = e.subst(k, v)
    return e


def _clone_body(items, sub: dict[str, AffExpr], ssa: dict[str, str],
                namer: _Namer) -> list:
    """Deep-copy ops/loops applying the affine substitution ``sub`` to
    indices, renaming cloned loop ivs and SSA results via ``namer``."""
    out = []
    for it in items:
        if isinstance(it, Loop):
            sub2 = dict(sub)
            new_iv = namer(it.ivname)
            sub2[it.ivname] = iv(new_iv)
            lp = Loop(ivname=new_iv, lb=it.lb, ub=it.ub, pipeline=it.pipeline,
                      ii=it.ii, unroll=it.unroll)
            lp.body = _clone_body(it.body, sub2, ssa, namer)
            out.append(lp)
        elif isinstance(it, ConstOp):
            r = namer(it.result)
            ssa[it.result] = r
            out.append(ConstOp(result=r, value=it.value))
        elif isinstance(it, LoadOp):
            r = namer(it.result)
            ssa[it.result] = r
            out.append(LoadOp(result=r, array=it.array,
                              index=tuple(_subst_all(e, sub) for e in it.index)))
        elif isinstance(it, StoreOp):
            out.append(StoreOp(array=it.array,
                               index=tuple(_subst_all(e, sub) for e in it.index),
                               value=ssa.get(it.value, it.value)))
        elif isinstance(it, ArithOp):
            r = namer(it.result)
            ssa[it.result] = r
            out.append(ArithOp(result=r, fn=it.fn,
                               args=tuple(ssa.get(a, a) for a in it.args)))
        else:
            raise TypeError(it)
    return out


def _rewrite_indices(items, sub: dict[str, AffExpr]) -> None:
    """In-place affine substitution on every access index below ``items``."""
    for it in items:
        if isinstance(it, Loop):
            _rewrite_indices(it.body, sub)
        elif isinstance(it, (LoadOp, StoreOp)):
            it.index = tuple(_subst_all(e, sub) for e in it.index)


# ---------------------------------------------------------------------------
# Pass / PassManager
# ---------------------------------------------------------------------------


class TransformError(ValueError):
    """A pass was asked to do something it cannot do soundly."""


class PassVerificationError(AssertionError):
    """Differential execution found a semantics change."""


class Pass:
    """A semantics-preserving program transform.

    Contract (DESIGN.md §6): ``apply`` is pure — it never mutates its input
    (clone first, rewrite the clone) — and the output must be sequentially
    equivalent to the input on the input's arrays.  A pass that does not
    apply (no matching loops, illegal fusion, ...) returns an unchanged
    program rather than raising, so pipelines compose.

    Every pass also has a *textual* identity for the ``hls.compile`` front
    end (``pipeline_parse``): ``tag`` is its name in the pipeline string
    syntax, ``params()`` returns the constructor parameters that differ
    from the defaults (what the printer emits inside ``{...}``), and
    ``build(params)`` reconstructs the pass from parsed parameters.  The
    round-trip obligation is ``build(parse(print(p))).signature() ==
    p.signature()``.
    """

    name: str = "pass"
    tag: str = "pass"

    def apply(self, p: Program) -> Program:
        raise NotImplementedError

    def __call__(self, p: Program) -> Program:
        return self.apply(p)

    def params(self) -> dict:
        """Textual-syntax parameters (non-default only), printable order."""
        return {}

    @classmethod
    def build(cls, params: dict) -> "Pass":
        """Construct from parsed textual parameters; raises TransformError
        on unknown or ill-typed keys (pipeline_parse wraps it with source
        positions)."""
        if params:
            raise TransformError(
                f"pass '{cls.tag}' takes no parameters, got {sorted(params)}")
        return cls()

    def signature(self) -> tuple:
        """(tag, canonicalized params) — the round-trip identity."""
        return (self.tag, tuple(sorted(
            (k, tuple(v) if isinstance(v, (list, tuple)) else v)
            for k, v in self.params().items())))

    def __repr__(self):
        return f"<{type(self).__name__} {self.name}>"


def _param_tuple(v, kind, what: str) -> tuple:
    """Normalize a parsed parameter value (scalar or list) to a tuple of
    ``kind``, raising TransformError with a helpful message otherwise."""
    items = list(v) if isinstance(v, (list, tuple)) else [v]
    out = []
    for it in items:
        if kind is int and isinstance(it, bool):
            raise TransformError(f"{what}: expected int, got {it!r}")
        if not isinstance(it, kind):
            raise TransformError(f"{what}: expected {kind.__name__}, "
                                 f"got {it!r}")
        out.append(it)
    return tuple(out)


def _param_scalar(v, kind, what: str):
    if isinstance(v, (list, tuple)):
        raise TransformError(f"{what}: expected one {kind.__name__}, "
                             f"got a list {v!r}")
    if kind is int and isinstance(v, bool):
        raise TransformError(f"{what}: expected int, got {v!r}")
    if kind is float and isinstance(v, int) and not isinstance(v, bool):
        v = float(v)
    if not isinstance(v, kind):
        raise TransformError(f"{what}: expected {kind.__name__}, got {v!r}")
    return v


@dataclass
class PassReport:
    name: str
    changed: bool
    seconds: float


def _fingerprint(p: Program) -> str:
    """Deep textual snapshot of a program (ops, loops, arrays)."""
    return repr([(type(n).__name__, vars(n)) for n, _ in p.walk()]) + \
        repr(sorted(p.arrays.items()))


class PassManager:
    """Run a pipeline of passes, optionally verifying each one.

    ``verify=True`` discharges every pass's preservation obligation by
    differential execution (``differential_check``) and raises
    ``PassVerificationError`` naming the offending pass on mismatch.  It
    also enforces the purity half of the contract: a pass that mutates its
    input in place (and would therefore dodge the differential oracle by
    returning the same corrupted object) is caught by a pre/post
    fingerprint comparison.
    """

    def __init__(self, passes: Sequence[Pass], *, verify: bool = False,
                 seeds: Sequence[int] = (0,)):
        self.passes = list(passes)
        self.verify = verify
        self.seeds = tuple(seeds)
        self.reports: list[PassReport] = []

    def run(self, p: Program) -> Program:
        self.reports = []
        cur = p
        for ps in self.passes:
            t0 = time.perf_counter()
            before = _fingerprint(cur) if self.verify else None
            nxt = ps.apply(cur)
            if self.verify:
                if _fingerprint(cur) != before:
                    raise PassVerificationError(
                        f"pass '{ps.name}' mutated its input program "
                        "(passes must clone, then rewrite the clone)")
                if nxt is not cur:  # identical object == proven no-op
                    try:
                        differential_check(cur, nxt, seeds=self.seeds)
                    except AssertionError as e:
                        raise PassVerificationError(
                            f"pass '{ps.name}' changed program semantics: {e}"
                        ) from e
            self.reports.append(PassReport(
                name=ps.name, changed=nxt is not cur,
                seconds=time.perf_counter() - t0))
            cur = nxt
        return cur

    def describe(self) -> str:
        return " | ".join(ps.name for ps in self.passes)


def differential_check(p: Program, q: Program,
                       seeds: Sequence[int] = (0,)) -> None:
    """Assert sequential equivalence of ``q`` to ``p`` on ``p``'s arrays.

    Fresh arrays introduced by ``q`` (e.g. SPSC copies) get independent
    random contents — a sound transform must treat them as dead on entry.
    """
    from .sim import make_inputs, sequential_exec

    for name, arr in p.arrays.items():
        if name not in q.arrays:
            raise AssertionError(f"array {name} disappeared")
        if tuple(q.arrays[name].shape) != tuple(arr.shape):
            raise AssertionError(f"array {name} changed shape")
    for seed in seeds:
        base = make_inputs(p, seed)
        extra = make_inputs(q, seed + 7919)
        qin = {**extra, **{k: v.copy() for k, v in base.items()}}
        out_p = sequential_exec(p, base)
        out_q = sequential_exec(q, qin)
        for k in out_p:
            if not np.allclose(out_p[k], out_q[k], rtol=1e-12, atol=0):
                raise AssertionError(f"array {k} differs (seed {seed})")


# ---------------------------------------------------------------------------
# Normalize
# ---------------------------------------------------------------------------


class Normalize(Pass):
    """``ir.normalize`` (complete expansion of ``unroll``-marked loops) as a
    pure pass, plus — with ``sink=True``, the default — canonicalization of
    loop-adjacent ops: every maximal run of ops that sits beside a loop
    (bare ops in ``Program.body``, or ops next to a sub-loop inside a loop
    body — an imperfect nest per ``ir.nest_shape``) is sunk into a fresh
    trip-1 *sink nest*, so downstream layers meet ops only at innermost
    loop bodies.  A run whose SSA results are consumed outside the run
    cannot be sunk (a loop body opens a fresh value scope) and is left in
    place; ``nest_shape`` then still reports the task as imperfect.
    Idempotent; the builder already normalizes unrolls, so this mostly
    guards hand-built and frontend-traced Programs entering the pipeline."""

    tag = "normalize"

    def __init__(self, sink: bool = True):
        self.sink = bool(sink)
        self.name = "normalize" if self.sink else "normalize(nosink)"

    def params(self) -> dict:
        return {} if self.sink else {"sink": False}

    @classmethod
    def build(cls, params: dict) -> "Normalize":
        p = dict(params)
        kw: dict = {}
        if "sink" in p:
            kw["sink"] = _param_scalar(p.pop("sink"), bool, "normalize sink")
        if p:
            raise TransformError(
                f"normalize: unknown parameter(s) {sorted(p)} (valid: sink)")
        return cls(**kw)

    @staticmethod
    def _op_uses(op) -> list[str]:
        if isinstance(op, ArithOp):
            return list(op.args)
        if isinstance(op, StoreOp):
            return [op.value]
        return []

    def _sink_runs(self, p: Program) -> bool:
        """Sink loop-adjacent op runs into trip-1 nests in place; returns
        whether anything changed."""
        uses: dict[str, int] = {}
        for node, _ in p.walk():
            if not isinstance(node, Loop):
                for a in self._op_uses(node):
                    uses[a] = uses.get(a, 0) + 1
        taken = {l.ivname for l in p.loops()}
        fresh_ids = itertools.count()

        def fresh() -> str:
            while True:
                nm = f"sink{next(fresh_ids)}"
                if nm not in taken:
                    taken.add(nm)
                    return nm

        changed = False

        def rework(items: list, top: bool) -> list:
            nonlocal changed
            if not top and not any(isinstance(it, Loop) for it in items):
                return items  # innermost body: nothing is loop-adjacent
            out: list = []
            run: list = []

            def close():
                nonlocal changed
                if not run:
                    return
                defs = {op.result for op in run
                        if getattr(op, "result", None) is not None}
                inrun: dict[str, int] = {}
                for op in run:
                    for a in self._op_uses(op):
                        inrun[a] = inrun.get(a, 0) + 1
                if any(uses.get(d, 0) != inrun.get(d, 0) for d in defs):
                    out.extend(run)  # results escape the run: cannot sink
                else:
                    nest = Loop(ivname=fresh(), lb=0, ub=1)
                    nest.body = list(run)
                    out.append(nest)
                    changed = True
                run.clear()

            for it in items:
                if isinstance(it, Loop):
                    close()
                    it.body = rework(it.body, False)
                    out.append(it)
                else:
                    run.append(it)
            close()
            return out

        p.body = rework(p.body, True)
        return changed

    def apply(self, p: Program) -> Program:
        q = clone_program(p)
        any_change = False
        if any(l.unroll for l in q.loops()):
            q = normalize(q)
            any_change = True
        if self.sink and self._sink_runs(q):
            any_change = True
        return q if any_change else p


# ---------------------------------------------------------------------------
# LoopUnroll (partial unroll by a factor)
# ---------------------------------------------------------------------------


class LoopUnroll(Pass):
    """Partial unroll: strip-mine a loop by ``factor`` and inline the inner
    copies, so the loop body holds ``factor`` consecutive iterations.

    Targets ``ivs`` (names) or, by default, every *innermost* loop whose trip
    count the factor divides.  Iterations execute in the original order, so
    sequential semantics are preserved by construction; the payoff is that
    the parent's occupancy floor (II_outer >= trip_inner * II_inner) drops
    when the scheduler finds an II below ``factor`` * old_II for the widened
    body — spending datapath resources (DSP) for latency.
    """

    tag = "unroll"

    def __init__(self, factor: int, ivs: Optional[Sequence[str]] = None):
        if factor < 2:
            raise TransformError(f"unroll factor must be >= 2, got {factor}")
        self.factor = factor
        self.ivs = None if ivs is None else set(ivs)
        self.name = f"unroll(x{factor}" + \
            (f",{','.join(sorted(self.ivs))})" if self.ivs else ")")

    def params(self) -> dict:
        d: dict = {"factor": self.factor}
        if self.ivs is not None:
            d["ivs"] = tuple(sorted(self.ivs))
        return d

    @classmethod
    def build(cls, params: dict) -> "LoopUnroll":
        p = dict(params)
        if "factor" not in p:
            raise TransformError("unroll requires factor=<int>")
        factor = _param_scalar(p.pop("factor"), int, "unroll factor")
        ivs = p.pop("ivs", None)
        if ivs is not None:
            ivs = _param_tuple(ivs, str, "unroll ivs")
        if p:
            raise TransformError(
                f"unroll: unknown parameter(s) {sorted(p)} "
                "(valid: factor, ivs)")
        return cls(factor, ivs)

    def _eligible(self, loop: Loop) -> bool:
        if loop.unroll or loop.trip % self.factor or loop.lb != 0:
            return False
        if loop.ii is not None:
            # an explicit II pragma (e.g. an interface rate) is stated for
            # THIS loop's body; the widened body would silently drop it
            return False
        if self.ivs is not None:
            return loop.ivname in self.ivs
        return not any(isinstance(ch, Loop) for ch in loop.body)  # innermost

    def apply(self, p: Program) -> Program:
        if not any(self._eligible(l) for l in p.loops()):
            return p
        q = clone_program(p)
        namer = _Namer("u")

        def rec(items):
            out = []
            for it in items:
                if not isinstance(it, Loop):
                    out.append(it)
                    continue
                it.body = rec(it.body)
                if self._eligible(it):
                    f = self.factor
                    body = []
                    for k in range(f):
                        # original iv value = f*iv_new + k
                        sub = {it.ivname: aff(it.ivname) * f + k}
                        ssa: dict[str, str] = {}
                        body.extend(_clone_body(it.body, sub, ssa, namer))
                    nl = Loop(ivname=it.ivname, lb=0, ub=it.trip // f,
                              pipeline=it.pipeline, ii=None,
                              fuse_group=it.fuse_group, peel=it.peel,
                              tile_block=it.tile_block)
                    nl.body = body
                    out.append(nl)
                else:
                    out.append(it)
            return out

        q.body = rec(q.body)
        return q


# ---------------------------------------------------------------------------
# LoopTile (order-preserving strip-mining)
# ---------------------------------------------------------------------------


class LoopTile(Pass):
    """Strip-mine loops: ``for i in [0, N)`` becomes
    ``for i_t in [0, N/s): for i_b in [0, s): i = s*i_t + i_b``.

    ``sizes`` is either a mapping ``iv name -> block size`` or a positional
    sequence of block sizes applied to the top-level loop nests in program
    order (the textual syntax ``tile{sizes=8,8}``).  The dynamic execution
    order is untouched (this is tiling without interchange), so semantics
    are preserved by construction.  Loops whose trip the size does not
    divide are left alone.  The outer loop of each strip pair is marked
    ``Loop.tile_block`` so the resource model can cost nest-local
    intermediates at their streamed tile-window footprint (DESIGN.md §6).
    """

    tag = "tile"

    def __init__(self, sizes):
        if isinstance(sizes, dict):
            if not sizes or any(s < 2 for s in sizes.values()):
                raise TransformError(f"tile sizes must be >= 2: {sizes}")
            self.sizes: Optional[dict[str, int]] = dict(sizes)
            self.seq: Optional[tuple[int, ...]] = None
            self.name = "tile(" + ",".join(
                f"{k}:{v}" for k, v in sorted(self.sizes.items())) + ")"
        else:
            seq = tuple(sizes)
            if not seq or any(not isinstance(s, int) or s < 2 for s in seq):
                raise TransformError(f"tile sizes must be ints >= 2: {sizes}")
            self.sizes = None
            self.seq = seq
            self.name = "tile(" + ",".join(map(str, seq)) + ")"

    def params(self) -> dict:
        if self.seq is not None:
            return {"sizes": self.seq}
        return dict(sorted(self.sizes.items()))

    @classmethod
    def build(cls, params: dict) -> "LoopTile":
        if not params:
            raise TransformError(
                "tile requires sizes=<ints> (positional, applied to "
                "top-level loops in order) or <iv>=<int> pairs")
        if "sizes" in params:
            extra = sorted(set(params) - {"sizes"})
            if extra:
                raise TransformError(
                    f"tile: cannot mix sizes= with named loops {extra}")
            return cls(_param_tuple(params["sizes"], int, "tile sizes"))
        return cls({k: _param_scalar(v, int, f"tile size for loop '{k}'")
                    for k, v in params.items()})

    def _resolved(self, p: Program) -> dict[str, int]:
        """The effective iv -> size map (positional sizes bind to top-level
        loops in program order at apply time)."""
        if self.sizes is not None:
            return self.sizes
        tops = [it for it in p.body if isinstance(it, Loop)]
        return {l.ivname: s for l, s in zip(tops, self.seq)}

    @staticmethod
    def _eligible(loop: Loop, sizes: dict[str, int]) -> bool:
        s = sizes.get(loop.ivname)
        return (s is not None and not loop.unroll and loop.lb == 0
                and loop.trip % s == 0 and loop.trip // s >= 2)

    def apply(self, p: Program) -> Program:
        sizes = self._resolved(p)
        if not any(self._eligible(l, sizes) for l in p.loops()):
            return p
        q = clone_program(p)

        def rec(items):
            out = []
            for it in items:
                if not isinstance(it, Loop):
                    out.append(it)
                    continue
                it.body = rec(it.body)
                if self._eligible(it, sizes):
                    s = sizes[it.ivname]
                    ot, ib = f"{it.ivname}_t", f"{it.ivname}_b"
                    _rewrite_indices(it.body, {it.ivname: aff(ot) * s + aff(ib)})
                    inner = Loop(ivname=ib, lb=0, ub=s, pipeline=it.pipeline,
                                 ii=it.ii)
                    inner.body = it.body
                    outer = Loop(ivname=ot, lb=0, ub=it.trip // s,
                                 pipeline=it.pipeline, ii=None,
                                 fuse_group=it.fuse_group, peel=it.peel,
                                 tile_block=s)
                    outer.body = [inner]
                    out.append(outer)
                else:
                    out.append(it)
            return out

        q.body = rec(q.body)
        return q


# ---------------------------------------------------------------------------
# ArrayPartition
# ---------------------------------------------------------------------------


class ArrayPartition(Pass):
    """Rewrite ``ArrayDecl.partition`` (and optionally ``ports``) so the
    scheduler's port pseudo-dependences can exploit banked parallelism.

    ``dims=None`` means complete partitioning (every dim banked — the
    paper's supported ``array_partition`` mode); ``arrays=None`` targets
    every array that is not already fully partitioned.  Purely metadata:
    sequential semantics are unaffected, only the dependence analysis and
    the resource model see the change (BRAM -> FF migration).
    """

    tag = "partition"

    def __init__(self, arrays: Optional[Sequence[str]] = None,
                 dims: Optional[Sequence[int]] = None,
                 ports: Optional[Sequence[str]] = None):
        self.arrays = None if arrays is None else tuple(arrays)
        self.dims = None if dims is None else tuple(dims)
        self.ports = None if ports is None else tuple(ports)
        tgt = "*" if self.arrays is None else ",".join(self.arrays)
        dd = "full" if self.dims is None else ",".join(map(str, self.dims))
        self.name = f"partition({tgt};dims={dd})"

    def params(self) -> dict:
        d: dict = {}
        if self.arrays is not None:
            d["arrays"] = self.arrays
        if self.dims is not None:
            d["dims"] = self.dims
        if self.ports is not None:
            d["ports"] = self.ports
        return d

    @classmethod
    def build(cls, params: dict) -> "ArrayPartition":
        p = dict(params)
        arrays = p.pop("arrays", None)
        if arrays is not None:
            arrays = _param_tuple(arrays, str, "partition arrays")
        dims = p.pop("dims", None)
        if dims is not None:
            dims = _param_tuple(dims, int, "partition dims")
        ports = p.pop("ports", None)
        if ports is not None:
            ports = _param_tuple(ports, str, "partition ports")
        if p:
            raise TransformError(
                f"partition: unknown parameter(s) {sorted(p)} "
                "(valid: arrays, dims, ports)")
        return cls(arrays, dims, ports)

    def apply(self, p: Program) -> Program:
        todo = {}
        for name, arr in p.arrays.items():
            if self.arrays is not None and name not in self.arrays:
                continue
            dims = tuple(range(len(arr.shape))) if self.dims is None else \
                tuple(d for d in self.dims if d < len(arr.shape))
            new_ports = self.ports or arr.ports
            if tuple(arr.partition) == dims and tuple(arr.ports) == tuple(new_ports):
                continue
            if arr.kind == "reg":
                continue  # already port-free registers
            todo[name] = (dims, tuple(new_ports))
        if not todo:
            return p
        q = clone_program(p)
        for name, (dims, ports) in todo.items():
            q.arrays[name] = dc_replace(q.arrays[name], partition=dims,
                                        ports=ports)
        return q


# ---------------------------------------------------------------------------
# FuseProducerConsumer
# ---------------------------------------------------------------------------


def _perfect_chain(item) -> Optional[tuple[list[Loop], list]]:
    """(loops outermost-first, innermost body) for a perfect nest, else None.

    Structural companion to ``ir.nest_shape``: returns None exactly for the
    tasks the classifier reports as non-``perfect`` (fusion consults the
    classifier first and uses this helper only to extract the chain)."""
    if not isinstance(item, Loop):
        return None
    loops = [item]
    body = item.body
    while True:
        inner = [ch for ch in body if isinstance(ch, Loop)]
        if not inner:
            return loops, body
        if len(inner) != 1 or len(body) != 1:
            return None  # non-perfect: ops alongside a loop / sibling loops
        loops.append(inner[0])
        body = inner[0].body


def _mem_ops_of(items) -> list:
    out = []
    for it in items:
        if isinstance(it, Loop):
            out.extend(_mem_ops_of(it.body))
        elif isinstance(it, (LoadOp, StoreOp)):
            out.append(it)
    return out


def _fusion_hazard(opA, opB, loopsA: list[Loop], loopsB: list[Loop],
                   shift: Optional[Sequence[int]] = None) -> bool:
    """Exact legality core.  ``opA`` (from the first nest) and ``opB`` (from
    the second) touch the same array and at least one writes.  In the
    original program every dynamic instance of ``opA`` precedes every
    instance of ``opB``; after fusion (with the consumer shifted by
    ``shift``, default zero) instance ``va`` of A executes at fused position
    ``va`` and instance ``vb`` of B at ``vb + shift``, A's body first at
    ties.  The fusion is illegal iff

        exists va, vb :  addr_A(va) == addr_B(vb)  and  va >lex vb + shift

    Decided exactly with one small feasibility ILP per lexicographic carry
    level.
    """
    d = len(loopsA)
    n = 2 * d
    sh = [0] * d if shift is None else list(shift)
    col_a = {l.ivname: i for i, l in enumerate(loopsA)}
    col_b = {l.ivname: d + i for i, l in enumerate(loopsB)}

    A_eq_addr, b_eq_addr = [], []
    for dim in range(len(opA.index)):
        ea, eb = opA.index[dim], opB.index[dim]
        row = np.zeros(n)
        for nm, c in ea.coeffs.items():
            row[col_a[nm]] += c
        for nm, c in eb.coeffs.items():
            row[col_b[nm]] -= c
        A_eq_addr.append(row)
        b_eq_addr.append(float(eb.const - ea.const))

    bounds = [(l.lb, l.ub - 1) for l in loopsA] + \
             [(l.lb, l.ub - 1) for l in loopsB]
    c = np.zeros(n)

    for lvl in range(d):  # va >lex vb + shift carried at level lvl
        A_eq = list(A_eq_addr)
        b_eq = list(b_eq_addr)
        for k in range(lvl):
            row = np.zeros(n)
            row[k], row[d + k] = 1.0, -1.0
            A_eq.append(row)
            b_eq.append(float(sh[k]))  # va_k == vb_k + shift_k
        row = np.zeros(n)  # (vb_lvl + shift_lvl) - va_lvl <= -1
        row[d + lvl], row[lvl] = 1.0, -1.0
        res = solve_ilp(c, np.asarray([row]), np.asarray([-1.0 - sh[lvl]]),
                        np.asarray(A_eq), np.asarray(b_eq), bounds=bounds)
        if res.status == "feasible":
            # c == 0: any integral point — truncated search or not — is a
            # concrete witness of the hazard
            return True
        if res.ok:
            return True
        if res.status == "infeasible":
            continue
        if not res.truncated:
            raise RuntimeError(
                f"fusion legality ILP unresolved ({res.status}) for "
                f"{opA!r} / {opB!r}")
        # truncated with no witness either way: conservatively report a
        # hazard, which refuses (or shifts) the fusion — legal, suboptimal
        faults.note("fusion-hazard-degraded", status=res.status,
                    src=repr(opA), snk=repr(opB), level=lvl)
        return True
    return False


def _max_dep_distance(opA, opB, loopsA: list[Loop], loopsB: list[Loop],
                      level: int,
                      fixed: Sequence[tuple[int, int]] = ()) -> Optional[int]:
    """max(va[level] - vb[level]) over address-matching instance pairs of
    ``opA``/``opB`` — the per-level dependence distance that a legal
    consumer shift must cover.  ``fixed`` pins earlier levels' distances
    (``va[k] - vb[k] == d_k``), which is how the lexicographic maximization
    proceeds level by level.  Returns None when the accesses never alias
    under the pinned prefix (no constraint).  Solved closed-form via the
    deps.py separable solver whenever the address system decomposes;
    genuinely coupled systems fall back to the branch-and-bound ILP.
    Raises TransformError when neither resolves.
    """
    from .deps import _FALLBACK as _SEP_FALLBACK, _solve_separable

    nx, ny = len(loopsA), len(loopsB)
    # minimize -(va_level - vb_level)  ==  maximize the distance
    vars: dict = {}
    for i, l in enumerate(loopsA):
        vars[("x", i)] = (l.lb, l.ub - 1, -1 if i == level else 0)
    for j, l in enumerate(loopsB):
        vars[("y", j)] = (l.lb, l.ub - 1, 1 if j == level else 0)
    col_a = {l.ivname: ("x", i) for i, l in enumerate(loopsA)}
    col_b = {l.ivname: ("y", j) for j, l in enumerate(loopsB)}
    rows = []
    for dim in range(len(opA.index)):
        ea, eb = opA.index[dim], opB.index[dim]
        coeffs: dict = {}
        for nm, c in ea.coeffs.items():
            k = col_a[nm]
            coeffs[k] = coeffs.get(k, 0) + c
        for nm, c in eb.coeffs.items():
            k = col_b[nm]
            coeffs[k] = coeffs.get(k, 0) - c
        rows.append(({k: v for k, v in coeffs.items() if v},
                     eb.const - ea.const))
    for lvl, dist in fixed:  # va[lvl] - vb[lvl] == dist
        rows.append(({("x", lvl): 1, ("y", lvl): -1}, dist))
    r = _solve_separable(vars, rows)
    if r is None:
        return None
    if r is not _SEP_FALLBACK:
        return -r

    # coupled system: exact branch-and-bound fallback
    n = nx + ny
    c = np.zeros(n)
    c[level] = -1.0
    c[nx + level] = 1.0
    A_eq, b_eq = [], []
    for coeffs, rhs in rows:
        row = np.zeros(n)
        for (side, k), v in coeffs.items():
            row[k if side == "x" else nx + k] = v
        A_eq.append(row)
        b_eq.append(float(rhs))
    bounds = [(l.lb, l.ub - 1) for l in loopsA] + \
             [(l.lb, l.ub - 1) for l in loopsB]
    res = solve_ilp(c, None, None, np.asarray(A_eq), np.asarray(b_eq),
                    bounds=bounds)
    if res.ok:
        return int(round(-res.fun))
    if res.status == "infeasible":
        return None
    if res.truncated:
        # maximizing the distance as min(-dist): -bound upper-bounds the
        # true maximum, so a shift covering it still covers every real
        # dependence — a legal, possibly over-shifted fusion.  With no root
        # bound at all, the box bound over the level's variable ranges
        # serves the same role.
        if res.bound is not None:
            dist = int(math.ceil(-res.bound - 1e-9))
        else:
            dist = (loopsA[level].ub - 1) - loopsB[level].lb
        faults.note("dep-distance-degraded", status=res.status,
                    distance_bound=dist, src=repr(opA), snk=repr(opB))
        return dist
    raise TransformError(
        f"dependence-distance ILP unresolved ({res.status}) for "
        f"{opA!r} / {opB!r}")


_FUSE_GROUP_IDS = itertools.count(1)


class FuseProducerConsumer(Pass):
    """Fuse adjacent top-level producer/consumer nests, shifting and peeling
    the consumer when the bounds do not match (DESIGN.md §6).

    Candidates: two adjacent top-level *perfect* nests with identical depth
    where the first writes an array the second reads.  Legality is decided
    exactly (``_fusion_hazard``): for every access pair on a shared array
    with at least one write, no dynamic dependence may be reversed by
    fusing.  When the zero-shift fusion is illegal or the bounds differ,
    the pass computes the LEXICOGRAPHIC-minimum legal consumer shift — the
    lex-maximum dependence-distance vector over all conflicting pairs,
    maximized level by level with earlier levels pinned
    (``_max_dep_distance``, closed form via the deps.py separable solver)
    — peels the iterations falling outside the shifted intersection of
    bounds into prologue/epilogue nests, and emits the fused core over the
    intersection.  Correlated distances (a large inner distance occurring
    only with a smaller outer one) therefore no longer inflate the shift
    the way per-level componentwise maxima did; inner shift components may
    even be negative (B-side head peels).  Fusions whose core would cover
    less than ``min_core_fraction`` of the smaller nest at any level (e.g.
    a dependence distance growing with the problem size — no finite shift)
    are refused.  The pass fuses greedily until a fixpoint, so a pointwise
    chain (e.g. unsharp's sharpen+mask) collapses into one nest the
    scheduler can pipeline with a single II.
    """

    tag = "fuse"

    def __init__(self, max_fusions: Optional[int] = None, *,
                 enable_shift: bool = True,
                 min_core_fraction: float = 0.5):
        self.max_fusions = max_fusions
        self.enable_shift = enable_shift
        self.min_core_fraction = min_core_fraction
        self.name = "fuse" if enable_shift else "fuse(noshift)"

    def params(self) -> dict:
        d: dict = {}
        if self.max_fusions is not None:
            d["max_fusions"] = self.max_fusions
        if not self.enable_shift:
            d["shift"] = False
        if self.min_core_fraction != 0.5:
            d["min_core_fraction"] = self.min_core_fraction
        return d

    @classmethod
    def build(cls, params: dict) -> "FuseProducerConsumer":
        p = dict(params)
        kw: dict = {}
        if "shift" in p:
            kw["enable_shift"] = _param_scalar(p.pop("shift"), bool,
                                               "fuse shift")
        if "min_core_fraction" in p:
            kw["min_core_fraction"] = _param_scalar(
                p.pop("min_core_fraction"), float, "fuse min_core_fraction")
        max_fusions = None
        if "max_fusions" in p:
            max_fusions = _param_scalar(p.pop("max_fusions"), int,
                                        "fuse max_fusions")
        if p:
            raise TransformError(
                f"fuse: unknown parameter(s) {sorted(p)} "
                "(valid: shift, min_core_fraction, max_fusions)")
        return cls(max_fusions, **kw)

    # -- candidate test -----------------------------------------------------
    def _candidate(self, a, b):
        """(loopsA, loopsB, conflicting pairs) or None (not producer/consumer
        perfect nests of equal depth)."""
        ca, cb = _perfect_chain(a), _perfect_chain(b)
        if ca is None or cb is None:
            return None
        loopsA, _ = ca
        loopsB, _ = cb
        if len(loopsA) != len(loopsB):
            return None
        opsA, opsB = _mem_ops_of([a]), _mem_ops_of([b])
        wrote = {op.array for op in opsA if isinstance(op, StoreOp)}
        read_b = {op.array for op in opsB if isinstance(op, LoadOp)}
        if not (wrote & read_b):
            return None  # not a producer/consumer pair
        pairs = [(oa, ob) for oa in opsA for ob in opsB
                 if oa.array == ob.array and
                 (isinstance(oa, StoreOp) or isinstance(ob, StoreOp))]
        return loopsA, loopsB, pairs

    def _lexmax_distance(self, oa, ob, loopsA, loopsB) -> Optional[tuple]:
        """The lexicographically maximal dependence-distance vector
        ``va - vb`` over address-matching instance pairs, computed level by
        level: maximize the level's distance with every earlier level
        pinned at its (already maximal) value.  None when the accesses
        never alias."""
        d = len(loopsA)
        vec: list[int] = []
        for lvl in range(d):
            dist = _max_dep_distance(oa, ob, loopsA, loopsB, lvl,
                                     fixed=tuple(enumerate(vec)))
            if dist is None:
                if lvl == 0:
                    return None  # no aliasing at all
                raise TransformError(
                    f"lexmax distance infeasible at level {lvl} under its "
                    f"own attained prefix {vec} ({oa!r} / {ob!r})")
            vec.append(dist)
        return tuple(vec)

    def _shift_for(self, loopsA, loopsB, pairs) -> Optional[list[int]]:
        """The lexicographic-minimum legal consumer shift, or None when
        fusion stays illegal / undecidable.

        Legality is ``va <=lex vb + sigma`` for every aliasing pair, i.e.
        ``sigma >=lex`` every dependence-distance vector — the minimum such
        sigma (lex order is total) is the lex-maximum distance vector over
        all pairs.  Unlike the componentwise per-level maxima this never
        overshoots correlated distances (e.g. a pair whose big inner
        distance only occurs alongside a smaller outer one), so inner
        components may come out negative (consumer runs ahead at that
        level); ``_build`` peels the corresponding B-side head.  A hazard
        at zero shift guarantees some distance ``>lex 0``, so the leading
        component is always nonnegative."""
        d = len(loopsA)
        try:
            if not any(_fusion_hazard(oa, ob, loopsA, loopsB)
                       for oa, ob in pairs):
                return [0] * d  # zero shift already legal
            if not self.enable_shift:
                return None
            best: Optional[tuple] = None
            for oa, ob in pairs:
                vec = self._lexmax_distance(oa, ob, loopsA, loopsB)
                if vec is not None and (best is None or vec > best):
                    best = vec
            if best is None:
                return None
            shift = list(best)
            # re-verify the exact shifted hazard ILP before fusing
            if any(_fusion_hazard(oa, ob, loopsA, loopsB, shift)
                   for oa, ob in pairs):
                return None
            return shift
        except (TransformError, RuntimeError):
            return None  # undecided legality: never fuse on a guess

    def _profitable(self, loopsA, loopsB, shift) -> bool:
        """Refuse degenerate fusions: the shifted intersection (the fused
        core) must cover >= min_core_fraction of the smaller nest at every
        level — a shift that eats the whole iteration space (a dependence
        distance scaling with the bounds, i.e. backward-flowing) fails."""
        for la, lb_, s in zip(loopsA, loopsB, shift):
            lo = max(la.lb, lb_.lb + s)
            hi = min(la.ub, lb_.ub + s)
            if hi - lo < 1:
                return False
            if hi - lo < self.min_core_fraction * min(la.trip, lb_.trip):
                return False
        return True

    # -- construction -------------------------------------------------------
    def _fuse(self, a: Loop, b: Loop, namer: _Namer) -> Loop:
        """Zero-shift, equal-bounds fusion: splice B's body into A's."""
        loopsA, bodyA = _perfect_chain(a)
        loopsB, bodyB = _perfect_chain(b)
        # the B->A iv renaming must be SIMULTANEOUS: with crossed names
        # (B's outer called like A's inner), sequential substitution would
        # chain j->i->j.  Route through fresh temporaries instead.
        tmp = {lb.ivname: iv(f"__fuse_tmp{k}") for k, lb in enumerate(loopsB)}
        ssa: dict[str, str] = {}
        cloned = _clone_body(bodyB, tmp, ssa, namer)
        _rewrite_indices(cloned, {f"__fuse_tmp{k}": iv(la.ivname)
                                  for k, la in enumerate(loopsA)})
        bodyA.extend(cloned)
        return a

    def _peel(self, loops, level, lo, hi, sub, namer, peels) -> Loop:
        """Clone loops[level:] with the level loop restricted to [lo, hi),
        rebased to start at 0 (the scheduler's latency accounting assumes
        lb == 0)."""
        src = loops[level]
        piv = namer(src.ivname)
        lp = Loop(ivname=piv, lb=0, ub=hi - lo, pipeline=src.pipeline,
                  ii=src.ii, peel=True)
        s2 = dict(sub)
        s2[src.ivname] = aff(piv) + lo
        lp.body = _clone_body(src.body, s2, {}, namer)
        peels.append(lp)
        return lp

    def _build(self, loopsA, loopsB, shift, level, subA, subB, namer, peels):
        """Emit the fused region for levels >= ``level``: head peels (the
        iterations before the shifted intersection), the fused core over the
        intersection, then tail peels — recursively per level, so inner-level
        bound mismatches peel *inside* the core loop's body."""
        d = len(loopsA)
        if level == d:
            return _clone_body(loopsA[-1].body, subA, {}, namer) + \
                _clone_body(loopsB[-1].body, subB, {}, namer)
        la, lb_ = loopsA[level], loopsB[level]
        s = shift[level]
        lo = max(la.lb, lb_.lb + s)
        hi = min(la.ub, lb_.ub + s)
        assert hi > lo, "empty core must be rejected by _profitable"
        out = []
        if la.lb < lo:        # A-only head (consumer shifted right)
            out.append(self._peel(loopsA, level, la.lb, lo, subA, namer,
                                  peels))
        if lb_.lb + s < lo:   # B-only head (negative shift; defensive)
            out.append(self._peel(loopsB, level, lb_.lb, lo - s, subB, namer,
                                  peels))
        civ = namer(la.ivname)
        core = Loop(ivname=civ, lb=0, ub=hi - lo,
                    pipeline=la.pipeline and lb_.pipeline)
        sA = dict(subA)
        sA[la.ivname] = aff(civ) + lo
        sB = dict(subB)
        sB[lb_.ivname] = aff(civ) + (lo - s)
        core.body = self._build(loopsA, loopsB, shift, level + 1, sA, sB,
                                namer, peels)
        out.append(core)
        if hi < la.ub:        # A-only tail (producer ranges further)
            out.append(self._peel(loopsA, level, hi, la.ub, subA, namer,
                                  peels))
        if hi - s < lb_.ub:   # B-only tail (shifted consumer ranges further)
            out.append(self._peel(loopsB, level, hi - s, lb_.ub, subB, namer,
                                  peels))
        return out

    def apply(self, p: Program) -> Program:
        q = clone_program(p)
        namer = _Namer("f")
        fused = 0
        changed = True
        any_change = False
        peeled: set[int] = set()   # uids of peel nests: never re-fused
        log: list[dict] = list(getattr(q, "_fusion_log", []))
        while changed and (self.max_fusions is None or fused < self.max_fusions):
            changed = False
            # one contract check, one place: only tasks the classifier calls
            # perfect are fusion candidates — imperfect / multi-loop tasks
            # elsewhere in the program never block fusing a legal pair
            shape = nest_shape(q)
            for i in range(len(q.body) - 1):
                a, b = q.body[i], q.body[i + 1]
                if not (isinstance(a, Loop) and isinstance(b, Loop)):
                    continue
                if not (shape.task(i).is_perfect and
                        shape.task(i + 1).is_perfect):
                    continue
                if a.uid in peeled or b.uid in peeled:
                    continue
                cand = self._candidate(a, b)
                if cand is None:
                    continue
                loopsA, loopsB, pairs = cand
                shift = self._shift_for(loopsA, loopsB, pairs)
                if shift is None:
                    continue
                arrays = sorted({oa.array for oa, _ in pairs})
                equal_bounds = all((x.lb, x.ub) == (y.lb, y.ub)
                                   for x, y in zip(loopsA, loopsB))
                old_groups = {g for g in (a.fuse_group, b.fuse_group)
                              if g is not None}
                if equal_bounds and not any(shift):
                    q.body[i:i + 2] = [self._fuse(a, b, namer)]
                    new_items = [q.body[i]]
                    n_peels = 0
                else:
                    if any(l.ii is not None for l in loopsA + loopsB):
                        continue  # a merged nest would drop the II pragma
                    if not self._profitable(loopsA, loopsB, shift):
                        continue
                    peels: list[Loop] = []
                    new_items = self._build(loopsA, loopsB, shift, 0,
                                            {}, {}, namer, peels)
                    peeled.update(lp.uid for lp in peels)
                    n_peels = len(peels)
                    q.body[i:i + 2] = new_items
                # peel nests share the fused core's datapath (resource model)
                group = min(old_groups) if old_groups else \
                    next(_FUSE_GROUP_IDS)
                for it in new_items:
                    it.fuse_group = group
                for it in q.body:
                    if isinstance(it, Loop) and it.fuse_group in old_groups:
                        it.fuse_group = group
                log.append({"arrays": arrays, "shift": list(shift),
                            "peels": n_peels,
                            "core_trips": [min(x.ub, y.ub + s) -
                                           max(x.lb, y.lb + s)
                                           for x, y, s in
                                           zip(loopsA, loopsB, shift)]})
                fused += 1
                changed = any_change = True
                break
        if not any_change:
            return p
        q._fusion_log = log
        return q


# ---------------------------------------------------------------------------
# ToSPSC (migrated from dataflow.py — the paper's §5.2 transformation)
# ---------------------------------------------------------------------------


def _top_tasks(p: Program) -> list[Loop]:
    ts = []
    for item in p.body:
        if not isinstance(item, Loop):
            raise TransformError(
                "to_spsc expects top-level loop nests only")
        ts.append(item)
    return ts


def _task_mem_ops(task: Loop) -> list:
    return _mem_ops_of([task])


def _spsc_targets(p: Program) -> list[tuple[str, set[int], list[int]]]:
    """(array, writer tasks, external consumer tasks) for every array the
    SPSC conversion applies to."""
    tasks = _top_tasks(p)
    writers: dict[str, set[int]] = {}
    readers: dict[str, set[int]] = {}
    for ti, t in enumerate(tasks):
        for op in _task_mem_ops(t):
            d = writers if isinstance(op, StoreOp) else readers
            d.setdefault(op.array, set()).add(ti)
    out = []
    for name in sorted(set(writers) | set(readers)):
        ws = writers.get(name, set())
        rs = sorted(readers.get(name, set()) - ws)
        if len(ws) > 1 or len(rs) <= 1:
            continue
        if ws and p.arrays[name].is_arg:
            continue  # written function argument: cannot be duplicated (2mm)
        if ws and any(rt < tuple(ws)[0] for rt in rs):
            # a consumer running BEFORE the producer reads the array's
            # initial contents — its copy nest (inserted after the producer)
            # could not feed it; such an array is no dataflow channel at all
            continue
        out.append((name, ws, rs))
    return out


def to_spsc(p: Program) -> Program:
    """Insert copy loops so every intermediate array has exactly one consumer
    task, duplicating arrays as the paper did for unsharp/harris/flow.
    Returns ``p`` unchanged (same object) when nothing applies."""
    if not _spsc_targets(p):
        return p
    p = clone_program(p)
    tasks = _top_tasks(p)
    fresh = [0]

    insertions: list[tuple[int, Loop]] = []
    for name, ws, rs in _spsc_targets(p):
        arr = p.arrays[name]
        dups = []
        for k, rt in enumerate(rs):
            dup = f"{name}_cp{k}"
            p.arrays[dup] = dc_replace(arr, name=dup, is_arg=False)
            dups.append(dup)
            # retarget this consumer task's loads
            for op in _task_mem_ops(tasks[rt]):
                if isinstance(op, LoadOp) and op.array == name:
                    op.array = dup
        # build the copy nest: reads `name` row-major, writes all duplicates
        fresh[0] += 1
        tag = f"cp{fresh[0]}"
        H, W = arr.shape[0], arr.shape[1] if len(arr.shape) > 1 else 1
        li = Loop(ivname=f"{tag}i", lb=0, ub=H)
        lj = Loop(ivname=f"{tag}j", lb=0, ub=W)
        li.body = [lj]
        ld = LoadOp(result=f"%{tag}v", array=name,
                    index=(iv(f"{tag}i"), iv(f"{tag}j"))[: len(arr.shape)])
        lj.body = [ld] + [
            StoreOp(array=d, index=(iv(f"{tag}i"), iv(f"{tag}j"))[: len(arr.shape)],
                    value=ld.result) for d in dups]
        # read-only inputs get their copy nest at the top of the function
        insertions.append((tuple(ws)[0] if ws else -1, li))

    # insert copy nests right after their producer task (stable program order)
    for wtask, nest in sorted(insertions, key=lambda x: -x[0]):
        p.body.insert(wtask + 1, nest)
    return p


class ToSPSC(Pass):
    """``to_spsc`` as a pass (multi-consumer arrays become SPSC chains)."""

    name = "to_spsc"
    tag = "spsc"

    def apply(self, p: Program) -> Program:
        return to_spsc(p)


# ---------------------------------------------------------------------------
# Registries (the DSE driver, the pipeline parser and tests iterate these)
# ---------------------------------------------------------------------------

TRANSFORMS: dict[str, Callable[..., Pass]] = {
    "normalize": Normalize,
    "loop_unroll": LoopUnroll,
    "loop_tile": LoopTile,
    "array_partition": ArrayPartition,
    "fuse_producer_consumer": FuseProducerConsumer,
    "to_spsc": ToSPSC,
}

# Textual pipeline syntax (pipeline_parse): tag -> Pass class.  Every class
# implements params()/build() so a pipeline string round-trips through
# parse_pipeline/print_pipeline.
PASS_TAGS: dict[str, type] = {
    cls.tag: cls
    for cls in (Normalize, LoopUnroll, LoopTile, ArrayPartition,
                FuseProducerConsumer, ToSPSC)
}

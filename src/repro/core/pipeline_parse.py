"""Textual pass-pipeline syntax (the ``hls.compile`` front end, DESIGN.md §6).

An MLIR-style comma-separated pipeline string maps one-to-one onto a list of
``transforms.Pass`` objects:

    "normalize,fuse{shift=true,min_core_fraction=0.5},tile{sizes=8,8},unroll{factor=2}"

Grammar (whitespace allowed around every token):

    pipeline :=  [ pass ("," pass)* ]
    pass     :=  NAME [ "{" param ("," param)* "}" ]
    param    :=  KEY "=" value ("," value)*       # extra bare values extend
    value    :=  INT | FLOAT | "true" | "false" | IDENT

so ``tile{sizes=8,8}`` parses ``sizes`` as the list ``[8, 8]`` (a comma
inside braces extends the previous key's value list).  Pass names come from
``transforms.PASS_TAGS``; parameter validation is each pass's ``build()``.

``parse_pipeline`` and ``print_pipeline`` round-trip:

    parse(print(parse(text)))  ==structurally==  parse(text)

(asserted by the property tests in tests/test_api.py).  Errors are
``PipelineSyntaxError`` carrying the source position and a caret line —
the compile front end shows them verbatim.
"""
from __future__ import annotations

import re
from typing import Sequence

from .transforms import PASS_TAGS, Pass, TransformError


class PipelineSyntaxError(ValueError):
    """A malformed pipeline string, with the offending source position."""

    def __init__(self, message: str, text: str, pos: int):
        self.message = message
        self.text = text
        self.pos = pos
        caret = " " * pos + "^"
        super().__init__(
            f"{message}\n  at position {pos}:\n    {text}\n    {caret}")


_NAME = re.compile(r"[A-Za-z_][A-Za-z_0-9.]*")
_VALUE = re.compile(r"[^,={}\s]+")


class _Cursor:
    def __init__(self, text: str):
        self.text = text
        self.i = 0

    def skip_ws(self) -> None:
        while self.i < len(self.text) and self.text[self.i].isspace():
            self.i += 1

    def peek(self) -> str:
        self.skip_ws()
        return self.text[self.i] if self.i < len(self.text) else ""

    def expect(self, ch: str, what: str) -> None:
        if self.peek() != ch:
            got = repr(self.peek()) if self.peek() else "end of input"
            raise PipelineSyntaxError(
                f"expected '{ch}' {what}, got {got}", self.text, self.i)
        self.i += 1

    def match_re(self, rx: re.Pattern, what: str) -> str:
        self.skip_ws()
        m = rx.match(self.text, self.i)
        if not m:
            got = repr(self.text[self.i]) if self.i < len(self.text) \
                else "end of input"
            raise PipelineSyntaxError(
                f"expected {what}, got {got}", self.text, self.i)
        self.i = m.end()
        return m.group(0)

    def done(self) -> bool:
        self.skip_ws()
        return self.i >= len(self.text)


def _typed(tok: str):
    """int / float / bool / bare identifier."""
    if re.fullmatch(r"-?\d+", tok):
        return int(tok)
    try:
        return float(tok)
    except ValueError:
        pass
    if tok == "true":
        return True
    if tok == "false":
        return False
    return tok


def _parse_params(cur: _Cursor) -> dict:
    """The ``{...}`` parameter block.  A bare value (no ``=``) extends the
    previous key's value list, so ``sizes=8,8`` is ``{"sizes": [8, 8]}``."""
    params: dict = {}
    last_key = None
    cur.expect("{", "to open the parameter block")
    if cur.peek() == "}":
        cur.i += 1
        return params
    while True:
        start = cur.i
        cur.skip_ws()
        start = cur.i
        tok = cur.match_re(_VALUE, "a parameter (key=value)")
        if cur.peek() == "=":
            cur.i += 1
            key = tok
            if not _NAME.fullmatch(key):
                raise PipelineSyntaxError(
                    f"invalid parameter name {key!r}", cur.text, start)
            if key in params:
                raise PipelineSyntaxError(
                    f"duplicate parameter {key!r}", cur.text, start)
            val = _typed(cur.match_re(_VALUE, f"a value for '{key}'"))
            params[key] = val
            last_key = key
        else:
            # bare value: extend the previous key's list
            if last_key is None:
                raise PipelineSyntaxError(
                    f"value {tok!r} has no parameter name (write key=value)",
                    cur.text, start)
            prev = params[last_key]
            if not isinstance(prev, list):
                prev = params[last_key] = [prev]
            prev.append(_typed(tok))
        nxt = cur.peek()
        if nxt == ",":
            cur.i += 1
            continue
        if nxt == "}":
            cur.i += 1
            return params
        got = repr(nxt) if nxt else "end of input"
        raise PipelineSyntaxError(
            f"expected ',' or '}}' in the parameter block, got {got}",
            cur.text, cur.i)


def parse_pipeline(text: str) -> list[Pass]:
    """Parse a textual pass pipeline into ``Pass`` objects.

    Raises ``PipelineSyntaxError`` (with the source position) on malformed
    syntax, unknown pass names, and invalid pass parameters.
    """
    if not isinstance(text, str):
        raise TypeError(f"pipeline must be a string, got {type(text).__name__}")
    cur = _Cursor(text)
    passes: list[Pass] = []
    if cur.done():
        return passes
    while True:
        cur.skip_ws()
        start = cur.i
        name = cur.match_re(_NAME, "a pass name")
        cls = PASS_TAGS.get(name)
        if cls is None:
            raise PipelineSyntaxError(
                f"unknown pass {name!r} (known: {', '.join(sorted(PASS_TAGS))})",
                text, start)
        params = _parse_params(cur) if cur.peek() == "{" else {}
        try:
            passes.append(cls.build(params))
        except TransformError as e:
            raise PipelineSyntaxError(str(e), text, start) from e
        if cur.done():
            return passes
        cur.expect(",", "between passes")
        if cur.done():
            raise PipelineSyntaxError(
                "trailing ',' with no pass after it", text, len(text) - 1)


def _fmt_value(v) -> str:
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, float):
        s = repr(v)
        return s
    return str(v)


def _fmt_param(key: str, val) -> str:
    if isinstance(val, (list, tuple)):
        return f"{key}=" + ",".join(_fmt_value(x) for x in val)
    return f"{key}={_fmt_value(val)}"


def print_pipeline(passes: Sequence[Pass]) -> str:
    """The textual form of a pass list; inverse of ``parse_pipeline``."""
    out = []
    for ps in passes:
        if not isinstance(ps, Pass):
            raise TypeError(f"not a Pass: {ps!r}")
        params = ps.params()
        if params:
            body = ",".join(_fmt_param(k, v) for k, v in params.items())
            out.append(f"{ps.tag}{{{body}}}")
        else:
            out.append(ps.tag)
    return ",".join(out)

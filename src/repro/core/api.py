"""Declarative HLS compilation front end (exported as ``repro.core.hls``).

The public surface is one call::

    from repro.core import hls

    result = hls.compile(program, hls.CompileSpec(
        target=hls.Target(capacities={"dsp": 48}),
        objectives=(hls.minimize("latency"), hls.minimize("bram")),
        constraints=("bram <= 1.0x baseline",),
    ))
    result.best          # the design point the objectives select
    result.frontier      # every non-dominated design (pipelines + schedules
                         # + resource vectors) — the Fig. 9 trade-off curve
    result.explain()     # per-candidate accept/reject reasons

``CompileSpec`` carries *what the caller wants* — a ``Target`` (resource
model mode + per-resource capacities), one or more ``Objective``s
(lexicographic by default, ``combine="weighted"`` for scalarization),
``Constraint``s (absolute like ``dsp <= 48`` or relative to the baseline
design like ``bram <= 1.0x baseline``), and optionally a fixed
``pipeline`` — either ``Pass`` objects or the MLIR-style textual syntax
(``"normalize,fuse{shift=true},tile{sizes=8,8},unroll{factor=2}"``,
``pipeline_parse``).  With a pipeline the front end compiles exactly that
program; without one it runs the Pareto-frontier DSE
(``autotune.pareto_explore``) over the move families in ``SearchConfig``.

The old entry points remain importable from ``repro.core`` as deprecated
shims (one ``DeprecationWarning`` at access):

    compile_program(p)    ==  hls.compile(p, pipeline=()).best.schedule
    explore(p, budget)    ==  hls.compile(p, constraints=<budget>) viewed
                              through the legacy DSEResult shape

(DESIGN.md §6 MIGRATION has the full mapping.)
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field, replace as dc_replace
from typing import Optional, Sequence, Union

from . import faults
from .autotune import (DSECandidate, DSEResult, MOVE_FAMILIES,
                       PARETO_METRICS, ParetoResult, _degrading,
                       dedupe_diagnostics, measure_candidate,
                       pareto_explore, validate_candidate)
from .errors import StaticValidationError
from .ir import Program
from .pipeline_parse import parse_pipeline, print_pipeline
from .transforms import Pass

# A design point of the frontier (pipeline + schedule + resource vector).
DesignPoint = DSECandidate

# Short metric aliases accepted anywhere a metric/resource is named.
METRIC_ALIASES = {
    "latency": "latency",
    "bram": "bram_bytes", "bram_bytes": "bram_bytes",
    "dsp": "dsp",
    "ff": "ff_bits", "ff_bits": "ff_bits",
    "lut": "lut",
}


def _canon_metric(name: str, *, what: str = "metric",
                  allow_latency: bool = True) -> str:
    key = METRIC_ALIASES.get(str(name).strip().lower())
    if key is None or (key == "latency" and not allow_latency):
        valid = sorted(k for k, v in METRIC_ALIASES.items()
                       if allow_latency or v != "latency")
        raise ValueError(f"unknown {what} {name!r}; valid: {', '.join(valid)}")
    return key


# ---------------------------------------------------------------------------
# Spec vocabulary: Objective / Constraint / Target / SearchConfig
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Objective:
    """Minimize one metric.  ``weight`` only matters under
    ``combine="weighted"`` (each metric is normalized by the baseline's
    value before weighting, so weights compare like-with-like)."""

    metric: str
    weight: float = 1.0

    def __post_init__(self):
        object.__setattr__(self, "metric", _canon_metric(self.metric,
                                                         what="objective"))
        if self.weight <= 0:
            raise ValueError(f"objective weight must be > 0, got {self.weight}")


def minimize(metric: str, weight: float = 1.0) -> Objective:
    """``minimize("latency")`` / ``minimize("bram", weight=2.0)``."""
    return Objective(metric, weight)


_CONSTRAINT_RE = re.compile(
    r"^\s*(?P<res>[A-Za-z_]+)\s*<=\s*(?P<num>[0-9]*\.?[0-9]+)\s*"
    r"(?P<rel>x\s*baseline)?\s*$")


@dataclass(frozen=True)
class Constraint:
    """An upper bound on one resource: absolute (``limit``) or relative to
    the baseline design's own usage (``scale`` — ``1.0`` = iso-resource)."""

    resource: str
    limit: Optional[float] = None
    scale: Optional[float] = None

    def __post_init__(self):
        object.__setattr__(
            self, "resource",
            _canon_metric(self.resource, what="constraint resource",
                          allow_latency=False))
        if (self.limit is None) == (self.scale is None):
            raise ValueError(
                "a Constraint needs exactly one of limit= (absolute) or "
                "scale= (x baseline)")

    @classmethod
    def parse(cls, text: str) -> "Constraint":
        """``"dsp <= 48"`` (absolute) or ``"bram <= 1.0x baseline"``
        (relative).  Only upper bounds exist — resources are costs."""
        m = _CONSTRAINT_RE.match(text)
        if not m:
            raise ValueError(
                f"malformed constraint {text!r}: expected "
                "'<resource> <= <number>' or "
                "'<resource> <= <number>x baseline' "
                "(resources: bram, dsp, ff, lut)")
        num = float(m.group("num"))
        if m.group("rel"):
            return cls(m.group("res"), scale=num)
        return cls(m.group("res"), limit=num)


def constraint(text: str) -> Constraint:
    """Alias of ``Constraint.parse`` for spec literals."""
    return Constraint.parse(text)


@dataclass(frozen=True)
class Target:
    """Where the design must fit: resource-model mode + hard capacities.

    ``mode`` selects the costing model (``dataflow.resources``): "ours"
    (default), "vitis_seq", or "vitis_dataflow".  ``capacities`` are
    absolute per-resource ceilings of the device (merged with the spec's
    ``Constraint``s; the tighter bound wins)."""

    name: str = "generic"
    mode: str = "ours"
    capacities: tuple[tuple[str, float], ...] = ()

    def __init__(self, name: str = "generic", mode: str = "ours",
                 capacities: Union[dict, Sequence, None] = None):
        if mode not in ("ours", "vitis_seq", "vitis_dataflow"):
            raise ValueError(
                f"unknown target mode {mode!r}; valid: ours, vitis_seq, "
                "vitis_dataflow")
        caps = dict(capacities or {})
        norm = tuple(sorted(
            (_canon_metric(k, what="capacity resource", allow_latency=False),
             float(v)) for k, v in caps.items()))
        object.__setattr__(self, "name", name)
        object.__setattr__(self, "mode", mode)
        object.__setattr__(self, "capacities", norm)


@dataclass(frozen=True)
class SearchConfig:
    """Knobs of the Pareto search (ignored when the spec fixes a
    pipeline).  ``moves`` selects move families out of
    ``autotune.MOVE_FAMILIES``; ``validate`` additionally runs the
    brute-force schedule/execution oracles on the selected best point.

    ``selector`` picks the expansion-base policy ("latency" = classic
    lowest-latency-first, "hv" = hypervolume-contribution over
    archive-normalized objectives); ``macro_moves`` adds composite
    fuse>tile / fuse>unroll single-step moves; ``jobs`` fans candidate
    compiles within one expansion wave across a process pool (results are
    bit-identical to serial); ``cache`` enables the persistent compile
    cache (also gated globally by ``REPRO_HLS_CACHE``).

    ``worker_deadline_s`` bounds each parallel worker's wall-clock per
    candidate — a hung worker past the deadline is retried then
    quarantined instead of stalling the wave (DESIGN.md §9).  Like
    ``jobs`` it does not change results, only how faults are survived,
    so it is excluded from the frontier cache key.

    ``lint`` runs the whole-program IR linter (``analysis.lint``) as a
    pre-pass, feeding findings into ``CompileResult.diagnostics`` (kind
    ``"lint"``); ``static_check`` runs the independent schedule
    translation validator (``analysis.validate_static``, DESIGN.md §12)
    on the frontier winner — a proven violation raises
    :class:`~repro.core.errors.StaticValidationError`.  Both default on;
    degraded-provenance schedules are validated even when
    ``static_check`` is opted out (their conservative edge bounds are
    exactly where an unnoticed miscompile would hide)."""

    moves: tuple[str, ...] = MOVE_FAMILIES
    unroll_factors: tuple[int, ...] = (2, 4)
    tile_sizes: tuple[int, ...] = (4,)
    max_candidates: int = 24
    verify: bool = True
    validate: bool = False
    seeds: tuple[int, ...] = (0,)
    selector: str = "latency"
    macro_moves: bool = False
    jobs: int = 1
    cache: bool = True
    worker_deadline_s: Optional[float] = 60.0
    lint: bool = True
    static_check: bool = True


@dataclass(frozen=True)
class CompileSpec:
    """The declarative compilation request ``hls.compile`` consumes."""

    target: Target = field(default_factory=Target)
    objectives: tuple[Objective, ...] = (Objective("latency"),)
    constraints: tuple[Union[Constraint, str], ...] = ()
    pipeline: Union[str, Sequence[Pass], None] = None
    combine: str = "lex"            # "lex" | "weighted"
    search: SearchConfig = field(default_factory=SearchConfig)

    def __post_init__(self):
        if self.combine not in ("lex", "weighted"):
            raise ValueError(
                f"unknown combine mode {self.combine!r}; valid: lex, weighted")
        objs = tuple(o if isinstance(o, Objective) else minimize(o)
                     for o in self.objectives)
        if not objs:
            raise ValueError("CompileSpec needs at least one objective")
        cons = tuple(Constraint.parse(c) if isinstance(c, str) else c
                     for c in self.constraints)
        for c in cons:
            if not isinstance(c, Constraint):
                raise ValueError(f"not a Constraint: {c!r}")
        object.__setattr__(self, "objectives", objs)
        object.__setattr__(self, "constraints", cons)


# ---------------------------------------------------------------------------
# CompileResult
# ---------------------------------------------------------------------------


@dataclass
class CompileResult:
    """What ``hls.compile`` returns.

    ``frontier`` holds every feasible non-dominated design point (latency ×
    BRAM × DSP × FF), sorted by objective vector; ``best`` is the frontier
    point the spec's objectives select (the baseline when everything was
    rejected — ``explain()`` says why).  ``candidates`` is the full search
    trace including dominated and over-capacity points."""

    program: Program
    spec: CompileSpec
    baseline: DesignPoint
    best: DesignPoint
    frontier: list[DesignPoint] = field(default_factory=list)
    candidates: list[DesignPoint] = field(default_factory=list)
    rejected: list[tuple[str, str]] = field(default_factory=list)
    caps: dict[str, float] = field(default_factory=dict)
    #: candidate evaluations charged against SearchConfig.max_candidates —
    #: invariant between cold and warm-cache runs (a cache hit still counts;
    #: it answers "how much search reached this frontier", not "how much CPU")
    compiles: int = 0
    #: structured failure-handling record (DESIGN.md §9): solver gaps,
    #: worker retries/quarantines, pool rebuilds, cache repairs
    diagnostics: list[dict] = field(default_factory=list)
    #: "degraded" when any diagnostic may have moved the result off the
    #: fault-free one; transparently recovered faults stay "exact"
    provenance: str = "exact"

    @property
    def degraded(self) -> bool:
        """True when a fault forced a conservative (sound but possibly
        suboptimal) answer somewhere — the frontier may differ from the
        fault-free run; ``diagnostics`` says where and why."""
        return self.provenance != "exact"

    @property
    def schedule(self):
        return self.best.schedule

    @property
    def speedup(self) -> float:
        """baseline latency / best latency (1.0 on degenerate latencies)."""
        if self.best.latency <= 0 or self.baseline.latency <= 0:
            return 1.0
        return self.baseline.latency / self.best.latency

    def pipeline_of(self, point: Optional[DesignPoint] = None) -> str:
        """The textual pipeline of a design point (round-trips through
        ``hls.compile(p, pipeline=...)``)."""
        return print_pipeline((point or self.best).passes)

    def knee(self, x: str = "latency", y: str = "bram",
             among: Optional[Sequence[DesignPoint]] = None) -> DesignPoint:
        """The knee point of the (x, y) projection of the frontier: the
        point closest (normalized Euclidean) to the ideal corner
        (min-x, min-y).  Degenerate axes (all equal) contribute zero."""
        pts = list(among if among is not None else self.frontier)
        if not pts:
            raise ValueError("knee() on an empty frontier")
        kx = _canon_metric(x, what="knee axis")
        ky = _canon_metric(y, what="knee axis")
        xs = [c.metric(kx) for c in pts]
        ys = [c.metric(ky) for c in pts]
        rx = (max(xs) - min(xs)) or 1.0
        ry = (max(ys) - min(ys)) or 1.0

        def dist(c):
            return math.hypot((c.metric(kx) - min(xs)) / rx,
                              (c.metric(ky) - min(ys)) / ry)

        return min(pts, key=lambda c: (dist(c), c.objectives()))

    def emit_pallas(self, point: Optional[DesignPoint] = None, *,
                    buffering: str = "double",
                    block_rows: Optional[int] = None,
                    dtype: str = "float32"):
        """Lower a frontier point (default: ``best``) to a generated Pallas
        kernel (DESIGN.md §10).  Returns a :class:`repro.core.codegen.
        PallasKernel`; raises :class:`UnlowerableProgram` — also recorded in
        ``diagnostics`` — when the point's program has no lowering."""
        from . import codegen
        return codegen.emit_pallas(self, point=point, buffering=buffering,
                                   block_rows=block_rows, dtype=dtype)

    def explain(self) -> str:
        """Per-candidate accept/reject reasons, frontier first."""
        lines = ["objectives: " + ", ".join(
            f"minimize({o.metric})" +
            (f"*{o.weight:g}" if o.weight != 1.0 else "")
            for o in self.spec.objectives)]
        if self.caps:
            lines.append("capacities: " + ", ".join(
                f"{k} <= {v:g}" for k, v in sorted(self.caps.items())))
        order = {id(c): i for i, c in enumerate(self.frontier)}
        for c in sorted(self.candidates,
                        key=lambda c: (id(c) not in order,
                                       order.get(id(c), 0), c.desc)):
            mark = " <- best" if c is self.best else ""
            src = " {cache hit}" if c.cached else ""
            deg = " {degraded}" if getattr(c, "provenance", "exact") != "exact" \
                else ""
            lines.append(
                f"  {c.desc}: latency={c.latency} " +
                " ".join(f"{k}={c.res[k]:g}"
                         for k in ("bram_bytes", "dsp", "ff_bits")) +
                f" [{c.status or 'ok'}]{src}{deg}{mark}")
        for desc, reason in self.rejected:
            if not any(c.desc == desc for c in self.candidates):
                lines.append(f"  {desc}: [{reason}]")
        if self.diagnostics:
            counts: dict[str, int] = {}
            for d in self.diagnostics:
                k = str(d.get("kind", "unknown"))
                counts[k] = counts.get(k, 0) + 1
            lines.append(
                f"diagnostics ({'degraded' if self.degraded else 'exact'}): "
                + ", ".join(f"{k} x{n}" for k, n in sorted(counts.items())))
            degr = [d for d in self.diagnostics
                    if d.get("kind") == "solver-degraded"]
            # stable order regardless of which DSE candidate surfaced the
            # gap first: sort by the (src, snk, carry) site, not insertion
            for d in sorted(degr, key=lambda d: (d.get("src") or -1,
                                                 d.get("snk") or -1,
                                                 d.get("carry")
                                                 if d.get("carry") is not None
                                                 else -1)):
                lines.append(
                    f"  solver gap on ({d.get('src')}, {d.get('snk')}) "
                    f"carry={d.get('carry')}: bound={d.get('slack_bound')}"
                    + (f" gap={d['gap']:g}" if d.get("gap") is not None
                       else ""))
            for d in self.diagnostics:
                if d.get("kind") == "lint" and d.get("severity") == "error":
                    lines.append(f"  lint[{d.get('code')}] "
                                 f"{d.get('where')}: {d.get('detail')}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# hls.compile
# ---------------------------------------------------------------------------


def _select_best(frontier: Sequence[DesignPoint], baseline: DesignPoint,
                 spec: CompileSpec) -> DesignPoint:
    if not frontier:
        return baseline
    metrics = [o.metric for o in spec.objectives]
    if spec.combine == "weighted":
        def score(c: DesignPoint) -> float:
            total = 0.0
            for o in spec.objectives:
                base = baseline.metric(o.metric) or 1.0
                total += o.weight * c.metric(o.metric) / base
            return total
        return min(frontier, key=lambda c: (score(c), c.objectives()))
    order = metrics + [m for m in PARETO_METRICS if m not in metrics]
    return min(frontier, key=lambda c: tuple(c.metric(m) for m in order))


def _lint_diagnostics(program: Program) -> list[dict]:
    """The lint pre-pass: whole-program findings as diagnostic dicts."""
    from . import analysis
    return [d.as_dict(kind="lint") for d in analysis.lint(program)]


def _static_check(point, diagnostics: list[dict]) -> None:
    """Post-pass: independently validate the winning schedule (DESIGN.md
    §12).  A *proven* violation raises :class:`StaticValidationError` — it
    means a miscompile, never something to report-and-continue.  Truncated
    emptiness checks (e.g. under injected solver faults) cannot prove
    safety either way; they degrade the result via a
    ``"validate-unresolved"`` diagnostic instead of raising."""
    s = getattr(point, "schedule", None)
    if s is None or not getattr(s, "feasible", True):
        return
    from . import analysis
    v = analysis.validate_static(s.program, s)
    if v.violations:
        raise StaticValidationError(s.program.name, v)
    if v.unresolved:
        diagnostics.append({
            "kind": "validate-unresolved", "program": s.program.name,
            "count": v.unresolved,
            "detail": f"{v.unresolved} of {v.cases} dependence cases "
                      "truncated; schedule safety not independently proven"})


def _resolve_spec(spec: Optional[CompileSpec], overrides: dict) -> CompileSpec:
    spec = spec or CompileSpec()
    if not isinstance(spec, CompileSpec):
        raise TypeError(f"spec must be a CompileSpec, got {type(spec).__name__}")
    clean = {k: v for k, v in overrides.items() if v is not None}
    if "objectives" in clean and not isinstance(clean["objectives"],
                                                (tuple, list)):
        clean["objectives"] = (clean["objectives"],)
    if "constraints" in clean and isinstance(clean["constraints"],
                                             (str, Constraint)):
        clean["constraints"] = (clean["constraints"],)
    return dc_replace(spec, **clean) if clean else spec


def compile(program: Program, spec: Optional[CompileSpec] = None, *,
            target: Optional[Target] = None,
            objectives=None, constraints=None,
            pipeline: Union[str, Sequence[Pass], None] = None,
            combine: Optional[str] = None,
            search: Optional[SearchConfig] = None,
            verbose: bool = False) -> CompileResult:
    """Compile ``program`` per a declarative ``CompileSpec``.

    Keyword arguments override the corresponding spec fields, so quick
    calls need no spec object: ``hls.compile(p, pipeline="fuse,partition")``
    or ``hls.compile(p, constraints=("dsp <= 48",))``.

    * With ``pipeline`` (textual string or ``Pass`` list): parse, verify,
      apply, compile — exactly that design; the frontier is that single
      point (plus the baseline when distinct).
    * Without: run the Pareto-frontier DSE and return the full frontier.

    The empty pipeline ``()`` compiles the program as-is — the
    ``compile_program`` migration path.
    """
    spec = _resolve_spec(spec, dict(target=target, objectives=objectives,
                                    constraints=constraints,
                                    pipeline=pipeline, combine=combine,
                                    search=search))
    sc = spec.search
    caps: dict[str, float] = {}
    rel: dict[str, float] = {}
    for k, v in spec.target.capacities:
        caps[k] = min(caps.get(k, v), v)
    for c in spec.constraints:
        if c.limit is not None:
            caps[c.resource] = min(caps.get(c.resource, c.limit), c.limit)
        else:
            rel[c.resource] = min(rel.get(c.resource, c.scale), c.scale)

    if spec.pipeline is not None:
        passes = parse_pipeline(spec.pipeline) \
            if isinstance(spec.pipeline, str) else list(spec.pipeline)
        for ps in passes:
            if not isinstance(ps, Pass):
                raise TypeError(f"pipeline element is not a Pass: {ps!r}")
        from .cache import get_store
        store = get_store() if sc.cache else None
        repairs0 = store.repairs if store is not None else 0
        ev0 = faults.event_count()
        baseline = measure_candidate(program, "baseline", [],
                                     verify=sc.verify, seeds=sc.seeds,
                                     mode=spec.target.mode, store=store)
        baseline.status = "baseline"
        for k, scale in rel.items():
            ceil = scale * baseline.res[k]
            caps[k] = min(caps.get(k, ceil), ceil)
        if passes:
            point = measure_candidate(program, print_pipeline(passes), passes,
                                      verify=sc.verify, seeds=sc.seeds,
                                      mode=spec.target.mode,
                                      incremental=False, store=store)
            if point is None:   # the WHOLE pipeline applied nothing
                point = baseline
        else:
            point = baseline
        candidates = [baseline] + ([point] if point is not baseline else [])
        rejected: list[tuple[str, str]] = []
        viol = point.res.violations(caps)
        if viol:
            point.within_budget = False
            point.status = "over budget: " + "; ".join(viol)
            rejected.append((point.desc, point.status))
            frontier = []
        else:
            point.within_budget = True
            if point.status != "baseline":
                point.status = "frontier"
            frontier = [point]
        if sc.validate and not viol:
            validate_candidate(point, sc.seeds)
        diagnostics = [dict(d) for d in faults.events_since(ev0)
                       if d.get("kind") != "cache-repair"]
        repaired = (store.repairs - repairs0) if store is not None else 0
        if repaired:
            diagnostics.append({"kind": "cache-repair", "count": repaired})
        diagnostics = dedupe_diagnostics(diagnostics)
        if sc.lint:
            diagnostics[:0] = _lint_diagnostics(program)
        if sc.static_check or \
                getattr(point, "provenance", "exact") != "exact":
            _static_check(point, diagnostics)
        degraded = any(getattr(c, "provenance", "exact") != "exact"
                       for c in candidates) or _degrading(diagnostics)
        return CompileResult(program=program, spec=spec, baseline=baseline,
                             best=point, frontier=frontier,
                             candidates=candidates, rejected=rejected,
                             caps=caps, compiles=len(candidates),
                             diagnostics=diagnostics,
                             provenance="degraded" if degraded else "exact")

    r: ParetoResult = pareto_explore(
        program, caps=caps, rel_caps=rel, moves=sc.moves,
        unroll_factors=sc.unroll_factors, tile_sizes=sc.tile_sizes,
        max_candidates=sc.max_candidates, verify=sc.verify, seeds=sc.seeds,
        mode=spec.target.mode, selector=sc.selector,
        macro_moves=sc.macro_moves, jobs=sc.jobs,
        worker_deadline_s=sc.worker_deadline_s,
        store="auto" if sc.cache else None, verbose=verbose)
    best = _select_best(r.frontier, r.baseline, spec)
    if sc.validate:
        validate_candidate(best, sc.seeds)
    diagnostics = list(r.diagnostics)
    if sc.lint:
        diagnostics[:0] = _lint_diagnostics(program)
    if sc.static_check or \
            getattr(best, "provenance", "exact") != "exact" \
            or r.provenance != "exact":
        _static_check(best, diagnostics)
    degraded = r.provenance != "exact" or _degrading(diagnostics)
    return CompileResult(program=program, spec=spec, baseline=r.baseline,
                         best=best, frontier=r.frontier,
                         candidates=r.candidates, rejected=r.rejected,
                         caps=r.caps, compiles=r.compiles,
                         diagnostics=diagnostics,
                         provenance="degraded" if degraded else "exact")


# ---------------------------------------------------------------------------
# Deprecated shims (surfaced via repro.core.__getattr__ with a
# DeprecationWarning; see DESIGN.md §6 MIGRATION)
# ---------------------------------------------------------------------------


def compile_program(p: Program, verbose: bool = False):
    """Deprecated: ``hls.compile(p, pipeline=()).best.schedule``."""
    if verbose:  # the legacy verbose flag printed autotuner II probes
        from .autotune import compile_program as _impl
        return _impl(p, verbose=True)
    return compile(p, pipeline=()).best.schedule


def explore(p: Program, budget: Optional[dict[str, float]] = None, *,
            unroll_factors: Sequence[int] = (2, 4),
            tile_sizes: Sequence[int] = (4,),
            max_candidates: int = 24,
            verify: bool = True,
            validate: bool = False,
            seeds: Sequence[int] = (0,),
            verbose: bool = False) -> DSEResult:
    """Deprecated: resource-aware DSE in the legacy ``DSEResult`` shape.

    ``budget`` maps resource names to absolute ceilings (unknown keys
    raise); ``budget=None`` is iso-resource (baseline BRAM/DSP).  Now
    backed by the Pareto engine: ``best`` is the budget-feasible
    minimum-latency frontier point; when the budget rejects EVERY
    candidate the baseline is returned as ``best`` (``within_budget``
    False) with the rejection reasons in ``DSEResult.rejections`` /
    ``explain()``.  Equivalent declarative call::

        hls.compile(p, constraints=("bram <= 1.0x baseline",
                                    "dsp <= 1.0x baseline"))
    """
    from .dataflow import RESOURCE_KEYS

    if budget is not None:
        unknown = set(budget) - set(RESOURCE_KEYS)
        if unknown:
            raise ValueError(
                f"unknown budget resource(s) {sorted(unknown)}; "
                f"valid keys: {sorted(RESOURCE_KEYS)}")
        caps, rel = dict(budget), {}
    else:
        caps, rel = {}, {"bram_bytes": 1.0, "dsp": 1.0}

    r = pareto_explore(p, caps=caps, rel_caps=rel,
                       unroll_factors=unroll_factors, tile_sizes=tile_sizes,
                       max_candidates=max_candidates, verify=verify,
                       seeds=seeds, verbose=verbose)
    feasible = [c for c in r.candidates if c.within_budget]
    if feasible:
        best = min(feasible, key=lambda c: (c.latency, c.res["bram_bytes"],
                                            c.res["dsp"], c.res["ff_bits"]))
    else:
        best = r.baseline  # graceful: every candidate rejected
    if validate:
        validate_candidate(best, seeds)
    return DSEResult(baseline=r.baseline, best=best, candidates=r.candidates,
                     budget=r.caps, frontier=r.frontier,
                     rejections=r.rejected)

"""Model of Vitis HLS ``dataflow`` optimization (the paper's §2 baseline).

Vitis overlaps producer/consumer loop nests *at runtime*: an intermediate
array is replaced by a FIFO when the consumer reads elements in exactly the
producer's write order (single-producer-single-consumer only), else by a
ping-pong buffer which gives **no** overlap within one function invocation.
Arrays accessed through function arguments disqualify the whole region.

We reproduce those semantics with (a) a static read/write-order analysis and
(b) a discrete-event simulation of FIFO stalls at loop-iteration granularity,
using the same per-loop IIs as our scheduler (fair: identical inner-loop
hardware, only the inter-nest mechanism differs).

The paper's §5.2 benchmark transformation (``to_spsc``) now lives in the
pass framework (``transforms.ToSPSC``); the name is re-exported here for
compatibility.

The resource model (Fig. 9) is first-order — Vivado is not available in this
container: BRAM bytes (w/ ping-pong doubling + port replication), FF bits
(shift-register delays, handshake state), LUT proxy (sync logic), DSP count
(fp mul=3/add-sub=2, reused across nests only when they run sequentially).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from .errors import NestContractViolation
from .ir import LoadOp, Loop, Program, StoreOp, nest_shape
from .scheduler import Schedule
from .transforms import to_spsc  # noqa: F401  (compatibility re-export)


# ---------------------------------------------------------------------------
# Task/channel analysis
# ---------------------------------------------------------------------------


@dataclass
class Channel:
    array: str
    producer: int          # task index
    consumer: int
    kind: str              # "fifo" | "pingpong"


@dataclass
class DataflowInfo:
    applicable: bool
    reason: str = ""
    channels: list[Channel] = field(default_factory=list)
    #: structured rejection (errors.NestContractViolation) when inapplicable;
    #: ``reason`` stays its string rendering for compat (JSON snapshots).
    diagnostic: Optional[NestContractViolation] = None


def _reject(code: str, detail: str) -> DataflowInfo:
    d = NestContractViolation(code, "dataflow", detail)
    return DataflowInfo(False, reason=detail, diagnostic=d)


def _tasks(p: Program) -> list[Loop]:
    ts = []
    for item in p.body:
        if not isinstance(item, Loop):
            raise ValueError("dataflow model expects top-level loop nests only"
                             " (run transforms.Normalize to sink loose ops)")
        ts.append(item)
    return ts


def _task_accesses(p: Program, task: Loop):
    """All (op, ancestors-within-task) memory ops of a task."""
    out = []

    def rec(items, anc):
        for it in items:
            if isinstance(it, Loop):
                rec(it.body, anc + [it])
            elif isinstance(it, (LoadOp, StoreOp)):
                out.append((it, list(anc)))

    rec(task.body, [task])
    return out


def _subnest_latency(p: Program, s: Schedule, loop: Loop) -> int:
    """Latency of one sub-nest in isolation: max over its ops of the op's
    theta offset from the sub-nest root plus the II-weighted span of the
    loops at-or-below the root (``Schedule.nest_latency`` restricted to an
    arbitrary loop instead of a top-level item)."""
    base = s.theta[loop.uid]
    worst = 0
    for node, anc in p.walk():
        if isinstance(node, Loop):
            continue
        idx = next((i for i, a in enumerate(anc) if a is loop), None)
        if idx is None:
            continue
        span = sum(s.iis[l.uid] * (l.trip - 1) for l in anc[idx:])
        worst = max(worst, s.theta[node.uid] - base + span
                    + p.op_latency(node))
    return worst


def _task_ticks(p: Program, task: Loop, s: Optional[Schedule] = None):
    """Sequential execution points ("ticks") of a task, in program order.

    Returns ``[(static_start, env, ops)]``.  One tick per innermost loop
    iteration of each chain, plus one tick per maximal run of loose ops
    (ops adjacent to a sub-loop — an imperfect nest).  This generalizes the
    old single-counter model: a perfect nest yields exactly its iteration
    space with ``static_start = sum(II_l * iv_l)``; sequential sub-loops
    run back-to-back, each draining fully before its sibling starts (the
    cross-chain sequencing edge of the Vitis model); loose ops advance the
    clock by their summed latency.

    Without a schedule the static starts are all 0 (order-only callers:
    ``_access_sequence``)."""
    ticks: list = []

    def ii_of(l: Loop) -> int:
        return s.iis[l.uid] if s is not None else 0

    def rec(items, env, base) -> int:
        """Emit ticks for one execution of ``items`` starting at ``base``;
        returns the clock after the region completes (drain included)."""
        cur = base
        pending: list = []
        subs_present = any(isinstance(it, Loop) for it in items)

        def flush():
            nonlocal cur
            if pending:
                ticks.append((cur, dict(env), list(pending)))
                if s is not None:
                    cur += sum(p.op_latency(op) for op in pending)
                pending.clear()

        for it in items:
            if isinstance(it, Loop):
                flush()
                for v in range(it.lb, it.ub):
                    env[it.ivname] = v
                    rec(it.body, env, cur + ii_of(it) * (v - it.lb))
                del env[it.ivname]
                cur += _subnest_latency(p, s, it) if s is not None else 0
            else:
                pending.append(it)
        if not subs_present:
            # innermost body: ONE tick per iteration at the II-weighted
            # start (the old model), ops contributing no clock advance
            if pending:
                ticks.append((cur, dict(env), list(pending)))
                pending.clear()
        else:
            flush()
        return cur

    # the task loop itself is part of every chain: passing ``[task]`` (not
    # ``task.body``) makes the root iv enumerate like any other loop
    rec([task], {}, 0)
    return ticks


def _access_sequence(p: Program, task: Loop, array: str, want_write: bool):
    """Sequential (iteration_counter, address) sequence of a task's accesses
    to ``array``.  The iteration counter is the task-wide tick index: the
    flattened innermost index of a perfect nest, and the program-order tick
    of the generalized traversal for multi-loop / imperfect tasks (per-chain
    FIFO order plus cross-chain sequencing)."""
    seq = []
    for q, (_, env, ops) in enumerate(_task_ticks(p, task)):
        for op in ops:
            if not isinstance(op, (LoadOp, StoreOp)) or op.array != array:
                continue
            if isinstance(op, StoreOp) != want_write:
                continue
            seq.append((q, tuple(e.eval(env) for e in op.index)))
    return seq


def analyze_dataflow(p: Program) -> DataflowInfo:
    shape = nest_shape(p)
    for t in shape.tasks:
        if t.kind == "ops":
            return _reject(
                "top-level-ops",
                f"task {t.index} is a bare op, not a loop nest (run "
                "transforms.Normalize to sink loose ops into nests)")
    tasks = _tasks(p)
    # NOTE: multi-loop and imperfect tasks are modeled, not rejected — the
    # generalized tick traversal gives every task a well-defined flattened
    # access order (per-chain FIFO orders + cross-chain sequencing edges).
    # array -> (writer task ids, reader task ids)
    writers: dict[str, set[int]] = {}
    readers: dict[str, set[int]] = {}
    for ti, t in enumerate(tasks):
        for op, _ in _task_accesses(p, t):
            d = writers if isinstance(op, StoreOp) else readers
            d.setdefault(op.array, set()).add(ti)
    channels = []
    for name in p.arrays:
        ws = writers.get(name, set())
        rs_all = readers.get(name, set())
        # every channel in a Vitis dataflow region must be SPSC — including
        # function-argument inputs fanning out to several processes
        if len(ws) > 1:
            return _reject("multi-producer", f"{name} has multiple producers")
        if len(rs_all - ws) > 1:
            return _reject("multi-consumer", f"{name} has multiple consumers")
        cross = {(w, r) for w in ws for r in rs_all if w != r}
        if not cross:
            continue
        arr = p.arrays[name]
        if arr.is_arg:
            return _reject("arg-intermediate",
                           f"intermediate {name} is a function argument")
        (wtask,) = ws
        (rtask,) = tuple(rs_all - ws)
        if rtask < wtask:
            # the consumer runs BEFORE the producer in program order: it
            # reads the array's initial contents, which no channel process
            # network can feed — the region is not a dataflow pipeline
            return _reject(
                "consumer-first",
                f"{name} consumer (task {rtask}) precedes its "
                f"producer (task {wtask})")
        wseq = [a for _, a in _access_sequence(p, tasks[wtask], name, True)]
        rseq = [a for _, a in _access_sequence(p, tasks[rtask], name, False)]
        kind = "fifo" if wseq == rseq else "pingpong"
        channels.append(Channel(name, wtask, rtask, kind))
    return DataflowInfo(True, channels=channels)


# ---------------------------------------------------------------------------
# Discrete-event latency model
# ---------------------------------------------------------------------------


def vitis_dataflow_latency(p: Program, s: Schedule) -> tuple[int, DataflowInfo]:
    """Latency (cycles) of the function under Vitis dataflow semantics.

    Falls back to sequential nest execution when dataflow is inapplicable."""
    info = analyze_dataflow(p)
    if not info.applicable:
        return s.sequential_nests_latency(), info

    tasks = _tasks(p)
    shape = nest_shape(p)
    n = len(tasks)
    # static per-tick times within each task (no stalls).  For a perfect
    # nest the ticks are exactly the flattened iteration space with start
    # sum(II_l * iv_l) — the original single-counter model; generalized
    # shapes additionally serialize sibling sub-loops (drain between
    # chains) and advance past loose ops.
    static_times: list[list[int]] = []
    tails: list[int] = []
    for t in tasks:
        times = ([t0 for t0, _, _ in _task_ticks(p, t, s)]
                 if _task_accesses(p, t) else [])
        static_times.append(times)
        tails.append(s.nest_latency(t) - (len(times) and
                                          (times[-1] - times[0]) or 0))

    # channel bookkeeping
    in_chan: dict[int, list[Channel]] = {i: [] for i in range(n)}
    for ch in info.channels:
        in_chan[ch.consumer].append(ch)

    start: list[list[int]] = [None] * n  # actual iteration start times
    completion: list[int] = [0] * n

    def write_times(ti: int, array: str):
        seq = _access_sequence(p, tasks[ti], array, True)
        wr = p.arrays[array].wr_latency
        # offset of the store inside one tick.  Perfect nests anchor at the
        # task root (original model); generalized shapes anchor at the
        # store's chain root, because the cross-chain serialization is
        # already part of the static tick base (anchoring at the task root
        # would double-count the drain of earlier sibling chains).
        perfect = shape.task(ti).kind == "perfect"
        offs = {}
        for op, anc in _task_accesses(p, tasks[ti]):
            if isinstance(op, StoreOp) and op.array == array:
                anchor = tasks[ti] if perfect or len(anc) < 2 else anc[1]
                offs[op.uid] = s.theta[op.uid] - s.theta[anchor.uid]
        off = min(offs.values()) if offs else 0
        return [start[ti][q] + off + wr for q, _ in seq]

    order = sorted(range(n), key=lambda ti: ti)  # program order is topological
    for ti in order:
        times = static_times[ti]
        ready_full = 0
        fifo_need: list[tuple[list[int], list[int]]] = []  # (per-iter ready,)
        for ch in in_chan[ti]:
            if ch.kind == "pingpong":
                ready_full = max(ready_full, completion[ch.producer])
            else:
                wt = write_times(ch.producer, ch.array)
                rseq = _access_sequence(p, tasks[ti], ch.array, False)
                per_iter: dict[int, int] = {}
                for tok, (q, _) in enumerate(rseq):
                    per_iter[q] = max(per_iter.get(q, 0), wt[tok])
                fifo_need.append(per_iter)
        st = []
        cur = ready_full
        for q in range(len(times)):
            t0 = cur if q == 0 else st[-1] + (times[q] - times[q - 1])
            need = max((d.get(q, 0) for d in fifo_need), default=0)
            st.append(max(t0, need, ready_full))
        start[ti] = st
        completion[ti] = (st[-1] + tails[ti]) if st else 0
    return max(completion), info


# ---------------------------------------------------------------------------
# Resource model (Fig. 9)
# ---------------------------------------------------------------------------

_DSP = {"mul": 3, "add": 2, "sub": 2, "div": 0, "min": 0, "max": 0, "cmp": 0,
        # exp: iterative fp unit built from mul/add stages (~7 DSPs on
        # UltraScale+); emitted only by the tracing frontend, outside the
        # paper's Fig. 9 benchmark set
        "exp": 7}

RESOURCE_KEYS = ("bram_bytes", "ff_bits", "lut", "dsp")


class ResourceVector(dict):
    """Typed resource vector — the four Fig. 9 axes with helpers.

    A ``dict`` subclass (fixed keys ``bram_bytes``/``ff_bits``/``lut``/
    ``dsp``) so existing consumers — JSON serialization, ``res["dsp"]``
    lookups, equality against plain dicts — keep working unchanged, while
    the DSE layer gets attribute access, capacity checks and dominance.
    """

    KEYS = RESOURCE_KEYS

    def __init__(self, bram_bytes: float = 0.0, ff_bits: float = 0.0,
                 lut: float = 0.0, dsp: float = 0.0):
        super().__init__(bram_bytes=float(bram_bytes), ff_bits=float(ff_bits),
                         lut=float(lut), dsp=float(dsp))

    bram_bytes = property(lambda self: self["bram_bytes"])
    ff_bits = property(lambda self: self["ff_bits"])
    lut = property(lambda self: self["lut"])
    dsp = property(lambda self: self["dsp"])

    def as_tuple(self, keys=KEYS) -> tuple[float, ...]:
        return tuple(self[k] for k in keys)

    def fits(self, caps: Optional[dict]) -> bool:
        """True when every capped resource is within its ceiling."""
        return not self.violations(caps)

    def violations(self, caps: Optional[dict]) -> list[str]:
        """Human-readable list of exceeded capacities (empty = fits)."""
        out = []
        for k, v in (caps or {}).items():
            if self.get(k, 0.0) > v + 1e-9:
                out.append(f"{k} {self[k]:g} > {v:g}")
        return out

    def dominates(self, other: dict, tol: float = 1e-9) -> bool:
        """<= on every axis and < on at least one (Pareto dominance over
        the resource axes only; the DSE adds latency as a fifth axis)."""
        le = all(self[k] <= other[k] + tol for k in self.KEYS)
        lt = any(self[k] < other[k] - tol for k in self.KEYS)
        return le and lt


# -- tile-local (streamed line-buffer) footprints ---------------------------


def _top_groups(p: Program) -> list[list]:
    """Top-level items grouped by ``fuse_group`` (a shift-and-peel fusion's
    peel nests + core are ONE hardware nest); singleton groups otherwise."""
    groups: dict = {}
    order: list = []
    for item in p.body:
        g = item.fuse_group if isinstance(item, Loop) else None
        key = ("g", g) if g is not None else ("i", id(item))
        if key not in groups:
            groups[key] = []
            order.append(key)
        groups[key].append(item)
    return [groups[k] for k in order]


def tile_window_elems(p: Program, *, buffers: int = 1) -> dict[str, int]:
    """array -> streamed-window element count for nest-local intermediates
    of explicitly tiled nests (DESIGN.md §6).

    ``buffers`` multiplies each window for multi-buffered (ping-pong)
    codegen footprints: ``codegen.lower_program(buffering="double")``
    overlaps tile ``t+1``'s refill with tile ``t``'s compute, which costs
    ``buffers=2`` copies of every window.  The default (1) is the cost
    model the §6 golden frontiers are pinned against — resource-aware DSE
    keeps using it; only codegen footprint reporting passes ``buffers=2``.

    An intermediate array (``is_arg=False``) whose every access lives in a
    single top-level group whose core nest was strip-mined by ``LoopTile``
    (outermost loop carries ``Loop.tile_block``) never needs full-array
    storage: one outer-tile iteration touches only a bounded window of
    addresses (block rows + stencil halo — exactly the VMEM line buffer the
    Pallas kernel allocates), and the buffer is reused across tiles.  The
    window is, per dim, the extent of the access indices over the inner ivs
    with the outer tile iv fixed; that requires every access to agree on
    the outer iv's coefficient (otherwise the window drifts per tile and we
    conservatively keep the full array).
    """
    groups = _top_groups(p)
    # array -> set of group indices it is accessed from
    where: dict[str, set[int]] = {}
    acc_by_group: list[list] = []
    for gi, items in enumerate(groups):
        accs = []
        for item in items:
            if isinstance(item, Loop):
                accs.extend(_task_accesses(p, item))
        acc_by_group.append(accs)
        for op, _ in accs:
            where.setdefault(op.array, set()).add(gi)

    out: dict[str, int] = {}
    for name, gis in where.items():
        arr = p.arrays[name]
        if arr.is_arg or len(gis) != 1:
            continue
        (gi,) = gis
        core = [it for it in groups[gi]
                if isinstance(it, Loop) and not it.peel]
        if len(core) != 1 or core[0].tile_block is None:
            continue
        outer_iv = core[0].ivname
        accs = [(op, anc) for op, anc in acc_by_group[gi]
                if op.array == name and not any(l.peel for l in anc)]
        if not accs:
            continue  # only peel nests touch it: window undefined, keep full
        window = 1
        ok = True
        for d in range(len(arr.shape)):
            coeffs0 = {e0.coeffs.get(outer_iv, 0)
                       for e0 in (op.index[d] for op, _ in accs)}
            if len(coeffs0) != 1:
                ok = False  # accesses disagree on the tile stride
                break
            los, his = [], []
            for op, anc in accs:
                e = op.index[d]
                lo = hi = e.const
                for ivn, c in e.coeffs.items():
                    if ivn == outer_iv:
                        continue
                    loop = next(l for l in anc if l.ivname == ivn)
                    lo += min(c * loop.lb, c * (loop.ub - 1))
                    hi += max(c * loop.lb, c * (loop.ub - 1))
                los.append(lo)
                his.append(hi)
            extent = max(his) - min(los) + 1
            window *= max(1, min(extent, arr.shape[d]))
        if ok and window < arr.num_elems():
            out[name] = window * max(1, int(buffers))
    return out


def resources(p: Program, s: Schedule, mode: str) -> ResourceVector:
    """mode: 'ours' | 'vitis_seq' (no dataflow) | 'vitis_dataflow'."""
    from .ir import ArithOp

    bram_bytes = 0.0
    ff_bits = 0.0
    lut = 0.0
    window = tile_window_elems(p)
    for arr in p.arrays.values():
        bits = window.get(arr.name, arr.num_elems()) * arr.elem_bits
        fully_part = arr.kind == "reg" or len(arr.partition) == len(arr.shape)
        if fully_part:
            ff_bits += bits
        else:
            repl = max(1, -(-len(arr.ports) // 2))  # BRAM = 2 physical ports
            bram_bytes += bits / 8 * repl

    # tile control: block counters + line-buffer rotation per tiled nest
    for l in p.loops():
        if l.tile_block is not None:
            ff_bits += 64
            lut += 32

    # fp datapath units.  Loops peeled off a shift-and-peel fusion
    # (``Loop.peel``) replicate a subrange of the fused core's body: in
    # hardware they are the same guarded datapath (the IR just lacks
    # conditionals), so their ops are not counted again.  Top-level nests of
    # one fusion additionally share a ``fuse_group`` and are costed once at
    # the group's widest member.
    per_nest_dsp = []
    group_dsp: dict[int, float] = {}
    for item in p.body:
        cnt = 0
        def rec(items):
            nonlocal cnt
            for it in items:
                if isinstance(it, Loop):
                    if not it.peel:
                        rec(it.body)
                elif isinstance(it, ArithOp):
                    cnt += _DSP.get(it.fn, 0)
        if isinstance(item, Loop) and not item.peel:
            rec(item.body)
        g = item.fuse_group if isinstance(item, Loop) else None
        if g is None:
            per_nest_dsp.append(cnt)
        else:
            group_dsp[g] = max(group_dsp.get(g, 0), cnt)
    per_nest_dsp.extend(group_dsp.values())
    dsp = max(per_nest_dsp, default=0) if mode == "vitis_seq" else sum(per_nest_dsp)

    # shift-register delays (ours and Vitis pay comparable pipeline registers;
    # our scheduler explicitly minimizes them — §4.3)
    ff_bits += s.delay_register_bits()

    if mode == "vitis_dataflow":
        info = analyze_dataflow(p)
        if info.applicable:
            for ch in info.channels:
                arr = p.arrays[ch.array]
                bits = arr.num_elems() * arr.elem_bits
                if ch.kind == "pingpong":
                    # double buffering duplicates the storage
                    if arr.kind == "reg" or len(arr.partition) == len(arr.shape):
                        ff_bits += bits
                    else:
                        bram_bytes += bits / 8
                    lut += 180
                    ff_bits += 100
                else:
                    ff_bits += 2 * arr.elem_bits + 70  # FIFO regs + handshake
                    lut += 120
    return ResourceVector(bram_bytes=bram_bytes, ff_bits=ff_bits, lut=lut,
                          dsp=dsp)

"""Dependence analysis: the paper's *memory-dependence ILPs* (§4.1–4.2).

For every ordered pair of conflicting accesses (X source, Y sink) we minimize

    slack = min  ivpart(Y) - ivpart(X)
            s.t. loop bounds, address equality, happens-before

where ``ivpart`` is the II-weighted iteration-vector component of the
schedule time T(op, ivs) = theta_op + sum_l II_l * iv_l.  The scheduling
system then enforces   theta_snk >= theta_src + delay - slack   which makes
T_snk >= T_src + delay hold for *every* conflicting dynamic-instance pair.

Happens-before is handled by lexicographic case-splitting per common-loop
depth (exact, and keeps ILP coefficients small — the paper instead linearizes
sequential time with large strides; both are equivalent for constant bounds).

Port conflicts use the same machinery as pseudo-dependences with the address
equality restricted to completely-partitioned dims (bank equality), exactly
the paper's "assume all operations on the same port have a data dependence".
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from .ilp import solve_ilp
from .ir import ArrayDecl, LoadOp, Loop, Program, StoreOp, position_keys


@dataclass(frozen=True)
class Access:
    op: object  # LoadOp | StoreOp
    ancestors: tuple[Loop, ...]
    array: ArrayDecl
    is_write: bool
    port: int

    @property
    def uid(self):
        return self.op.uid


@dataclass(frozen=True)
class DepEdge:
    """Constraint theta_snk >= theta_src + lower  (lower = delay - slack)."""

    src: int  # op uid
    snk: int
    lower: int
    kind: str  # RAW | WAR | WAW | PORT | SSA | STRUCT
    array: str = ""


def collect_accesses(p: Program) -> list[Access]:
    """Gather memory accesses and assign ports (simple policy: round-robin
    over compatible ports per array, in program order — writes over write
    ports, reads over read ports).  ``reg`` arrays are fully partitioned
    registers and take no port."""
    rr: dict[tuple[str, str], int] = {}
    out = []
    for op, anc in p.walk():
        if not isinstance(op, (LoadOp, StoreOp)):
            continue
        arr = p.arrays[op.array]
        is_write = isinstance(op, StoreOp)
        if arr.kind == "reg":
            port = 0
        else:
            ports = arr.write_ports() if is_write else arr.read_ports()
            if not ports:
                raise ValueError(
                    f"array {arr.name} has no {'write' if is_write else 'read'} port")
            key = (arr.name, "w" if is_write else "r")
            k = rr.get(key, 0)
            port = ports[k % len(ports)]
            rr[key] = k + 1
        op.port = port
        out.append(Access(op=op, ancestors=tuple(anc), array=arr,
                          is_write=is_write, port=port))
    return out


def _common_prefix_len(a: tuple[Loop, ...], b: tuple[Loop, ...]) -> int:
    n = 0
    for x, y in zip(a, b):
        if x is y:
            n += 1
        else:
            break
    return n


class DepAnalysis:
    """Caches memory-dependence-ILP results across autotuner II probes."""

    def __init__(self, p: Program):
        self.p = p
        self.accesses = collect_accesses(p)
        self.pos = position_keys(p)
        self._cache: dict = {}

    # ------------------------------------------------------------------
    def _slack_case(self, X: Access, Y: Access, carry_level: Optional[int],
                    eq_dims: Optional[list[int]], iis: dict[int, int]) -> Optional[int]:
        """Solve one memory-dependence ILP case; None if infeasible (no dep)."""
        la, lb = X.ancestors, Y.ancestors
        key = (X.uid, Y.uid, carry_level, tuple(eq_dims) if eq_dims is not None else None,
               tuple(iis[l.uid] for l in la), tuple(iis[l.uid] for l in lb))
        if key in self._cache:
            return self._cache[key]

        nx, ny = len(la), len(lb)
        n = nx + ny

        def xcol(i):  # source iv columns
            return i

        def ycol(i):
            return nx + i

        bounds = [(l.lb, l.ub - 1) for l in la] + [(l.lb, l.ub - 1) for l in lb]
        A_eq, b_eq, A_ub, b_ub = [], [], [], []

        def name_to_col_src(nm):
            for i, l in enumerate(la):
                if l.ivname == nm:
                    return xcol(i)
            raise KeyError(nm)

        def name_to_col_snk(nm):
            for i, l in enumerate(lb):
                if l.ivname == nm:
                    return ycol(i)
            raise KeyError(nm)

        # address equality on the requested dims
        dims = range(len(X.array.shape)) if eq_dims is None else eq_dims
        if X.op.array == Y.op.array:
            for d in dims:
                ex, ey = X.op.index[d], Y.op.index[d]
                row = np.zeros(n)
                for nm, c in ex.coeffs.items():
                    row[name_to_col_src(nm)] += c
                for nm, c in ey.coeffs.items():
                    row[name_to_col_snk(nm)] -= c
                A_eq.append(row)
                b_eq.append(ey.const - ex.const)

        # happens-before
        pfx = _common_prefix_len(la, lb)
        if carry_level is not None:
            assert carry_level < pfx
            for k in range(carry_level):
                row = np.zeros(n)
                row[xcol(k)] = 1.0
                row[ycol(k)] = -1.0
                A_eq.append(row)
                b_eq.append(0.0)
            row = np.zeros(n)
            row[xcol(carry_level)] = 1.0
            row[ycol(carry_level)] = -1.0
            A_ub.append(row)
            b_ub.append(-1.0)  # iv_src <= iv_snk - 1
        else:
            # loop-independent: all common ivs equal (caller checked program order)
            for k in range(pfx):
                row = np.zeros(n)
                row[xcol(k)] = 1.0
                row[ycol(k)] = -1.0
                A_eq.append(row)
                b_eq.append(0.0)

        # objective: min ivpart(Y) - ivpart(X)
        c = np.zeros(n)
        for i, l in enumerate(la):
            c[xcol(i)] -= iis[l.uid]
        for i, l in enumerate(lb):
            c[ycol(i)] += iis[l.uid]

        res = solve_ilp(c, np.asarray(A_ub) if A_ub else None,
                        np.asarray(b_ub) if b_ub else None,
                        np.asarray(A_eq) if A_eq else None,
                        np.asarray(b_eq) if b_eq else None,
                        bounds=bounds)
        val = int(round(res.fun)) if res.ok else None
        self._cache[key] = val
        return val

    # ------------------------------------------------------------------
    def _slack(self, X: Access, Y: Access, eq_dims: Optional[list[int]],
               iis: dict[int, int]) -> Optional[int]:
        """min slack over all happens-before cases (None = no dependence)."""
        pfx = _common_prefix_len(X.ancestors, Y.ancestors)
        slacks = []
        for lvl in range(pfx):
            s = self._slack_case(X, Y, lvl, eq_dims, iis)
            if s is not None:
                slacks.append(s)
        # loop-independent case only when X syntactically precedes Y
        px, py = self.pos[X.uid], self.pos[Y.uid]
        if X.uid != Y.uid and px < py:
            s = self._slack_case(X, Y, None, eq_dims, iis)
            if s is not None:
                slacks.append(s)
        if not slacks:
            return None
        return min(slacks)

    # ------------------------------------------------------------------
    def memory_edges(self, iis: dict[int, int]) -> list[DepEdge]:
        edges = []
        by_array: dict[str, list[Access]] = {}
        for a in self.accesses:
            by_array.setdefault(a.op.array, []).append(a)
        for name, accs in by_array.items():
            arr = self.p.arrays[name]
            # ---- real data dependences -------------------------------
            for X in accs:
                for Y in accs:
                    if not (X.is_write or Y.is_write):
                        continue
                    if X.is_write and not Y.is_write:
                        kind, delay = "RAW", arr.wr_latency
                    elif not X.is_write and Y.is_write:
                        kind, delay = "WAR", 1
                    else:
                        kind, delay = "WAW", 1
                    s = self._slack(X, Y, None, iis)
                    if s is None:
                        continue
                    edges.append(DepEdge(src=X.uid, snk=Y.uid,
                                         lower=delay - s, kind=kind, array=name))
            # ---- port pseudo-dependences ------------------------------
            if arr.kind == "reg":
                continue
            by_port: dict[int, list[Access]] = {}
            for a in accs:
                by_port.setdefault(a.port, []).append(a)
            part = list(arr.partition)
            for port, paccs in by_port.items():
                for X in paccs:
                    for Y in paccs:
                        s = self._slack(X, Y, part, iis)
                        if s is None:
                            continue
                        edges.append(DepEdge(src=X.uid, snk=Y.uid,
                                             lower=1 - s, kind="PORT", array=name))
        return edges

    # ------------------------------------------------------------------
    def ssa_edges(self) -> list[DepEdge]:
        defs: dict[str, object] = {}
        edges = []
        for op, _ in self.p.walk():
            if isinstance(op, Loop):
                continue
            for a in getattr(op, "args", ()) or ():
                if a in defs:
                    d = defs[a]
                    edges.append(DepEdge(src=d.uid, snk=op.uid,
                                         lower=self.p.op_latency(d), kind="SSA"))
            if isinstance(op, StoreOp) and op.value in defs:
                d = defs[op.value]
                edges.append(DepEdge(src=d.uid, snk=op.uid,
                                     lower=self.p.op_latency(d), kind="SSA"))
            if op.result is not None:
                defs[op.result] = op
        return edges

    def struct_edges(self) -> list[DepEdge]:
        edges = []
        for node, anc in self.p.walk():
            if anc:
                edges.append(DepEdge(src=anc[-1].uid, snk=node.uid, lower=0,
                                     kind="STRUCT"))
        return edges

"""Dependence analysis: the paper's *memory-dependence ILPs* (§4.1–4.2).

For every ordered pair of conflicting accesses (X source, Y sink) we minimize

    slack = min  ivpart(Y) - ivpart(X)
            s.t. loop bounds, address equality, happens-before

where ``ivpart`` is the II-weighted iteration-vector component of the
schedule time T(op, ivs) = theta_op + sum_l II_l * iv_l.  The scheduling
system then enforces   theta_snk >= theta_src + delay - slack   which makes
T_snk >= T_src + delay hold for *every* conflicting dynamic-instance pair.

Happens-before is handled by lexicographic case-splitting per common-loop
depth (exact, and keeps ILP coefficients small — the paper instead linearizes
sequential time with large strides; both are equivalent for constant bounds).

Port conflicts use the same machinery as pseudo-dependences with the address
equality restricted to completely-partitioned dims (bank equality), exactly
the paper's "assume all operations on the same port have a data dependence".

Fast path (DESIGN.md §4): the dependence ILPs produced by affine programs
with constant bounds are almost always *separable* after two rewrites —
merging the prefix-equal ivs of the happens-before case and switching the
common-suffix ivs to difference variables d_l = iv_snk,l - iv_src,l.  What
remains is a box-constrained integer program whose equality rows nearly
always touch one variable (pin it: divisibility + bounds check) or two
(a 2-var linear Diophantine equation: GCD feasibility, then minimize a
linear objective over an interval of the solution parameter).  These are
solved in closed form; only genuinely coupled systems (a residual component
with >=3 variables or >=3 equations) fall back to branch-and-bound
``solve_ilp``.  A crucial corollary: the *feasible region* of every case is
II-independent (IIs only weight the objective), so pair/case feasibility is
decided once at construction and never re-examined across autotuner probes.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Optional

import math

import numpy as np

from . import faults
from .ilp import solve_ilp
from .ir import ArrayDecl, LoadOp, Loop, Program, StoreOp, position_keys


@dataclass(frozen=True)
class Access:
    op: object  # LoadOp | StoreOp
    ancestors: tuple[Loop, ...]
    array: ArrayDecl
    is_write: bool
    port: int

    @property
    def uid(self):
        return self.op.uid


@dataclass(frozen=True)
class DepEdge:
    """Constraint theta_snk >= theta_src + lower  (lower = delay - slack)."""

    src: int  # op uid
    snk: int
    lower: int
    kind: str  # RAW | WAR | WAW | PORT | SSA | STRUCT
    array: str = ""


def collect_accesses(p: Program) -> list[Access]:
    """Gather memory accesses and assign ports (simple policy: round-robin
    over compatible ports per array, in program order — writes over write
    ports, reads over read ports).  ``reg`` arrays are fully partitioned
    registers and take no port."""
    rr: dict[tuple[str, str], int] = {}
    out = []
    for op, anc in p.walk():
        if not isinstance(op, (LoadOp, StoreOp)):
            continue
        arr = p.arrays[op.array]
        is_write = isinstance(op, StoreOp)
        if arr.kind == "reg":
            port = 0
        else:
            ports = arr.write_ports() if is_write else arr.read_ports()
            if not ports:
                raise ValueError(
                    f"array {arr.name} has no {'write' if is_write else 'read'} port")
            key = (arr.name, "w" if is_write else "r")
            k = rr.get(key, 0)
            port = ports[k % len(ports)]
            rr[key] = k + 1
        op.port = port
        out.append(Access(op=op, ancestors=tuple(anc), array=arr,
                          is_write=is_write, port=port))
    return out


def _common_prefix_len(a: tuple[Loop, ...], b: tuple[Loop, ...]) -> int:
    n = 0
    for x, y in zip(a, b):
        if x is y:
            n += 1
        else:
            break
    return n


# ---------------------------------------------------------------------------
# Closed-form affine slack solver (the fast path)
# ---------------------------------------------------------------------------

_FALLBACK = object()  # sentinel: case not separable, use the ILP


def _ext_gcd(a: int, b: int) -> tuple[int, int, int]:
    """(g, p, q) with a*p + b*q == g == gcd(a, b) (g >= 0)."""
    old_r, r = a, b
    old_p, p = 1, 0
    old_q, q = 0, 1
    while r:
        quo = old_r // r
        old_r, r = r, old_r - quo * r
        old_p, p = p, old_p - quo * p
        old_q, q = q, old_q - quo * q
    if old_r < 0:
        old_r, old_p, old_q = -old_r, -old_p, -old_q
    return old_r, old_p, old_q


def _ceil_div(a: int, b: int) -> int:
    return -((-a) // b)


def _param_interval(v0: int, s: int, lo: int, hi: int) -> tuple[int, int]:
    """t-range keeping v0 + s*t inside [lo, hi] (s != 0)."""
    if s > 0:
        return _ceil_div(lo - v0, s), (hi - v0) // s
    return _ceil_div(hi - v0, s), (lo - v0) // s


def _min_diophantine_2var(a: int, b: int, e: int,
                          lu: int, hu: int, lv: int, hv: int,
                          cu: int, cv: int):
    """min cu*u + cv*v  s.t.  a*u + b*v == e, u in [lu,hu], v in [lv,hv],
    all integer.  Returns the value or None (infeasible)."""
    g, p, q = _ext_gcd(a, b)
    if e % g:
        return None
    k = e // g
    u0, v0 = p * k, q * k
    su, sv = b // g, -(a // g)
    tlo1, thi1 = _param_interval(u0, su, lu, hu)
    tlo2, thi2 = _param_interval(v0, sv, lv, hv)
    tlo, thi = max(tlo1, tlo2), min(thi1, thi2)
    if tlo > thi:
        return None
    slope = cu * su + cv * sv
    t = tlo if slope >= 0 else thi
    return cu * (u0 + su * t) + cv * (v0 + sv * t)


def _solve_separable(vars: dict, rows: list):
    """min sum c_v * v over integer vars with box bounds and equality rows.

    ``vars``: vid -> (lo, hi, c).  ``rows``: list of (dict vid->coeff, rhs).
    Returns the optimum (int), None (infeasible), or _FALLBACK when a
    residual component is not closed-form solvable.
    """
    for lo, hi, _ in vars.values():
        if lo > hi:
            return None

    fixed: dict = {}
    rows = [(dict(coeffs), rhs) for coeffs, rhs in rows]
    while True:
        nrows = []
        for coeffs, rhs in rows:
            nc = {}
            for v, a in coeffs.items():
                if v in fixed:
                    rhs -= a * fixed[v]
                else:
                    nc[v] = a
            if not nc:
                if rhs != 0:
                    return None
                continue
            nrows.append((nc, rhs))
        rows = nrows
        newly = False
        for coeffs, rhs in rows:
            if len(coeffs) == 1:
                (v, a), = coeffs.items()
                if v in fixed:
                    if a * fixed[v] != rhs:
                        return None
                    continue
                if rhs % a:
                    return None
                val = rhs // a
                lo, hi, _ = vars[v]
                if not (lo <= val <= hi):
                    return None
                fixed[v] = val
                newly = True
        if not newly:
            break

    total = sum(vars[v][2] * val for v, val in fixed.items())

    # connected components over the residual rows (each row now has >= 2
    # vars, singletons were eliminated).  A row bridging two existing
    # components implies >= 4 coupled variables — beyond the closed form —
    # so bail out immediately instead of merging.
    comp: dict = {}
    comp_rows: dict[int, list] = {}
    next_root = 0
    for coeffs, rhs in rows:
        roots = {comp[v] for v in coeffs if v in comp}
        if len(roots) > 1:
            return _FALLBACK
        if roots:
            root = roots.pop()
        else:
            root = next_root
            next_root += 1
        for v in coeffs:
            comp[v] = root
        comp_rows.setdefault(root, []).append((coeffs, rhs))

    for crows in comp_rows.values():
        cvars = sorted({v for coeffs, _ in crows for v in coeffs})
        if len(cvars) != 2:
            return _FALLBACK
        u, v = cvars
        if len(crows) == 2:
            (c1, e1), (c2, e2) = crows
            a1, b1 = c1.get(u, 0), c1.get(v, 0)
            a2, b2 = c2.get(u, 0), c2.get(v, 0)
            det = a1 * b2 - a2 * b1
            if det != 0:
                un, vn = e1 * b2 - e2 * b1, a1 * e2 - a2 * e1
                if un % det or vn % det:
                    return None
                uu, vv = un // det, vn // det
                if not (vars[u][0] <= uu <= vars[u][1] and
                        vars[v][0] <= vv <= vars[v][1]):
                    return None
                total += vars[u][2] * uu + vars[v][2] * vv
                continue
            # proportional LHS: consistent -> one row; else infeasible
            if a1 * e2 != a2 * e1 or b1 * e2 != b2 * e1:
                return None
            crows = [(c1, e1)]
        if len(crows) != 1:
            return _FALLBACK
        coeffs, rhs = crows[0]
        val = _min_diophantine_2var(coeffs[u], coeffs[v], rhs,
                                    vars[u][0], vars[u][1],
                                    vars[v][0], vars[v][1],
                                    vars[u][2], vars[v][2])
        if val is None:
            return None
        total += val

    for v, (lo, hi, c) in vars.items():
        if v in fixed or v in comp:
            continue
        total += c * lo if c >= 0 else c * hi
    return total


def _fast_slack_case(la: tuple[Loop, ...], lb: tuple[Loop, ...], pfx: int,
                     carry_level: Optional[int], rows: list,
                     iis: dict[int, int]):
    """Closed-form solve of one happens-before case.

    ``rows`` are the address-equality rows over columns x_0..x_{nx-1},
    y_0..y_{ny-1} (source / sink iteration vectors).  Returns the minimum
    slack (int), None (case infeasible), or _FALLBACK.
    """
    nx, ny = len(la), len(lb)
    P = carry_level if carry_level is not None else pfx

    nrows = []
    for coeffs, rhs in rows:
        nc = {}
        for k in range(P):  # prefix-equal: x_k == y_k merged into one var
            a = coeffs.get(k, 0) + coeffs.get(nx + k, 0)
            if a:
                nc[("m", k)] = a
        for k in range(P, pfx):  # common suffix: d_k = y_k - x_k
            cx, cy = coeffs.get(k, 0), coeffs.get(nx + k, 0)
            if cx != -cy:
                return _FALLBACK  # not diagonal-coupled; keep the ILP exact
            if cy:
                nc[("d", k)] = cy
        for i in range(pfx, nx):
            a = coeffs.get(i, 0)
            if a:
                nc[("x", i)] = a
        for j in range(pfx, ny):
            a = coeffs.get(nx + j, 0)
            if a:
                nc[("y", j)] = a
        nrows.append((nc, rhs))

    # variable table: vid -> (lo, hi, objective coefficient).
    # Prefix-merged vars contribute 0 to the objective (same loop, same II);
    # difference vars contribute +II_l; split vars keep their signed II.
    vars: dict = {}
    for k in range(P):
        l = la[k]
        vars[("m", k)] = (l.lb, l.ub - 1, 0)
    for k in range(P, pfx):
        l = la[k]
        span = l.ub - 1 - l.lb
        lo = 1 if carry_level is not None and k == carry_level else -span
        vars[("d", k)] = (lo, span, iis[l.uid])
    for i in range(pfx, nx):
        l = la[i]
        vars[("x", i)] = (l.lb, l.ub - 1, -iis[l.uid])
    for j in range(pfx, ny):
        l = lb[j]
        vars[("y", j)] = (l.lb, l.ub - 1, iis[l.uid])

    return _solve_separable(vars, nrows)


# ---------------------------------------------------------------------------


@dataclass
class _Pair:
    """One conflicting-access candidate, fully analyzed at construction."""

    X: Access
    Y: Access
    kind: str       # RAW | WAR | WAW | PORT
    delay: int
    array: str
    rows: list      # address-equality rows (dict col->coeff, rhs)
    cases: list     # feasible happens-before cases: carry levels and/or None
    loop_uids: tuple[int, ...]  # IIs the slack actually depends on


# ---------------------------------------------------------------------------
# Cross-candidate sharing of the data-dependence half of pair enumeration.
#
# DSE candidates that differ only in array METADATA (partition moves, port
# rewrites) have identical iteration spaces and access functions, so their
# RAW/WAR/WAW pair rows and happens-before case feasibility are identical —
# only the PORT pseudo-dependences (whose address rows are restricted to the
# partitioned dims) change.  ``clone_program`` preserves op/loop uids, so
# the shared results are keyed on an iteration-space fingerprint and looked
# up per (src uid, snk uid, kind).
# ---------------------------------------------------------------------------

DATA_PAIR_ENUM_RUNS = 0   # full (uncached) data-pair enumerations (test probe)
DATA_PAIR_CACHE_HITS = 0  # enumerations served from the shared cache
_DATA_PAIR_CACHE: "OrderedDict[str, dict]" = OrderedDict()
_DATA_PAIR_CACHE_MAX = 64


def cache_stats() -> dict:
    """Hit/miss counters and current size of the module-level data-pair
    cache (bounded at ``_DATA_PAIR_CACHE_MAX`` entries with LRU eviction, so
    long-running serving processes don't grow without limit)."""
    return {"hits": DATA_PAIR_CACHE_HITS, "misses": DATA_PAIR_ENUM_RUNS,
            "entries": len(_DATA_PAIR_CACHE),
            "max_entries": _DATA_PAIR_CACHE_MAX}


def iteration_space_key(p: Program) -> str:
    """Fingerprint of everything the data-dependence pairs depend on: loop
    structure/bounds, access functions, program order (walk order), op uids
    (the cache's lookup keys) and access latencies — NOT array partition,
    ports or storage kind (pure metadata for RAW/WAR/WAW)."""
    parts = []
    for node, _ in p.walk():
        if isinstance(node, Loop):
            parts.append(f"L{node.uid}:{node.ivname}:{node.lb}:{node.ub}")
        elif isinstance(node, (LoadOp, StoreOp)):
            arr = p.arrays[node.array]
            tag = "S" if isinstance(node, StoreOp) else "R"
            parts.append(f"{tag}{node.uid}:{node.array}:{node.index!r}:"
                         f"{arr.wr_latency}:{arr.rd_latency}")
    return "|".join(parts)


class DepAnalysis:
    """Memory-dependence analysis, incremental across autotuner II probes.

    Construction enumerates every conflicting-access pair ONCE, builds its
    address-equality rows, and case-splits happens-before — discarding the
    cases (and whole pairs) whose feasible region is empty, which is an
    II-independent property.  ``memory_edges(iis)`` then only re-evaluates
    the objective of the surviving cases, cached per pair on the IIs of the
    loops actually appearing in that pair's iteration vectors, so a binary
    search probing one loop's II recomputes only the edges touching it.
    """

    def __init__(self, p: Program, fastpath: bool = True,
                 crosscheck: bool = False):
        self.p = p
        self.accesses = collect_accesses(p)
        self.pos = position_keys(p)
        self.fastpath = fastpath
        self.crosscheck = crosscheck
        self.fallback_cases = 0   # cases the closed form could not take
        self.fast_cases = 0
        # truncated-solver degradations: each entry records one dependence
        # case whose slack was replaced by a conservative lower bound.  A
        # non-empty list taints every schedule built from this analysis
        # (Schedule.provenance == "degraded").
        self.degradations: list[dict] = []
        self._degraded_keys: set = set()
        self._edge_cache: dict = {}
        self._static_edges: Optional[list[DepEdge]] = None
        self._nodes: Optional[list] = None
        self._pairs: list[_Pair] = self._enumerate_pairs()

    def all_nodes(self) -> list:
        """Every op/loop node, cached (reused across autotuner probes)."""
        if self._nodes is None:
            self._nodes = [n for n, _ in self.p.walk()]
        return self._nodes

    # ------------------------------------------------------------------
    # pair enumeration (once)
    # ------------------------------------------------------------------
    def _address_rows(self, X: Access, Y: Access,
                      eq_dims: Optional[list[int]]) -> list:
        """Equality rows over columns [x_0..x_{nx-1}, y_0..y_{ny-1}]."""
        la, lb = X.ancestors, Y.ancestors
        nx = len(la)
        src_col = {l.ivname: i for i, l in enumerate(la)}
        snk_col = {l.ivname: nx + i for i, l in enumerate(lb)}
        rows = []
        assert X.op.array == Y.op.array  # pairs come from one array's bucket
        dims = range(len(X.array.shape)) if eq_dims is None else eq_dims
        for d in dims:
            ex, ey = X.op.index[d], Y.op.index[d]
            coeffs: dict[int, int] = {}
            for nm, c in ex.coeffs.items():
                col = src_col[nm]
                coeffs[col] = coeffs.get(col, 0) + c
            for nm, c in ey.coeffs.items():
                col = snk_col[nm]
                coeffs[col] = coeffs.get(col, 0) - c
            rows.append(({k: v for k, v in coeffs.items() if v}, ey.const - ex.const))
        return rows

    def _feasible_cases(self, X: Access, Y: Access, rows: list) -> list:
        """Happens-before cases with a non-empty feasible region (an
        II-independent property: IIs only weight the objective)."""
        ones = {l.uid: 1 for l in X.ancestors + Y.ancestors}
        pfx = _common_prefix_len(X.ancestors, Y.ancestors)
        cases = []
        for lvl in range(pfx):
            if self._case_slack(X, Y, lvl, rows, ones) is not None:
                cases.append(lvl)
        px, py = self.pos[X.uid], self.pos[Y.uid]
        if X.uid != Y.uid and px < py:
            if self._case_slack(X, Y, None, rows, ones) is not None:
                cases.append(None)
        return cases

    def _enumerate_pairs(self) -> list[_Pair]:
        global DATA_PAIR_ENUM_RUNS, DATA_PAIR_CACHE_HITS
        pairs = []
        by_array: dict[str, list[Access]] = {}
        for a in self.accesses:
            by_array.setdefault(a.op.array, []).append(a)

        # data-dependence rows/cases are metadata-independent: share them
        # across candidates with the same iteration-space fingerprint
        key = iteration_space_key(self.p)
        shared = _DATA_PAIR_CACHE.get(key)
        if shared is None:
            DATA_PAIR_ENUM_RUNS += 1
            shared = {}
            _DATA_PAIR_CACHE[key] = shared
            while len(_DATA_PAIR_CACHE) > _DATA_PAIR_CACHE_MAX:
                _DATA_PAIR_CACHE.popitem(last=False)
        else:
            DATA_PAIR_CACHE_HITS += 1
            _DATA_PAIR_CACHE.move_to_end(key)

        for name, accs in by_array.items():
            arr = self.p.arrays[name]
            # ---- real data dependences -------------------------------
            for X in accs:
                for Y in accs:
                    if not (X.is_write or Y.is_write):
                        continue
                    if X.is_write and not Y.is_write:
                        kind, delay = "RAW", arr.wr_latency
                    elif not X.is_write and Y.is_write:
                        kind, delay = "WAR", 1
                    else:
                        kind, delay = "WAW", 1
                    ckey = (X.uid, Y.uid, kind)
                    entry = shared.get(ckey)
                    if entry is None:
                        deg0 = len(self.degradations)
                        rows = self._address_rows(X, Y, None)
                        entry = (rows, self._feasible_cases(X, Y, rows))
                        if len(self.degradations) == deg0:
                            # only clean computations enter the shared
                            # cross-candidate cache; a degraded case list
                            # must not poison fault-free analyses
                            shared[ckey] = entry
                    rows, cases = entry
                    if cases:
                        self._append_pair(pairs, X, Y, kind, delay, name,
                                          rows, cases)
            # ---- port pseudo-dependences (metadata-dependent: fresh) ---
            if arr.kind == "reg":
                continue
            by_port: dict[int, list[Access]] = {}
            for a in accs:
                by_port.setdefault(a.port, []).append(a)
            part = list(arr.partition)
            for port, paccs in by_port.items():
                for X in paccs:
                    for Y in paccs:
                        rows = self._address_rows(X, Y, part)
                        cases = self._feasible_cases(X, Y, rows)
                        if cases:
                            self._append_pair(pairs, X, Y, "PORT", 1, name,
                                              rows, cases)
        return pairs

    def _append_pair(self, pairs, X, Y, kind, delay, name, rows, cases):
        uids = tuple(dict.fromkeys(
            [l.uid for l in X.ancestors] + [l.uid for l in Y.ancestors]))
        pairs.append(_Pair(X=X, Y=Y, kind=kind, delay=delay, array=name,
                           rows=rows, cases=cases, loop_uids=uids))

    # ------------------------------------------------------------------
    # per-case slack
    # ------------------------------------------------------------------
    def _case_slack(self, X: Access, Y: Access, carry_level: Optional[int],
                    rows: list, iis: dict[int, int]) -> Optional[int]:
        """Solve one memory-dependence case; None if infeasible (no dep)."""
        la, lb = X.ancestors, Y.ancestors
        pfx = _common_prefix_len(la, lb)
        if self.fastpath:
            val = _fast_slack_case(la, lb, pfx, carry_level, rows, iis)
            if val is not _FALLBACK:
                self.fast_cases += 1
                if self.crosscheck:
                    deg0 = len(self.degradations)
                    ref = self._ilp_case_slack(X, Y, carry_level, rows, iis)
                    if len(self.degradations) > deg0:
                        # the ILP reference itself was truncated: its value
                        # is a bound, not a ground truth to compare against
                        return val
                    if val != ref:
                        raise AssertionError(
                            f"fast-path slack mismatch: {val} != ILP {ref} "
                            f"({X.op} -> {Y.op}, carry={carry_level})")
                return val
            self.fallback_cases += 1
        return self._ilp_case_slack(X, Y, carry_level, rows, iis)

    def _ilp_case_slack(self, X: Access, Y: Access,
                        carry_level: Optional[int], rows: list,
                        iis: dict[int, int]) -> Optional[int]:
        """Reference path: branch-and-bound ILP on the full case system."""
        la, lb = X.ancestors, Y.ancestors
        nx, ny = len(la), len(lb)
        n = nx + ny
        bounds = [(l.lb, l.ub - 1) for l in la] + [(l.lb, l.ub - 1) for l in lb]
        A_eq, b_eq, A_ub, b_ub = [], [], [], []
        for coeffs, rhs in rows:
            row = np.zeros(n)
            for col, c in coeffs.items():
                row[col] = c
            A_eq.append(row)
            b_eq.append(float(rhs))

        pfx = _common_prefix_len(la, lb)
        if carry_level is not None:
            assert carry_level < pfx
            for k in range(carry_level):
                row = np.zeros(n)
                row[k] = 1.0
                row[nx + k] = -1.0
                A_eq.append(row)
                b_eq.append(0.0)
            row = np.zeros(n)
            row[carry_level] = 1.0
            row[nx + carry_level] = -1.0
            A_ub.append(row)
            b_ub.append(-1.0)  # iv_src <= iv_snk - 1
        else:
            # loop-independent: all common ivs equal (caller checked order)
            for k in range(pfx):
                row = np.zeros(n)
                row[k] = 1.0
                row[nx + k] = -1.0
                A_eq.append(row)
                b_eq.append(0.0)

        # objective: min ivpart(Y) - ivpart(X)
        c = np.zeros(n)
        for i, l in enumerate(la):
            c[i] -= iis[l.uid]
        for i, l in enumerate(lb):
            c[nx + i] += iis[l.uid]

        res = solve_ilp(c, np.asarray(A_ub) if A_ub else None,
                        np.asarray(b_ub) if b_ub else None,
                        np.asarray(A_eq) if A_eq else None,
                        np.asarray(b_eq) if b_eq else None,
                        bounds=bounds)
        if res.ok:
            return int(round(res.fun))
        if res.status == "infeasible":
            return None
        if not res.truncated:
            raise RuntimeError(
                f"dependence-case ILP unresolved ({res.status}) for "
                f"{X.op!r} -> {Y.op!r}")
        # Truncated search (deadline / node cap / injected timeout).  Reading
        # it as "no dependence" would unsoundly prune a real edge — case
        # feasibility is decided once at construction — so degrade to a
        # conservative slack instead: any lower bound on the true minimum
        # under-estimates the slack, which *over*-serializes the schedule
        # (edge lower = delay - slack grows).  Sound, possibly suboptimal.
        lb = res.bound
        if lb is None:
            # no root LP bound either: fall back to the box lower bound of
            # the objective over the variable bounds
            lb = sum(cj * (bounds[j][0] if cj > 0 else bounds[j][1])
                     for j, cj in enumerate(c) if cj)
        slack = int(math.floor(lb + 1e-6))
        dkey = (X.uid, Y.uid, carry_level)
        if dkey not in self._degraded_keys:
            self._degraded_keys.add(dkey)
            info = {"src": X.uid, "snk": Y.uid, "carry": carry_level,
                    "status": res.status, "slack_bound": slack,
                    "incumbent": None if res.fun is None else int(round(res.fun)),
                    "gap": res.gap}
            self.degradations.append(info)
            faults.note("solver-degraded", **info)
        return slack

    def _pair_slack(self, pair: _Pair, iis: dict[int, int]) -> Optional[int]:
        """min slack over the pair's feasible happens-before cases."""
        slacks = [self._case_slack(pair.X, pair.Y, lvl, pair.rows, iis)
                  for lvl in pair.cases]
        slacks = [s for s in slacks if s is not None]
        return min(slacks) if slacks else None

    # ------------------------------------------------------------------
    # edges
    # ------------------------------------------------------------------
    def memory_edges(self, iis: dict[int, int]) -> list[DepEdge]:
        edges = []
        cache = self._edge_cache
        for idx, pair in enumerate(self._pairs):
            key = (idx,) + tuple(iis[u] for u in pair.loop_uids)
            edge = cache.get(key, _FALLBACK)
            if edge is _FALLBACK:
                s = self._pair_slack(pair, iis)
                edge = None if s is None else DepEdge(
                    src=pair.X.uid, snk=pair.Y.uid, lower=pair.delay - s,
                    kind=pair.kind, array=pair.array)
                cache[key] = edge
            if edge is not None:
                edges.append(edge)
        return edges

    # ------------------------------------------------------------------
    def ssa_edges(self) -> list[DepEdge]:
        defs: dict[str, object] = {}
        edges = []
        for op, _ in self.p.walk():
            if isinstance(op, Loop):
                continue
            for a in getattr(op, "args", ()) or ():
                if a in defs:
                    d = defs[a]
                    edges.append(DepEdge(src=d.uid, snk=op.uid,
                                         lower=self.p.op_latency(d), kind="SSA"))
            if isinstance(op, StoreOp) and op.value in defs:
                d = defs[op.value]
                edges.append(DepEdge(src=d.uid, snk=op.uid,
                                     lower=self.p.op_latency(d), kind="SSA"))
            if op.result is not None:
                defs[op.result] = op
        return edges

    def struct_edges(self) -> list[DepEdge]:
        edges = []
        for node, anc in self.p.walk():
            if anc:
                edges.append(DepEdge(src=anc[-1].uid, snk=node.uid, lower=0,
                                     kind="STRUCT"))
        return edges

    def static_edges(self) -> list[DepEdge]:
        """SSA + structural edges: II-independent, computed once."""
        if self._static_edges is None:
            self._static_edges = self.ssa_edges() + self.struct_edges()
        return self._static_edges

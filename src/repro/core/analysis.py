"""Independent static verification: IR linter + schedule translation
validation (DESIGN.md §12).

Two levels, deliberately *outside* the machinery that produces schedules:

**Level 1 — :func:`lint`** is a whole-program IR linter needing no schedule:
affine access bounds vs array shapes (out-of-bounds reads/writes, including
shifted/peeled fusion cores and frontend affine views), use-before-def
across tasks, dead stores / never-read arrays, multi-writer hazards, SSA
scoping, and pragma consistency (tile/partition/unroll/peel markers).  Every
finding is a structured :class:`~repro.core.errors.Diagnostic` — linting
never raises.

**Level 2 — :func:`validate_static`** is a translation validator: given a
``(program, schedule)`` pair it *re-derives* the legality of the (II, theta)
assignment from first principles and checks every conflicting dynamic-
instance pair is separated by its required delay.  Each dependence case
becomes a polyhedral **emptiness check** run directly on the branch-and-
bound :func:`~repro.core.ilp.solve_ilp`:

    exists iteration vectors x (src) and y (snk) with
        loop bounds  AND  address equality  AND  happens-before(case)
        AND  T(snk, y) <= T(src, x) + delay - 1        <- the violation

A feasible point is a concrete counterexample (reported in the verdict); an
infeasible system proves the case safe.  Port/bank conflicts under
``array_partition`` use the same machinery with the address equality
restricted to the partitioned dims and the separation replaced by
equal-time.  The module intentionally shares **nothing** with ``deps.py`` —
no fast-path slack solver, no pair cache, no Access/DepEdge types — so a
bug in the dependence analysis cannot hide itself from the validator (the
only shared substrate is the IR and the generic ILP solver, which deps
itself only trusts as a fallback).

``python -m repro.core.analysis [names... | --all]`` runs the linter (and
optionally the validator) over the benchmark corpus; CI runs it on every
push and fails on any non-pinned error.
"""
from __future__ import annotations

import argparse
import sys
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from .errors import Diagnostic
from .ilp import solve_ilp
from .ir import (AffExpr, ArithOp, LoadOp, Loop, Program, StoreOp,
                 position_keys)

__all__ = ["Diagnostic", "Verdict", "lint", "validate_static",
           "corrupt_schedule", "LINT_CODES", "VALIDATE_CODES",
           "EXPECTED_LINT", "corpus_programs", "main"]


#: Every lint code with a one-line meaning (the DESIGN.md §12 table).
LINT_CODES = {
    "oob-read":       "a load's affine index can exceed the array bounds",
    "oob-write":      "a store's affine index can exceed the array bounds",
    "rank-mismatch":  "access index arity differs from the array rank",
    "unknown-array":  "access names an undeclared array",
    "unbound-iv":     "access index uses a variable no enclosing loop binds",
    "read-uninitialized": "non-arg array is read but never written",
    "use-before-def": "non-arg array's first access in program order is a read",
    "never-read":     "non-arg array is written but never read (dead stores)",
    "unused-array":   "declared array is never accessed",
    "multi-writer":   "array written by several tasks, none of them a carry",
    "nonzero-base":   "non-unrolled loop lower bound != 0 (normalize contract)",
    "empty-loop":     "loop trip count <= 0",
    "bad-ii":         "explicit II pragma < 1",
    "unnormalized-unroll": "unroll marker survived normalization",
    "tile-marker":    "tile_block marker inconsistent with the strip pair",
    "orphan-peel":    "top-level peel loop without a fuse group",
    "partition-dim":  "array_partition names an out-of-range/duplicate dim",
    "missing-port":   "array accessed through a port kind it does not have",
    "undef-ssa":      "op consumes an SSA name with no visible definition",
    "unknown-fn":     "ArithOp.fn has no latency in Program.op_delays",
}

#: Every validator code (all severity "error" — a failed verdict).
VALIDATE_CODES = {
    "missing-ii":     "schedule has no II for a loop",
    "missing-theta":  "schedule has no start offset for an op/loop",
    "infeasible-schedule": "schedule is marked infeasible",
    "occupancy":      "II_outer < trip_inner * II_inner for a nested loop",
    "ssa-order":      "a use starts before its def's latency has elapsed",
    "struct-order":   "an op starts before its enclosing loop",
    "dep-violated":   "a conflicting instance pair runs closer than its delay",
    "port-conflict":  "two same-port same-bank accesses in the same cycle",
    "fuse-no-core":   "a fuse group consists only of peel loops",
    "orphan-peel":    "top-level peel loop without a fuse group",
    "unresolved":     "an emptiness check was truncated (cannot prove safety)",
}

#: Pinned expected lint findings per corpus program (satellite goldens):
#: ``name -> {code, ...}``.  The CLI (and CI) fails on any error-severity
#: finding whose code is not pinned here; pinned codes are reported but
#: accepted.  An empty corpus entry means "must lint clean".
EXPECTED_LINT: dict[str, set] = {}


# ---------------------------------------------------------------------------
# Level 1 — IR linter
# ---------------------------------------------------------------------------


def _iv_bounds(ancestors: Sequence[Loop]) -> dict[str, tuple[int, int]]:
    """Inclusive [lb, ub-1] range per enclosing iv, inner shadowing outer."""
    return {l.ivname: (l.lb, l.ub - 1) for l in ancestors}


def _lint_arrays(p: Program, out: list[Diagnostic]) -> None:
    for arr in p.arrays.values():
        rank = len(arr.shape)
        seen = set()
        for d in arr.partition:
            if not (0 <= d < rank) or d in seen:
                out.append(Diagnostic(
                    "partition-dim", f"{p.name}/{arr.name}", "error",
                    f"partition dim {d} invalid for rank-{rank} array "
                    f"{arr.name} (partition={arr.partition})"))
            seen.add(d)


def _lint_loops(p: Program, out: list[Diagnostic]) -> None:
    top = {id(it) for it in p.body}
    for loop, anc in p.walk():
        if not isinstance(loop, Loop):
            continue
        where = f"{p.name}/loop {loop.ivname}"
        if loop.trip <= 0:
            out.append(Diagnostic("empty-loop", where, "warning",
                                  f"trip count {loop.trip} <= 0 "
                                  f"([{loop.lb}, {loop.ub}))"))
        if not loop.unroll and loop.lb != 0:
            out.append(Diagnostic(
                "nonzero-base", where, "error",
                f"lower bound {loop.lb} != 0 on a non-unrolled loop "
                "(the normalize contract; scheduler latency accounting "
                "assumes rebased loops)"))
        if loop.unroll:
            out.append(Diagnostic(
                "unnormalized-unroll", where, "warning",
                "unroll marker present — normalize() should have expanded "
                "this loop"))
        if loop.ii is not None and loop.ii < 1:
            out.append(Diagnostic("bad-ii", where, "error",
                                  f"explicit II pragma {loop.ii} < 1"))
        if loop.tile_block is not None:
            subs = loop.sub_loops()
            ok = (len(loop.body) == 1 and len(subs) == 1
                  and subs[0].trip == loop.tile_block)
            if not ok:
                out.append(Diagnostic(
                    "tile-marker", where, "error",
                    f"tile_block={loop.tile_block} but the strip pair is "
                    f"gone (body has {len(loop.body)} items, inner trips "
                    f"{[s.trip for s in subs]})"))
        if loop.peel and id(loop) in top and loop.fuse_group is None:
            out.append(Diagnostic(
                "orphan-peel", where, "warning",
                "top-level peel loop carries no fuse_group — its datapath "
                "cannot be shared with a fused core"))


def _lint_accesses(p: Program, out: list[Diagnostic]) -> None:
    for op, anc in p.walk():
        if not isinstance(op, (LoadOp, StoreOp)):
            continue
        what = "load" if isinstance(op, LoadOp) else "store"
        where = f"{p.name}/{op.array}[{what} uid={op.uid}]"
        arr = p.arrays.get(op.array)
        if arr is None:
            out.append(Diagnostic("unknown-array", where, "error",
                                  f"{what} of undeclared array {op.array!r}"))
            continue
        if len(op.index) != len(arr.shape):
            out.append(Diagnostic(
                "rank-mismatch", where, "error",
                f"{what} index rank {len(op.index)} != array rank "
                f"{len(arr.shape)}"))
            continue
        bounds = _iv_bounds(anc)
        for d, e in enumerate(op.index):
            e = e if isinstance(e, AffExpr) else AffExpr({}, int(e))
            missing = [n for n in e.coeffs if n not in bounds]
            if missing:
                out.append(Diagnostic(
                    "unbound-iv", where, "error",
                    f"index dim {d} uses unbound variable(s) {missing} "
                    f"(enclosing ivs: {sorted(bounds)})"))
                continue
            lo, hi = e.interval(bounds)
            if lo < 0 or hi >= arr.shape[d]:
                out.append(Diagnostic(
                    "oob-write" if what == "store" else "oob-read",
                    where, "error",
                    f"index dim {d} = {e!r} ranges [{lo}, {hi}] outside "
                    f"[0, {arr.shape[d]})"))
        if arr.kind != "reg":
            ports = (arr.write_ports() if what == "store"
                     else arr.read_ports())
            if not ports:
                out.append(Diagnostic(
                    "missing-port", where, "error",
                    f"{what} of {arr.name} but ports={arr.ports} has no "
                    f"{'write' if what == 'store' else 'read'} port"))


def _task_index(p: Program) -> dict[int, int]:
    """op/loop uid -> index of its top-level task in ``Program.body``."""
    tix: dict[int, int] = {}
    for i, item in enumerate(p.body):
        tix[item.uid] = i
        if isinstance(item, Loop):
            stack = list(item.body)
            while stack:
                it = stack.pop()
                tix[it.uid] = i
                if isinstance(it, Loop):
                    stack.extend(it.body)
    return tix


def _lint_liveness(p: Program, out: list[Diagnostic]) -> None:
    first: dict[str, str] = {}      # array -> "r" | "w" of first access
    readers: dict[str, set] = {}    # array -> reader task indices
    writers: dict[str, set] = {}    # array -> writer task indices
    tix = _task_index(p)
    for op, _ in p.walk():
        if isinstance(op, LoadOp):
            first.setdefault(op.array, "r")
            readers.setdefault(op.array, set()).add(tix[op.uid])
        elif isinstance(op, StoreOp):
            first.setdefault(op.array, "w")
            writers.setdefault(op.array, set()).add(tix[op.uid])
    for name, arr in p.arrays.items():
        where = f"{p.name}/{name}"
        rs, ws = readers.get(name, set()), writers.get(name, set())
        if not rs and not ws:
            out.append(Diagnostic("unused-array", where, "warning",
                                  f"array {name} is never accessed"))
            continue
        if arr.is_arg:
            pass  # args are externally initialized and externally observed
        elif rs and not ws:
            out.append(Diagnostic(
                "read-uninitialized", where, "error",
                f"non-arg array {name} is read but never written"))
        elif ws and not rs:
            out.append(Diagnostic(
                "never-read", where, "warning",
                f"non-arg array {name} is written but never read "
                "(dead stores)"))
        elif first.get(name) == "r":
            out.append(Diagnostic(
                "use-before-def", where, "warning",
                f"non-arg array {name} is read before its first write in "
                "program order (initial contents are undefined)"))
        # multi-writer: several top-level tasks store the array and none of
        # them also reads it (a read-write task is a recurrence carry, e.g.
        # a scan; fused peel+core groups share one datapath and are exempt)
        if len(ws) > 1 and not (ws & rs):
            groups = set()
            for i in ws:
                item = p.body[i]
                groups.add(item.fuse_group
                           if isinstance(item, Loop) else None)
            if len(groups) > 1 or groups == {None}:
                out.append(Diagnostic(
                    "multi-writer", where, "warning",
                    f"array {name} is written by tasks {sorted(ws)} with no "
                    "carry/fuse relationship (dataflow multi-producer "
                    "hazard)"))


def _lint_ssa(p: Program, out: list[Diagnostic]) -> None:
    def run(items, visible: set):
        for it in items:
            if isinstance(it, Loop):
                run(it.body, set(visible))
                continue
            where = f"{p.name}/op uid={it.uid}"
            uses = list(getattr(it, "args", ()) or ())
            if isinstance(it, StoreOp) and it.value:
                uses.append(it.value)
            for a in uses:
                if a not in visible:
                    out.append(Diagnostic(
                        "undef-ssa", where, "error",
                        f"op consumes SSA name {a!r} with no visible def "
                        "(defined in a sibling scope or not at all)"))
            if isinstance(it, ArithOp) and it.fn not in p.op_delays:
                out.append(Diagnostic(
                    "unknown-fn", where, "error",
                    f"ArithOp fn {it.fn!r} has no latency in op_delays"))
            if it.result is not None:
                visible.add(it.result)

    run(p.body, set())


def lint(program: Program) -> list[Diagnostic]:
    """Run every whole-program check; returns findings in a stable
    severity-first order.  Never raises on malformed programs — every
    problem becomes a :class:`Diagnostic`."""
    out: list[Diagnostic] = []
    _lint_arrays(program, out)
    _lint_loops(program, out)
    _lint_accesses(program, out)
    _lint_liveness(program, out)
    _lint_ssa(program, out)
    return sorted(out, key=Diagnostic.sort_key)


# ---------------------------------------------------------------------------
# Level 2 — schedule translation validation
# ---------------------------------------------------------------------------


@dataclass
class Verdict:
    """Result of :func:`validate_static`.

    ``ok`` is True only when every re-derived constraint was *proved*
    preserved: any violation witness or truncated (unprovable) emptiness
    check makes it False.  ``diagnostics`` carries one entry per problem;
    ``pairs``/``cases``/``ilp_calls`` record how much was checked (the
    interval prefilter resolves most cases without an ILP)."""

    ok: bool
    diagnostics: list[Diagnostic] = field(default_factory=list)
    pairs: int = 0
    cases: int = 0
    ilp_calls: int = 0
    unresolved: int = 0

    @property
    def violations(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics
                if d.severity == "error" and d.code != "unresolved"]

    def as_dicts(self) -> list[dict]:
        return [d.as_dict(kind="validate-static") for d in self.diagnostics]

    def __str__(self) -> str:
        head = ("ok" if self.ok else
                f"FAIL ({len(self.violations)} violations, "
                f"{self.unresolved} unresolved)")
        return (f"{head}: {self.pairs} pairs, {self.cases} cases, "
                f"{self.ilp_calls} ILP emptiness checks")


@dataclass(frozen=True)
class _Acc:
    """One memory access with its iteration context (re-derived locally —
    deliberately not deps.Access)."""

    op: object
    anc: tuple[Loop, ...]
    is_write: bool
    port: int

    @property
    def uid(self):
        return self.op.uid


def _collect(p: Program) -> dict[str, list[_Acc]]:
    """Accesses bucketed per array, ports resolved.

    Ports already assigned on the ops (by a prior scheduling run) are kept —
    they are part of the design being validated.  Unassigned ports (-1) are
    resolved with the documented policy (round-robin over compatible ports
    per array in program order) without mutating the program."""
    rr: dict[tuple[str, str], int] = {}
    by_array: dict[str, list[_Acc]] = {}
    for op, anc in p.walk():
        if not isinstance(op, (LoadOp, StoreOp)):
            continue
        arr = p.arrays[op.array]
        is_write = isinstance(op, StoreOp)
        if arr.kind == "reg":
            port = 0
        elif op.port >= 0:
            port = op.port
        else:
            ports = arr.write_ports() if is_write else arr.read_ports()
            if not ports:
                continue  # lint reports missing-port; nothing to bank-check
            key = (op.array, "w" if is_write else "r")
            k = rr.get(key, 0)
            port = ports[k % len(ports)]
            rr[key] = k + 1
        by_array.setdefault(op.array, []).append(
            _Acc(op=op, anc=tuple(anc), is_write=is_write, port=port))
    return by_array


def _prefix_len(a: tuple[Loop, ...], b: tuple[Loop, ...]) -> int:
    n = 0
    for x, y in zip(a, b):
        if x is not y:
            break
        n += 1
    return n


def _sep_range(X: _Acc, Y: _Acc, carry: Optional[int],
               iis: dict[int, int], theta: dict[int, int],
               want_hi: bool = False) -> tuple[int, int]:
    """Box bounds of T(Y, y) - T(X, x) over the case's iteration region,
    *ignoring address equality* — a sound relaxation used to skip the ILP:
    if even this lower bound reaches the delay, no instance pair of the
    case can violate it."""
    lo = hi = theta[Y.uid] - theta[X.uid]
    pfx = _prefix_len(X.anc, Y.anc)
    for k in range(pfx):
        l = X.anc[k]
        w, span = iis[l.uid], l.trip - 1
        if carry is None or k < carry:
            d_lo = d_hi = 0            # pinned equal
        elif k == carry:
            d_lo, d_hi = 1, span       # iv_src <= iv_snk - 1
        else:
            d_lo, d_hi = -span, span   # free after the carry level
        lo += w * d_lo
        hi += w * d_hi
    for l in X.anc[pfx:]:
        w = iis[l.uid]
        lo -= w * (l.ub - 1)
        hi -= w * l.lb
    for l in Y.anc[pfx:]:
        w = iis[l.uid]
        lo += w * l.lb
        hi += w * (l.ub - 1)
    return lo, hi


def _case_system(X: _Acc, Y: _Acc, carry: Optional[int],
                 eq_dims: Optional[Sequence[int]], arr_index_pairs) \
        -> tuple[list, list, list, list, list]:
    """Shared polyhedron of one happens-before case: loop bounds, address
    equality over ``eq_dims`` (None = every dim), prefix/carry rows.
    Columns are [x_0..x_{nx-1}, y_0..y_{ny-1}]."""
    la, lb_ = X.anc, Y.anc
    nx, n = len(la), len(la) + len(lb_)
    bounds = ([(l.lb, l.ub - 1) for l in la]
              + [(l.lb, l.ub - 1) for l in lb_])
    src_col = {l.ivname: i for i, l in enumerate(la)}
    snk_col = {l.ivname: nx + i for i, l in enumerate(lb_)}
    A_eq, b_eq, A_ub, b_ub = [], [], [], []
    for ex, ey in arr_index_pairs if eq_dims is None else \
            [arr_index_pairs[d] for d in eq_dims]:
        row = np.zeros(n)
        for nm, c in ex.coeffs.items():
            row[src_col[nm]] += c
        for nm, c in ey.coeffs.items():
            row[snk_col[nm]] -= c
        A_eq.append(row)
        b_eq.append(float(ey.const - ex.const))
    pfx = _prefix_len(la, lb_)
    stop = pfx if carry is None else carry
    for k in range(stop):
        row = np.zeros(n)
        row[k], row[nx + k] = 1.0, -1.0
        A_eq.append(row)
        b_eq.append(0.0)
    if carry is not None:
        row = np.zeros(n)
        row[carry], row[nx + carry] = 1.0, -1.0
        A_ub.append(row)
        b_ub.append(-1.0)  # iv_src <= iv_snk - 1
    return bounds, A_eq, b_eq, A_ub, b_ub


def _time_row(X: _Acc, Y: _Acc, iis: dict[int, int]) -> np.ndarray:
    """Coefficients of T(Y, y) - T(X, x) on [x..., y...] (thetas go to the
    right-hand side)."""
    nx, n = len(X.anc), len(X.anc) + len(Y.anc)
    row = np.zeros(n)
    for i, l in enumerate(X.anc):
        row[i] -= iis[l.uid]
    for i, l in enumerate(Y.anc):
        row[nx + i] += iis[l.uid]
    return row


class _Validator:
    def __init__(self, p: Program, s, fail_fast: bool):
        self.p = p
        self.s = s
        self.fail_fast = fail_fast
        self.v = Verdict(ok=True)
        self.pos = position_keys(p)

    def diag(self, code: str, where: str, detail: str) -> None:
        self.v.diagnostics.append(Diagnostic(code, where, "error", detail))
        self.v.ok = False

    @property
    def done(self) -> bool:
        return self.fail_fast and not self.v.ok

    # -- cheap structural re-checks ------------------------------------
    def check_complete(self) -> bool:
        s, p = self.s, self.p
        if not getattr(s, "feasible", True):
            self.diag("infeasible-schedule", p.name,
                      "schedule is marked infeasible")
            return False
        ok = True
        for l in p.loops():
            if s.iis.get(l.uid, 0) < 1:
                self.diag("missing-ii", f"{p.name}/loop {l.ivname}",
                          f"II {s.iis.get(l.uid)!r} missing or < 1")
                ok = False
        for node, _ in p.walk():
            if node.uid not in s.theta:
                self.diag("missing-theta", f"{p.name}/uid={node.uid}",
                          "no start offset in the schedule")
                ok = False
        return ok

    def check_occupancy(self) -> None:
        iis = self.s.iis
        for node, anc in self.p.walk():
            if self.done:
                return
            if isinstance(node, Loop) and anc:
                parent = anc[-1]
                need = node.trip * iis[node.uid]
                if iis[parent.uid] < need:
                    self.diag(
                        "occupancy",
                        f"{self.p.name}/loop {parent.ivname}",
                        f"II {iis[parent.uid]} < trip({node.ivname}) * "
                        f"II({node.ivname}) = {need}: the inner pipeline "
                        "is re-entered before it drains")

    def check_ssa_struct(self) -> None:
        p, theta = self.p, self.s.theta
        defs: dict[str, object] = {}
        for op, anc in p.walk():
            if self.done:
                return
            if anc and theta[op.uid] < theta[anc[-1].uid]:
                self.diag("struct-order", f"{p.name}/uid={op.uid}",
                          f"starts at {theta[op.uid]} before its loop "
                          f"{anc[-1].ivname} at {theta[anc[-1].uid]}")
            if isinstance(op, Loop):
                continue
            uses = list(getattr(op, "args", ()) or ())
            if isinstance(op, StoreOp) and op.value:
                uses.append(op.value)
            for a in uses:
                d = defs.get(a)
                if d is None:
                    continue  # lint's undef-ssa territory
                lat = p.op_latency(d)
                if theta[op.uid] < theta[d.uid] + lat:
                    self.diag(
                        "ssa-order", f"{p.name}/uid={op.uid}",
                        f"use of {a!r} at {theta[op.uid]} before def "
                        f"uid={d.uid} completes at {theta[d.uid]} + {lat}")
            if op.result is not None:
                defs[op.result] = op

    def check_fusion_markers(self) -> None:
        groups: dict[int, list[Loop]] = {}
        for item in self.p.body:
            if isinstance(item, Loop):
                if item.fuse_group is not None:
                    groups.setdefault(item.fuse_group, []).append(item)
                elif item.peel:
                    self.diag("orphan-peel",
                              f"{self.p.name}/loop {item.ivname}",
                              "top-level peel loop without a fuse group")
        for g, members in sorted(groups.items()):
            if all(m.peel for m in members):
                self.diag(
                    "fuse-no-core", f"{self.p.name}/fuse_group {g}",
                    f"group {g} has only peel loops "
                    f"({[m.ivname for m in members]}) — the core they "
                    "replicate is gone")

    # -- the polyhedral emptiness checks -------------------------------
    def _empty(self, X: _Acc, Y: _Acc, carry: Optional[int],
               eq_dims, index_pairs, *, delay: Optional[int]) \
            -> tuple[Optional[bool], Optional[list]]:
        """Is the case's violation region empty?  ``delay=None`` means the
        port equal-time check.  Returns (empty, witness): (True, None) —
        proved safe, (False, x) — concrete counterexample, (None, None) —
        truncated search, safety unproven."""
        iis, theta = self.s.iis, self.s.theta
        lo, hi = _sep_range(X, Y, carry, iis, theta)
        if delay is not None:
            if lo >= delay:
                return True, None
        elif lo > 0 or hi < 0:
            return True, None
        bounds, A_eq, b_eq, A_ub, b_ub = _case_system(
            X, Y, carry, eq_dims, index_pairs)
        trow = _time_row(X, Y, iis)
        dtheta = theta[X.uid] - theta[Y.uid]
        if delay is not None:
            A_ub.append(trow)
            b_ub.append(float(dtheta + delay - 1))
        else:
            A_eq.append(trow)
            b_eq.append(float(dtheta))
        n = len(bounds)
        self.v.ilp_calls += 1
        res = solve_ilp(np.zeros(n),
                        np.asarray(A_ub) if A_ub else None,
                        np.asarray(b_ub) if b_ub else None,
                        np.asarray(A_eq) if A_eq else None,
                        np.asarray(b_eq) if b_eq else None,
                        bounds=bounds)
        if res.status == "infeasible":
            return True, None
        if res.x is not None:
            return False, [int(round(v)) for v in res.x]
        self.v.unresolved += 1
        return None, None

    def _report(self, kind: str, X: _Acc, Y: _Acc, array: str,
                carry: Optional[int], empty: Optional[bool],
                witness, delay: Optional[int]) -> None:
        where = f"{self.p.name}/{array}[{X.uid}->{Y.uid}]"
        case = "loop-independent" if carry is None else f"carry={carry}"
        if empty is False:
            if delay is None:
                self.diag("port-conflict", where,
                          f"port {X.port} accesses uid={X.uid} and "
                          f"uid={Y.uid} collide in one cycle at "
                          f"ivs={witness} ({case})")
            else:
                self.diag("dep-violated", where,
                          f"{kind} separation < {delay} at ivs={witness} "
                          f"({case})")
        elif empty is None:
            self.v.ok = False
            self.v.diagnostics.append(Diagnostic(
                "unresolved", where, "error",
                f"{kind} emptiness check truncated ({case}) — cannot "
                "prove the schedule safe"))

    def check_dependences(self, by_array: dict[str, list[_Acc]]) -> None:
        wr_lat = {n: a.wr_latency for n, a in self.p.arrays.items()}
        for name in sorted(by_array):
            accs = by_array[name]
            for X in accs:
                for Y in accs:
                    if self.done:
                        return
                    if not (X.is_write or Y.is_write):
                        continue
                    if X.is_write and not Y.is_write:
                        kind, delay = "RAW", wr_lat[name]
                    elif not X.is_write and Y.is_write:
                        kind, delay = "WAR", 1
                    else:
                        kind, delay = "WAW", 1
                    index_pairs = list(zip(X.op.index, Y.op.index))
                    pfx = _prefix_len(X.anc, Y.anc)
                    cases: list[Optional[int]] = list(range(pfx))
                    if X.uid != Y.uid and self.pos[X.uid] < self.pos[Y.uid]:
                        cases.append(None)
                    if not cases:
                        continue
                    self.v.pairs += 1
                    for carry in cases:
                        if self.done:
                            return
                        self.v.cases += 1
                        empty, w = self._empty(X, Y, carry, None,
                                               index_pairs, delay=delay)
                        if empty is not True:
                            self._report(kind, X, Y, name, carry, empty, w,
                                         delay)

    def check_ports(self, by_array: dict[str, list[_Acc]]) -> None:
        for name in sorted(by_array):
            arr = self.p.arrays[name]
            if arr.kind == "reg":
                continue
            part = list(arr.partition)
            by_port: dict[int, list[_Acc]] = {}
            for a in by_array[name]:
                by_port.setdefault(a.port, []).append(a)
            for port in sorted(by_port):
                paccs = by_port[port]
                for i, X in enumerate(paccs):
                    for Y in paccs[i:]:
                        if self.done:
                            return
                        index_pairs = list(zip(X.op.index, Y.op.index))
                        if X.uid == Y.uid:
                            # distinct iterations of one op: split on the
                            # first differing level (x <lex y WLOG — a
                            # same-cycle collision is symmetric)
                            cases = list(range(len(X.anc)))
                        else:
                            cases = [None]
                        if not cases:
                            continue
                        self.v.pairs += 1
                        for carry in cases:
                            if self.done:
                                return
                            self.v.cases += 1
                            empty, w = self._empty(X, Y, carry, part,
                                                   index_pairs, delay=None)
                            if empty is not True:
                                self._report("PORT", X, Y, name, carry,
                                             empty, w, None)

    def run(self) -> Verdict:
        if not self.check_complete():
            return self.v
        self.check_fusion_markers()
        self.check_occupancy()
        if not self.done:
            self.check_ssa_struct()
        by_array = _collect(self.p)
        if not self.done:
            self.check_dependences(by_array)
        if not self.done:
            self.check_ports(by_array)
        self.v.diagnostics.sort(key=Diagnostic.sort_key)
        return self.v


def validate_static(program: Program, schedule, *,
                    fail_fast: bool = False) -> Verdict:
    """Independently re-derive and check every constraint the schedule must
    satisfy (DESIGN.md §12): loop occupancy, SSA/structural ordering,
    RAW/WAR/WAW separation per happens-before case (polyhedral emptiness
    checks on :func:`solve_ilp`), port/bank conflicts under
    ``array_partition``, and peel/fuse-group marker consistency.

    ``fail_fast=True`` stops at the first problem (used by mutation tests
    where any rejection suffices); the default scans everything so the
    verdict enumerates every violation."""
    return _Validator(program, schedule, fail_fast).run()


# ---------------------------------------------------------------------------
# Schedule corruption (the mutation-test harness)
# ---------------------------------------------------------------------------


def corrupt_schedule(schedule, rng) -> Optional[tuple[object, dict]]:
    """Produce a schedule that is invalid **by construction**, for mutation-
    testing the validator (a validator that accepts any of these is broken).

    Three mutation families, chosen by ``rng`` (a ``numpy`` Generator):

    * ``theta``: pick a RAW/WAR/WAW/SSA/STRUCT edge and move its sink to
      ``theta[src] + lower - 1 - extra``.  Edge lower bounds are *tight*
      (the minimizing instance pair attains the slack), so undershooting by
      one provably violates the underlying constraint — valid only for
      exact-provenance schedules (degraded edges are conservative).
    * ``ii``: lower one loop's II below its occupancy floor
      ``trip_inner * II_inner`` (guaranteed structurally invalid).
    * ``drop-edge``: remove one memory/SSA edge and recompute the earliest
      schedule from the rest; kept only when the new theta actually
      violates the dropped edge's difference constraint.

    Returns ``(mutant, info)`` or ``None`` when the chosen family has no
    applicable site (caller retries with the next seed)."""
    import dataclasses

    from .scheduler import longest_path

    s = schedule
    if s.provenance != "exact":
        raise ValueError("corrupt_schedule needs an exact-provenance "
                         "schedule (degraded edge bounds are not tight)")
    family = rng.choice(["theta", "ii", "drop-edge"])
    if family == "theta":
        edges = [e for e in s.edges
                 if e.kind in ("RAW", "WAR", "WAW", "SSA", "STRUCT")]
        if not edges:
            return None
        e = edges[rng.integers(len(edges))]
        theta = dict(s.theta)
        theta[e.snk] = theta[e.src] + e.lower - 1 - int(rng.integers(0, 3))
        info = {"family": "theta", "edge": (e.src, e.snk, e.kind, e.lower)}
        return dataclasses.replace(s, theta=theta), info
    if family == "ii":
        floors = {}
        for l in s.program.loops():
            for c in l.sub_loops():
                need = c.trip * s.iis[c.uid]
                floors[l.uid] = max(floors.get(l.uid, 1), need)
        sites = [u for u, f in floors.items() if s.iis[u] >= f > 1]
        if not sites:
            return None
        u = sites[rng.integers(len(sites))]
        iis = dict(s.iis)
        iis[u] = int(rng.integers(1, floors[u]))  # strictly below the floor
        return dataclasses.replace(s, iis=iis), {"family": "ii", "loop": u}
    # drop-edge
    mem = [i for i, e in enumerate(s.edges)
           if e.kind in ("RAW", "WAR", "WAW", "SSA")]
    rng.shuffle(mem)
    nodes = [n for n, _ in s.program.walk()]
    for i in mem:
        e = s.edges[i]
        rest = s.edges[:i] + s.edges[i + 1:]
        theta = longest_path(nodes, rest)
        if theta is None:
            continue
        if theta[e.snk] - theta[e.src] < e.lower:  # actually violates it
            info = {"family": "drop-edge",
                    "edge": (e.src, e.snk, e.kind, e.lower)}
            return dataclasses.replace(s, theta=theta, edges=rest), info
    return None


# ---------------------------------------------------------------------------
# Corpus registry + CLI
# ---------------------------------------------------------------------------


def corpus_programs(include_traced: bool = True) -> dict:
    """name -> zero-arg constructor for every corpus program the CLI/CI
    lints: the paper benchmarks, the fusion chains, both figures, and (when
    jax is importable) the bundled traced kernels."""
    from . import programs as P

    reg = dict(P.BENCHMARKS)
    reg.update(P.CHAIN_BENCHMARKS)
    reg["fig1_conv_chain"] = P.fig1_conv_chain
    reg["fig3_conv1d"] = P.fig3_conv1d
    if include_traced:
        try:
            from . import frontend as F
            reg["traced_wkv6"] = lambda: F.wkv6_program().program
            reg["traced_conv_block"] = lambda: F.conv_block_program().program
            reg["traced_attention"] = lambda: F.attention_program().program
        except Exception:   # pragma: no cover - jax-less environments
            pass
    return reg


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.analysis",
        description="IR linter + static schedule validator over the corpus.")
    ap.add_argument("names", nargs="*",
                    help="corpus program names (default/--all: everything)")
    ap.add_argument("--all", action="store_true", dest="all_",
                    help="lint the whole corpus")
    ap.add_argument("--no-traced", action="store_true",
                    help="skip the jax-traced kernels")
    ap.add_argument("--validate", action="store_true",
                    help="also compile each program (empty pipeline) and "
                         "run the static schedule validator on the result")
    ap.add_argument("--codes", action="store_true",
                    help="print the lint/validate code tables and exit")
    args = ap.parse_args(argv)

    if args.codes:
        for title, table in (("lint", LINT_CODES),
                             ("validate", VALIDATE_CODES)):
            print(f"# {title} codes")
            for code, meaning in table.items():
                print(f"  {code:<22} {meaning}")
        return 0

    reg = corpus_programs(include_traced=not args.no_traced)
    names = args.names or sorted(reg)
    unknown = [n for n in names if n not in reg]
    if unknown:
        ap.error(f"unknown program(s) {unknown}; known: {sorted(reg)}")

    failures = 0
    for name in names:
        p = reg[name]()
        diags = lint(p)
        pinned = EXPECTED_LINT.get(name, set())
        new_errors = [d for d in diags
                      if d.severity == "error" and d.code not in pinned]
        status = "FAIL" if new_errors else "ok"
        print(f"{name}: {status} ({len(diags)} findings)")
        for d in diags:
            pin = " [pinned]" if d.code in pinned else ""
            print(f"  {d}{pin}")
        failures += bool(new_errors)
        if args.validate:
            from . import api as hls
            r = hls.compile(p, pipeline=())
            v = validate_static(r.program, r.best.schedule)
            print(f"  schedule: {v}")
            failures += not v.ok
    return 1 if failures else 0


if __name__ == "__main__":   # pragma: no cover - exercised via CLI tests
    sys.exit(main())

"""Pallas codegen backend: lower a scheduled ``Program`` to a real kernel.

This closes the modeled-vs-measured loop named in ROADMAP: after PRs 1-7 the
DSE winner was a latency *number*; this module turns the winning design point
into an executable Pallas kernel so ``benchmarks/run.py codegen`` can record
measured wall-clock next to the modeled latency (BENCH_codegen.json).

Two lowering strategies (DESIGN.md §10):

* **streamed** ("Mode A") — for single-sink producer-consumer chains of
  perfect depth-2 nests (the paper's Fig. 1 shape).  The sink's row loop is
  strip-mined into a 1-D Pallas grid of ``T = ceil(Rout/block_rows)`` steps;
  every producer stage is recomputed per grid step over exactly the *window*
  of its rows the later stages consume.  Windows are derived by propagating
  ``rows [a*t+b, a*t+b+sz)`` triples backward through the chain, which
  generalizes the shift-and-peel fusion analysis: a producer's window
  overhang ``sz - a`` IS the fusion's row shift (the VMEM line-buffer halo)
  whenever the DSE fused that edge.  Intermediates live entirely in
  registers/VMEM — they never materialize in HBM.

  - ``buffering="double"`` emits the window reads against whole-array input
    refs inside a gridded ``pallas_call`` with a per-tile output BlockSpec:
    Pallas' grid pipeline machinery ping-pongs the output block buffers, so
    tile ``t+1``'s refill overlaps tile ``t``'s compute.
  - ``buffering="single"`` emits the same stage body inside a
    ``lax.fori_loop`` over tiles with explicit ``pl.store`` of each tile —
    one window, serialized refill/compute/store.  It exists as the
    measurable baseline the double-buffered variant must beat.

* **whole-array** ("Mode B") — the generic fallback for programs the
  streamed contract rejects only *softly* (multi-store nests, strided or
  transposed stores, reads of unwritten regions, multiple sinks,
  reduction-carrying nests): every array becomes a whole VMEM ref, each
  nest is vectorized over its full domain in program order, and partial
  stores update a value initialized from the ref (so uncovered elements
  keep their initial values, exactly like ``sim.sequential_exec``).
  Canonical accumulations — the innermost iv absent from the store index,
  every load of the stored array at the store address (``two_mm``-style
  matmuls) — vectorize the outer ivs and fold the innermost one with a
  ``lax.fori_loop`` left fold, which matches the sequential float rounding
  bit for bit.

Programs outside both contracts (multi-chain tasks, imperfect nests, loose
top-level ops, non-canonical reductions — the shape vocabulary is
``ir.nest_shape``) raise the structured :class:`UnlowerableProgram`
carrying machine-readable :class:`NestContractViolation` entries instead
of an opaque downstream failure; ``CompileResult.emit_pallas`` records the
rejection (with its violation codes) in ``diagnostics``.

The kernel is emitted as *source text* and ``exec``'d: the source is the
debuggable artifact (``PallasKernel.source``), and the golden test asserts
the generated blur-chain kernel is bit-exact against the hand-written
``kernels/stencil_pipeline.py`` it generalizes.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Callable, Optional

from .errors import NestContractViolation, UnlowerableProgram
from .ir import (AffExpr, ArithOp, ConstOp, LoadOp, Loop, Program, StoreOp,
                 nest_shape)

DEFAULT_BLOCK_ROWS = 8

_ARITH_FMT = {
    "add": "({} + {})",
    "sub": "({} - {})",
    "mul": "({} * {})",
    "div": "({} / {})",
    "min": "jnp.minimum({}, {})",
    "max": "jnp.maximum({}, {})",
    "cmp": "({} > {}).astype(DTYPE)",
}


def _ident(name: str) -> str:
    return re.sub(r"\W", "_", name)


def _vname(ssa: str) -> str:
    return "v_" + _ident(ssa.lstrip("%"))


# ---------------------------------------------------------------------------
# Nest extraction + the hard (mode-independent) contract
# ---------------------------------------------------------------------------


@dataclass
class _Access:
    """One affine access, separability-checked: per array dim at most one
    induction variable, ``coef * iv + const`` with coef >= 1, const >= 0."""

    array: str
    dims: list[tuple[Optional[str], int, int]]  # (iv | None, coef, const)


@dataclass
class _Nest:
    loop: Loop
    ivs: list[str]
    trips: list[int]
    ops: list  # innermost body, program order
    loads: list[tuple[LoadOp, _Access]] = field(default_factory=list)
    stores: list[tuple[StoreOp, _Access]] = field(default_factory=list)
    # reduction carry (canonical accumulation): the innermost iv is absent
    # from the store index, and every load of the stored array matches the
    # store address exactly — ``dst[outs] = f(dst[outs], inputs[.., red..])``
    red_iv: Optional[str] = None
    red_loads: tuple = ()  # uids of the carried-accumulator loads


def _hard(hard: list, code: str, detail: str) -> None:
    hard.append(NestContractViolation(code, "codegen", detail))


def _classify_access(nest_ivs, index, arr_shape, what, tag, hard):
    dims = []
    seen_ivs: set = set()
    if len(index) != len(arr_shape):
        _hard(hard, "rank-mismatch",
              f"nest '{tag}': {what} rank {len(index)} != array rank "
              f"{len(arr_shape)}")
        return None
    if len(arr_shape) > 2:
        _hard(hard, "rank",
              f"nest '{tag}': {what} of a rank-{len(arr_shape)} array "
              "(only 1-D/2-D arrays lower)")
        return None
    for e in index:
        e = e if isinstance(e, AffExpr) else AffExpr({}, int(e))
        if len(e.coeffs) > 1:
            _hard(hard, "non-separable",
                  f"nest '{tag}': non-separable {what} index {e!r}")
            return None
        if e.const < 0:
            _hard(hard, "negative-offset",
                  f"nest '{tag}': negative {what} offset {e!r}")
            return None
        if e.coeffs:
            (ivn, coef), = e.coeffs.items()
            if ivn not in nest_ivs:
                _hard(hard, "unknown-iv",
                      f"nest '{tag}': {what} uses unknown iv '{ivn}'")
                return None
            if coef < 1:
                _hard(hard, "negative-stride",
                      f"nest '{tag}': negative-stride {what} {e!r}")
                return None
            if ivn in seen_ivs:
                _hard(hard, "diagonal-access",
                      f"nest '{tag}': iv '{ivn}' in two {what} dims "
                      "(diagonal access)")
                return None
            seen_ivs.add(ivn)
            dims.append((ivn, coef, e.const))
        else:
            dims.append((None, 0, e.const))
    return dims


def _extract_nests(p: Program) -> tuple[list[_Nest], list]:
    hard: list = []
    nests: list[_Nest] = []
    shape = nest_shape(p)
    for ti, item in enumerate(p.body):
        ts = shape.task(ti)
        # one contract check, one place: the structural gate is the
        # ir.nest_shape classifier, not an ad-hoc re-traversal
        if ts.kind == "ops":
            _hard(hard, "top-level-ops",
                  "top-level op outside any loop nest "
                  "(run transforms.Normalize to sink loose ops)")
            continue
        if ts.kind == "imperfect":
            _hard(hard, "imperfect-nest",
                  f"nest '{item.ivname}': imperfect nest (ops mixed with an "
                  "inner loop; run transforms.Normalize to sink them)")
            continue
        if ts.kind == "multi_loop":
            _hard(hard, "multi-chain",
                  f"nest '{item.ivname}': multiple inner loops at one level "
                  "(multi-chain tasks have no single vectorized domain)")
            continue
        ivs, trips, cur = [], [], item
        ops, chain_ok = None, True
        while True:
            if cur.lb != 0:
                _hard(hard, "non-zero-lb",
                      f"nest '{item.ivname}': non-zero lower bound")
                chain_ok = False
                break
            ivs.append(cur.ivname)
            trips.append(cur.trip)
            inner = [x for x in cur.body if isinstance(x, Loop)]
            if inner:
                cur = inner[0]
                continue
            ops = cur.body
            break
        if not chain_ok:
            continue
        nest = _Nest(loop=item, ivs=ivs, trips=trips, ops=ops)
        ok = True
        red_stores = []  # stores whose index omits the innermost iv
        for op in ops:
            if isinstance(op, LoadOp):
                dims = _classify_access(set(ivs), op.index,
                                        p.arrays[op.array].shape, "load",
                                        item.ivname, hard)
                if dims is None:
                    ok = False
                    break
                nest.loads.append((op, _Access(op.array, dims)))
            elif isinstance(op, StoreOp):
                dims = _classify_access(set(ivs), op.index,
                                        p.arrays[op.array].shape, "store",
                                        item.ivname, hard)
                if dims is None:
                    ok = False
                    break
                used = [d[0] for d in dims if d[0] is not None]
                if (sorted(used) == sorted(ivs[:-1]) and len(ivs) >= 2
                        and len(used) == len(dims)):
                    # reduction-carrying store: every iv but the innermost
                    red_stores.append(op)
                elif sorted(used) != sorted(ivs) or len(used) != len(dims):
                    _hard(hard, "store-shape",
                          f"nest '{item.ivname}': store to '{op.array}' "
                          "must use every nest iv (or every iv but the "
                          "innermost reduction iv) in exactly one dim "
                          "(no constant dims)")
                    ok = False
                    break
                nest.stores.append((op, _Access(op.array, dims)))
            elif isinstance(op, ArithOp):
                if op.fn not in _ARITH_FMT:
                    _hard(hard, "unsupported-op",
                          f"nest '{item.ivname}': unsupported op '{op.fn}'")
                    ok = False
                    break
            elif not isinstance(op, ConstOp):
                _hard(hard, "unsupported-node",
                      f"nest '{item.ivname}': unsupported IR node "
                      f"{type(op).__name__}")
                ok = False
                break
        if not ok:
            continue
        if red_stores:
            # canonical accumulation: ONE reduction store, and every load
            # of the carried array matches the store address exactly, so
            # the nest is a left fold over the innermost iv —
            # dst[outs] = f(dst[outs], inputs[.., red, ..]) per step
            if len(nest.stores) != 1:
                _hard(hard, "reduction",
                      f"nest '{item.ivname}': reduction with "
                      f"{len(nest.stores)} stores (only single-store "
                      "accumulations lower)")
                continue
            sop, sacc = nest.stores[0]
            carried = [(op_, a) for op_, a in nest.loads
                       if a.array == sacc.array]
            if not carried or any(a.dims != sacc.dims for _, a in carried):
                _hard(hard, "reduction",
                      f"nest '{item.ivname}': reduction — reads "
                      f"'{sacc.array}' it also writes at a different "
                      "address (non-canonical carried accumulation)")
                continue
            nest.red_iv = ivs[-1]
            nest.red_loads = tuple(op_.uid for op_, _ in carried)
        rd = {a.array for _, a in nest.loads}
        wr = {a.array for _, a in nest.stores}
        for arr in sorted(rd & wr):
            if nest.red_iv is not None and arr == nest.stores[0][1].array:
                continue  # the canonical carry, handled above
            _hard(hard, "reduction",
                  f"nest '{item.ivname}': reduction — reads '{arr}' it "
                  "also writes (carried accumulation outside the "
                  "canonical innermost-axis pattern has no lowering)")
            ok = False
        if ok:
            nests.append(nest)
    writers: dict[str, str] = {}
    for nest in nests:
        for _, acc in nest.stores:
            prev = writers.get(acc.array)
            if prev is not None and prev != nest.loop.ivname:
                _hard(hard, "multi-writer",
                      f"array '{acc.array}' written by two nests "
                      f"('{prev}', '{nest.loop.ivname}')")
            writers[acc.array] = nest.loop.ivname
    return nests, hard


# ---------------------------------------------------------------------------
# Mode A: the streamed (grid + window) plan
# ---------------------------------------------------------------------------


@dataclass
class _StagePlan:
    nest: _Nest
    out: str                    # produced array
    r0: int                     # store row/col offsets into the array
    c0: int
    win_a: int = 0              # domain rows [a*t+b, a*t+b+sz) per grid step
    win_b: int = 0
    win_sz: int = 0


@dataclass
class _StreamPlan:
    stages: list[_StagePlan]
    sink: _StagePlan
    block_rows: int
    grid: int                   # T
    inputs: list[str]           # arrays read from refs (not stage-produced)
    pad_rows: dict[str, int]    # input array -> trailing edge-pad rows
    halo: dict[str, int]        # produced array -> window overhang (sz - a)


def _plan_streamed(p: Program, nests: list[_Nest],
                   block_rows: int) -> tuple[Optional[_StreamPlan], list[str]]:
    soft: list[str] = []
    stages: list[_StagePlan] = []
    for nest in nests:
        tag = nest.loop.ivname
        if nest.red_iv is not None:
            soft.append(f"nest '{tag}': streamed mode does not pipeline "
                        "reduction-carrying nests (whole-array fallback)")
            return None, soft
        if len(nest.ivs) != 2:
            soft.append(f"nest '{tag}': streamed mode needs depth-2 nests")
            return None, soft
        if len(nest.stores) != 1:
            soft.append(f"nest '{tag}': streamed mode needs exactly one "
                        f"store ({len(nest.stores)} found)")
            return None, soft
        _, acc = nest.stores[0]
        (iv0, c0_, r_off), (iv1, c1_, c_off) = acc.dims
        if (iv0, c0_) != (nest.ivs[0], 1) or (iv1, c1_) != (nest.ivs[1], 1):
            soft.append(f"nest '{tag}': store to '{acc.array}' is strided or "
                        "transposed")
            return None, soft
        stages.append(_StagePlan(nest=nest, out=acc.array, r0=r_off,
                                 c0=c_off))
    if not stages:
        soft.append("no loop nests")
        return None, soft
    produced = {s.out: i for i, s in enumerate(stages)}
    # loads: row dim must carry the outer iv; col dim the inner iv or const
    for si, s in enumerate(stages):
        tag = s.nest.loop.ivname
        for _, acc in s.nest.loads:
            if len(acc.dims) != 2:
                soft.append(f"nest '{tag}': streamed mode needs 2-D loads "
                            f"('{acc.array}' is {len(acc.dims)}-D)")
                return None, soft
            (riv, _, _), (civ, _, _) = acc.dims
            if riv != s.nest.ivs[0] or civ not in (s.nest.ivs[1], None):
                soft.append(f"nest '{tag}': load of '{acc.array}' is "
                            "transposed or row-constant")
                return None, soft
            if acc.array in produced and produced[acc.array] >= si:
                soft.append(f"nest '{tag}': reads '{acc.array}' before its "
                            "producer runs (initial-value read)")
                return None, soft
    sinks = [s for s in stages
             if not any(acc.array == s.out
                        for t in stages for _, acc in t.nest.loads)]
    if len(sinks) != 1:
        soft.append("streamed mode needs a unique sink stage "
                    f"({len(sinks)} found: {[s.out for s in sinks]})")
        return None, soft
    sink = sinks[0]
    shape = p.arrays[sink.out].shape
    if (sink.r0, sink.c0) != (0, 0) or tuple(sink.nest.trips) != shape:
        soft.append(f"sink nest '{sink.nest.loop.ivname}' does not fully "
                    f"cover '{sink.out}'")
        return None, soft
    # coverage: every stage-to-stage read stays inside the producer's
    # written box (else the read would see initial values -> Mode B)
    for s in stages:
        for _, acc in s.nest.loads:
            if acc.array not in produced:
                continue
            prod = stages[produced[acc.array]]
            (riv, rc, rk), (civ, cc, ck) = acc.dims
            rmax = rc * (s.nest.trips[0] - 1) + rk
            cmax = (cc * (s.nest.trips[1] - 1) + ck) if civ else ck
            if not (rk >= prod.r0 and ck >= prod.c0
                    and rmax < prod.r0 + prod.nest.trips[0]
                    and cmax < prod.c0 + prod.nest.trips[1]):
                soft.append(f"nest '{s.nest.loop.ivname}': load of "
                            f"'{acc.array}' reads outside the producer's "
                            "written box")
                return None, soft
    # backward window propagation: sink computes [B*t, B*t+B)
    rout = sink.nest.trips[0]
    B = max(1, min(block_rows, rout))
    sink.win_a, sink.win_b, sink.win_sz = B, 0, B
    halo: dict[str, int] = {}
    for s in reversed(stages):
        if s is sink:
            continue
        reqs = []  # (consumer stage, row coef, row const)
        for c in stages:
            for _, acc in c.nest.loads:
                if acc.array == s.out:
                    reqs.append((c, acc.dims[0][1], acc.dims[0][2]))
        # the unique-sink check already ran: a non-sink stage has consumers,
        # and they are later stages whose windows are already resolved
        assert reqs, s.out
        rates = {rc * c.win_a for c, rc, _ in reqs}
        if len(rates) > 1:
            soft.append(f"consumers of '{s.out}' advance at incompatible "
                        f"row rates {sorted(rates)}")
            return None, soft
        a = rates.pop()
        lo = min(rc * c.win_b + rk for c, rc, rk in reqs) - s.r0
        hi = max(rc * (c.win_b + c.win_sz - 1) + rk
                 for c, rc, rk in reqs) - s.r0
        if lo < 0:
            soft.append(f"window of '{s.out}' starts before its domain "
                        f"(offset {lo})")
            return None, soft
        s.win_a, s.win_b, s.win_sz = a, lo, hi - lo + 1
        halo[s.out] = s.win_sz - s.win_a
    T = -(-rout // B)
    # trailing edge-padding so the last (possibly partial) tile's input
    # reads stay in bounds; padded rows only feed output rows >= Rout,
    # which the host wrapper trims
    pad_rows: dict[str, int] = {}
    inputs: list[str] = []
    for s in stages:
        for _, acc in s.nest.loads:
            if acc.array in produced:
                continue
            if acc.array not in inputs:
                inputs.append(acc.array)
            rc, rk = acc.dims[0][1], acc.dims[0][2]
            need = rc * (s.win_a * (T - 1) + s.win_b + s.win_sz - 1) + rk
            over = need - (p.arrays[acc.array].shape[0] - 1)
            if over > 0:
                pad_rows[acc.array] = max(pad_rows.get(acc.array, 0), over)
    return _StreamPlan(stages=stages, sink=sink, block_rows=B, grid=T,
                       inputs=inputs, pad_rows=pad_rows, halo=halo), soft


# ---------------------------------------------------------------------------
# Source emission helpers
# ---------------------------------------------------------------------------


def _lit(v: float) -> str:
    return repr(float(v))


def _affine_t(coef: int, const: int) -> str:
    if coef == 0:
        return str(const)
    if const == 0:
        return f"{coef} * t"
    return f"{coef} * t + {const}"


def _sl(lo: int, hi: int, step: int) -> str:
    s = f"{lo}:{hi}"
    return s + (f":{step}" if step > 1 else "")


def _emit_streamed(p: Program, plan: _StreamPlan, buffering: str,
                   dtype: str) -> tuple[str, dict]:
    B, T = plan.block_rows, plan.grid
    sink = plan.sink
    cout = p.arrays[sink.out].shape[1]
    rout = sink.nest.trips[0]
    produced = {s.out: s for s in plan.stages}
    refs = [f"r_{_ident(a)}" for a in plan.inputs]

    body: list[str] = []
    loadcache: dict[tuple, str] = {}
    final = None
    for s in plan.stages:
        tag = s.nest.loop.ivname
        csz = s.nest.trips[1]
        body.append(f"# stage {tag}: '{s.out}' domain rows "
                    f"[{s.win_a}*t+{s.win_b}, +{s.win_sz})")
        names: dict[str, str] = {}
        for op in s.nest.ops:
            if isinstance(op, ConstOp):
                names[op.result] = _lit(op.value)
            elif isinstance(op, LoadOp):
                acc = next(a for o, a in s.nest.loads if o is op)
                (_, rc, rk), (civ, cc, ck) = acc.dims
                rowsel = f"::{rc}" if rc > 1 else ":"
                if acc.array in produced:
                    prod = produced[acc.array]
                    rel = rc * s.win_b + rk - prod.r0 - prod.win_b
                    rsel = _sl(rel, rel + rc * (s.win_sz - 1) + 1, rc)
                    if civ is None:
                        csel = _sl(ck - prod.c0, ck - prod.c0 + 1, 1)
                    else:
                        csel = _sl(ck - prod.c0,
                                   ck - prod.c0 + cc * (csz - 1) + 1, cc)
                    expr = f"w_{_ident(acc.array)}[{rsel}, {csel}]"
                else:
                    start = _affine_t(rc * s.win_a, rc * s.win_b + rk)
                    span = rc * (s.win_sz - 1) + 1
                    key = (acc.array, start, span)
                    if key not in loadcache:
                        ld = f"ld_{_ident(acc.array)}{len(loadcache)}"
                        body.append(
                            f"{ld} = pl.load(r_{_ident(acc.array)}, "
                            f"(pl.dslice({start}, {span}), slice(None)))")
                        loadcache[key] = ld
                    if civ is None:
                        csel = _sl(ck, ck + 1, 1)
                    else:
                        csel = _sl(ck, ck + cc * (csz - 1) + 1, cc)
                    expr = f"{loadcache[key]}[{rowsel}, {csel}]"
                names[op.result] = _vname(op.result)
                body.append(f"{names[op.result]} = {expr}")
            elif isinstance(op, ArithOp):
                names[op.result] = _vname(op.result)
                body.append(f"{names[op.result]} = " + _ARITH_FMT[op.fn]
                            .format(*(names[a] for a in op.args)))
            elif isinstance(op, StoreOp):
                val = names[op.value]
                if s is sink:
                    final = val
                else:
                    body.append(f"w_{_ident(s.out)} = jnp.broadcast_to("
                                f"{val}, ({s.win_sz}, {csz}))")
    assert final is not None
    store_val = f"jnp.broadcast_to({final}, ({B}, {cout}))"

    lines = [
        '"""Generated by repro.core.codegen — do not edit."""',
        "import jax",
        "import jax.numpy as jnp",
        "from jax.experimental import pallas as pl",
        "",
        f"DTYPE = jnp.dtype('{dtype}')",
        "",
        "",
        "def _kernel(" + ", ".join(refs + ["o_ref"]) + "):",
    ]
    if buffering == "double":
        lines.append("    t = pl.program_id(0)")
        lines += ["    " + b for b in body]
        lines.append(f"    o_ref[...] = {store_val}")
    else:
        lines.append("    def _tile(t, carry):")
        lines += ["        " + b for b in body]
        lines.append(f"        pl.store(o_ref, (pl.dslice({B} * t, {B}), "
                     f"slice(None)), {store_val})")
        lines.append("        return carry")
        lines.append(f"    jax.lax.fori_loop(0, {T}, _tile, 0)")
    lines += ["", "",
              "def run(arrays, interpret=None):",
              "    if interpret is None:",
              "        interpret = jax.default_backend() != 'tpu'"]
    args = []
    specs = []
    for a in plan.inputs:
        v = f"x_{_ident(a)}"
        lines.append(f"    {v} = jnp.asarray(arrays['{a}'], DTYPE)")
        pad = plan.pad_rows.get(a, 0)
        h, w = p.arrays[a].shape
        if pad:
            lines.append(f"    {v} = jnp.pad({v}, ((0, {pad}), (0, 0)), "
                         "mode='edge')")
        args.append(v)
        specs.append(f"pl.BlockSpec(({h + pad}, {w}), lambda t: (0, 0))")
    lines.append("    out = pl.pallas_call(")
    lines.append("        _kernel,")
    if buffering == "double":
        lines.append(f"        grid=({T},),")
        lines.append("        in_specs=[" + ", ".join(specs) + "],")
        lines.append(f"        out_specs=pl.BlockSpec(({B}, {cout}), "
                     "lambda t: (t, 0)),")
    lines.append(f"        out_shape=jax.ShapeDtypeStruct(({T * B}, {cout}), "
                 "DTYPE),")
    lines.append("        interpret=interpret,")
    lines.append("    )(" + ", ".join(args) + ")")
    trim = f"[:{rout}]" if T * B != rout else ""
    lines.append(f"    return {{'{sink.out}': out{trim}}}")
    meta = {"mode": "streamed", "grid": (T,), "block_rows": B,
            "halo": dict(plan.halo), "outputs": (sink.out,),
            "vmem_window_elems": {s.out: s.win_sz * s.nest.trips[1]
                                  for s in plan.stages if s is not sink}}
    return "\n".join(lines) + "\n", meta


# Strided stores can't use `.at[::step].set` inside a Pallas kernel (the
# scatter lowering captures index constants, which pallas_call rejects), so
# the generated module spreads the value with repeat/pad and selects the
# strided positions with an iota mask — all Pallas-legal primitives.
_STRIDED_SET_HELPER = '''

def _strided_set(dst, val, starts, steps):
    sp = val
    for ax, st in enumerate(steps):
        if st > 1:
            sp = jnp.repeat(sp, st, axis=ax)
    sp = sp[tuple(slice(0, dst.shape[a] - starts[a]) for a in range(sp.ndim))]
    sp = jnp.pad(sp, tuple(
        (starts[a], dst.shape[a] - starts[a] - sp.shape[a])
        for a in range(sp.ndim)))
    mask = None
    for ax, (s0, st, n) in enumerate(zip(starts, steps, val.shape)):
        i = jax.lax.broadcasted_iota(jnp.int32, dst.shape, ax)
        m = (i >= s0) & (i < s0 + st * (n - 1) + 1)
        if st > 1:
            m = m & ((i - s0) % st == 0)
        mask = m if mask is None else (mask & m)
    return jnp.where(mask, sp, dst)
'''


def _align_suffix(val_axes: list, outs: list) -> str:
    """Indexing suffix aligning a loaded value's axes with the accumulator's
    (store-dim-ordered) axes; empty when broadcasting already lines up."""
    if len(outs) <= 1 or val_axes == outs:
        return ""
    if len(val_axes) == 2:
        return ".T"
    if not val_axes:
        return ""  # scalar broadcasts
    return "[:, None]" if val_axes[0] == outs[0] else "[None, :]"


def _emit_whole(p: Program, nests: list[_Nest], dtype: str) -> tuple[str, dict]:
    stored = []
    for nest in nests:
        for _, acc in nest.stores:
            if acc.array not in stored:
                stored.append(acc.array)
    order = list(p.arrays)
    refs = [f"r_{_ident(a)}" for a in order]
    outs = [f"o_{_ident(a)}" for a in stored]

    body: list[str] = []
    inited: set[str] = set()

    def init(a: str):
        if a not in inited:
            body.append(f"v_{_ident(a)} = r_{_ident(a)}[...]")
            inited.add(a)

    red_count = 0
    for nest in nests:
        ivpos = {ivn: k for k, ivn in enumerate(nest.ivs)}
        trips = nest.trips
        if nest.red_iv is not None:
            # canonical accumulation: vectorize the outer ivs, fold the
            # innermost one with lax.fori_loop — a left fold in program
            # order, so the float rounding matches sequential_exec bit for
            # bit (the _exact golden tests rely on this)
            _, sacc = nest.stores[0]
            init(sacc.array)
            red_outs = [ivn for ivn, _, _ in sacc.dims]
            nk = trips[-1]
            acc_shape = tuple(trips[ivpos[ivn]] for ivn, _, _ in sacc.dims)
            sels = [_sl(const, const + coef * (trips[ivpos[ivn]] - 1) + 1,
                        coef)
                    for ivn, coef, const in sacc.dims]
            dst = f"v_{_ident(sacc.array)}"
            body.append(f"# nest {nest.loop.ivname}: reduction over "
                        f"'{nest.red_iv}' ({nk} steps), domain {acc_shape}")
            acc0 = f"a_red{red_count}"
            body.append(f"{acc0} = {dst}[" + ", ".join(sels) + "]")
            inner: list[str] = []
            names = {}
            final = None
            for op in nest.ops:
                if isinstance(op, ConstOp):
                    names[op.result] = _lit(op.value)
                elif isinstance(op, LoadOp):
                    if op.uid in nest.red_loads:
                        names[op.result] = "_acc"  # the fold carry
                        continue
                    acc_ = next(a for o, a in nest.loads if o is op)
                    init(acc_.array)
                    lsels, val_axes = [], []
                    for ivn, coef, const in acc_.dims:
                        if ivn is None:
                            lsels.append(str(const))
                        elif ivn == nest.red_iv:
                            ix = "_k" if coef == 1 else f"{coef} * _k"
                            lsels.append(f"{ix} + {const}" if const else ix)
                        else:
                            n = trips[ivpos[ivn]]
                            lsels.append(
                                _sl(const, const + coef * (n - 1) + 1, coef))
                            val_axes.append(ivn)
                    expr = (f"v_{_ident(acc_.array)}[" + ", ".join(lsels)
                            + "]" + _align_suffix(val_axes, red_outs))
                    names[op.result] = _vname(op.result)
                    inner.append(f"{names[op.result]} = {expr}")
                elif isinstance(op, ArithOp):
                    names[op.result] = _vname(op.result)
                    inner.append(f"{names[op.result]} = " + _ARITH_FMT[op.fn]
                                 .format(*(names[a] for a in op.args)))
                elif isinstance(op, StoreOp):
                    final = names[op.value]
            assert final is not None
            body.append(f"def _red{red_count}(_k, _acc):")
            body += ["    " + b for b in inner]
            body.append(f"    return jnp.broadcast_to({final}, "
                        f"{acc_shape!r}).astype(DTYPE)")
            body.append(f"{acc0} = jax.lax.fori_loop(0, {nk}, "
                        f"_red{red_count}, {acc0})")
            starts = [const for _, _, const in sacc.dims]
            steps = [coef for _, coef, _ in sacc.dims]
            shape = p.arrays[sacc.array].shape
            full = (all(st == 1 for st in steps)
                    and all(s0 == 0 for s0 in starts)
                    and acc_shape == shape)
            if full:
                body.append(f"{dst} = jnp.broadcast_to({acc0}, {shape!r})")
            elif all(st == 1 for st in steps):
                body.append(f"{dst} = {dst}.at[" + ", ".join(sels)
                            + f"].set({acc0})")
            else:
                exts_t = ("(" + ", ".join(map(str, acc_shape))
                          + ("," if len(acc_shape) == 1 else "") + ")")
                body.append(
                    f"{dst} = _strided_set({dst}, jnp.broadcast_to({acc0}, "
                    f"{exts_t}), {tuple(starts)!r}, {tuple(steps)!r})")
            red_count += 1
            continue
        body.append(f"# nest {nest.loop.ivname}: domain {tuple(trips)}")
        names: dict[str, str] = {}
        for op in nest.ops:
            if isinstance(op, ConstOp):
                names[op.result] = _lit(op.value)
            elif isinstance(op, LoadOp):
                acc = next(a for o, a in nest.loads if o is op)
                init(acc.array)
                sels, axis_ivs = [], []
                for ivn, coef, const in acc.dims:
                    if ivn is None:
                        sels.append(_sl(const, const + 1, 1))
                        axis_ivs.append(None)
                    else:
                        n = trips[ivpos[ivn]]
                        sels.append(_sl(const, const + coef * (n - 1) + 1,
                                        coef))
                        axis_ivs.append(ivn)
                v = f"v_{_ident(acc.array)}"
                if len(acc.dims) == 1:
                    (ivn, coef, const), = acc.dims
                    if ivn is None:
                        expr = f"{v}[{const}]"
                    else:
                        expr = f"{v}[{sels[0]}]"
                        if len(nest.ivs) == 2 and ivpos[ivn] == 0:
                            expr += "[:, None]"
                elif all(x is None for x in axis_ivs):
                    expr = f"{v}[{acc.dims[0][2]}, {acc.dims[1][2]}]"
                elif len(nest.ivs) == 1:
                    # depth-1 nest reading a 2-D array: collapse the
                    # constant axis so the value is 1-D over the nest iv
                    if axis_ivs[0] is None:
                        expr = f"{v}[{acc.dims[0][2]}, {sels[1]}]"
                    else:
                        expr = f"{v}[{sels[0]}, {acc.dims[1][2]}]"
                else:
                    expr = f"{v}[{sels[0]}, {sels[1]}]"
                    # align value axes with the (outer, inner) target:
                    # transpose when an axis varies over the wrong iv
                    if any(ivn is not None and ivpos[ivn] != k
                           for k, ivn in enumerate(axis_ivs)):
                        expr += ".T"
                names[op.result] = _vname(op.result)
                body.append(f"{names[op.result]} = {expr}")
            elif isinstance(op, ArithOp):
                names[op.result] = _vname(op.result)
                body.append(f"{names[op.result]} = " + _ARITH_FMT[op.fn]
                            .format(*(names[a] for a in op.args)))
            elif isinstance(op, StoreOp):
                acc = next(a for o, a in nest.stores if o is op)
                init(acc.array)
                sels, starts, steps, exts = [], [], [], []
                for ivn, coef, const in acc.dims:
                    n = trips[ivpos[ivn]]
                    sels.append(_sl(const, const + coef * (n - 1) + 1, coef))
                    starts.append(const)
                    steps.append(coef)
                    exts.append(n)
                val = names[op.value]
                if (len(acc.dims) == 2
                        and ivpos[acc.dims[0][0]] == 1):  # transposed store
                    # exts are already in destination-dim order, matching
                    # the transposed value
                    val = f"jnp.asarray({val}).T"
                v = f"v_{_ident(acc.array)}"
                shape = p.arrays[acc.array].shape
                full = (all(st == 1 for st in steps)
                        and all(s0 == 0 for s0 in starts)
                        and tuple(exts) == shape)
                if full:
                    # a full-array `.at[...].set` hits a scatter path whose
                    # lowering captures constants (rejected by pallas_call);
                    # a full store is just a broadcast reassignment
                    body.append(f"{v} = jnp.broadcast_to({val}, {shape!r})")
                elif all(st == 1 for st in steps):
                    body.append(f"{v} = {v}.at[" + ", ".join(sels) +
                                f"].set({val})")
                else:
                    exts_t = ("(" + ", ".join(map(str, exts))
                              + ("," if len(exts) == 1 else "") + ")")
                    body.append(
                        f"{v} = _strided_set({v}, jnp.broadcast_to({val}, "
                        f"{exts_t}), {tuple(starts)!r}, {tuple(steps)!r})")
    for a in stored:
        init(a)  # a store-only nest filtered out earlier can't happen,
        body.append(f"o_{_ident(a)}[...] = v_{_ident(a)}")

    lines = [
        '"""Generated by repro.core.codegen — do not edit."""',
        "import jax",
        "import jax.numpy as jnp",
        "from jax.experimental import pallas as pl",
        "",
        f"DTYPE = jnp.dtype('{dtype}')",
    ]
    if any("_strided_set(" in b for b in body):
        lines.append(_STRIDED_SET_HELPER.rstrip("\n"))
    lines += [
        "",
        "",
        "def _kernel(" + ", ".join(refs + outs) + "):",
    ]
    lines += ["    " + b for b in body]
    lines += ["", "",
              "def run(arrays, interpret=None):",
              "    if interpret is None:",
              "        interpret = jax.default_backend() != 'tpu'"]
    for a in order:
        lines.append(f"    x_{_ident(a)} = jnp.asarray(arrays['{a}'], DTYPE)")
    shapes = ", ".join(
        f"jax.ShapeDtypeStruct({p.arrays[a].shape!r}, DTYPE)" for a in stored)
    lines.append("    outs = pl.pallas_call(")
    lines.append("        _kernel,")
    lines.append(f"        out_shape=[{shapes}],")
    lines.append("        interpret=interpret,")
    lines.append("    )(" + ", ".join(f"x_{_ident(a)}" for a in order) + ")")
    lines.append("    return {" + ", ".join(
        f"'{a}': outs[{i}]" for i, a in enumerate(stored)) + "}")
    meta = {"mode": "whole", "grid": (), "block_rows": None, "halo": {},
            "outputs": tuple(stored), "vmem_window_elems": {}}
    return "\n".join(lines) + "\n", meta


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


@dataclass
class PallasKernel:
    """An executable lowering of a Program.

    ``fn(arrays, interpret=None) -> dict`` maps input arrays (by name, the
    same dict ``sim.make_inputs`` produces) to the produced output arrays.
    ``source`` is the emitted kernel module text — the debuggable artifact.
    """

    program_name: str
    mode: str                       # "streamed" | "whole"
    buffering: str                  # "double" | "single"
    source: str
    fn: Callable
    outputs: tuple
    grid: tuple
    block_rows: Optional[int]
    halo: dict = field(default_factory=dict)
    vmem_window_elems: dict = field(default_factory=dict)
    soft_reasons: list = field(default_factory=list)
    modeled_latency: Optional[int] = None
    point_desc: Optional[str] = None
    fusion_shifts: list = field(default_factory=list)

    def __call__(self, arrays, interpret=None):
        return self.fn(arrays, interpret=interpret)


def lower_program(p: Program, *, block_rows: Optional[int] = None,
                  buffering: str = "double",
                  dtype: str = "float32") -> PallasKernel:
    """Lower ``p`` to a Pallas kernel (streamed if the chain contract holds,
    whole-array otherwise); raises :class:`UnlowerableProgram` when the
    program is outside both contracts."""
    if buffering not in ("double", "single"):
        raise ValueError("buffering must be 'double' or 'single', "
                         f"got {buffering!r}")
    # A program whose affine accesses can leave their arrays has no faithful
    # kernel — jnp indexing clamps silently, hiding the bug.  The linter
    # proves the bounds (or the violation) statically; other lint findings
    # stay warnings, but OOB is a hard refusal here.
    from .analysis import lint as _lint
    oob = [d for d in _lint(p) if d.code in ("oob-read", "oob-write")]
    if oob:
        raise UnlowerableProgram(p.name, [
            NestContractViolation(d.code, "codegen",
                                  f"{d.where}: {d.detail}") for d in oob])
    nests, hard = _extract_nests(p)
    if hard:
        raise UnlowerableProgram(p.name, hard)
    if not nests:
        raise UnlowerableProgram(p.name, [NestContractViolation(
            "empty", "codegen", "program has no loop nests")])
    plan, soft = _plan_streamed(p, nests, block_rows or DEFAULT_BLOCK_ROWS)
    if plan is not None:
        src, meta = _emit_streamed(p, plan, buffering, dtype)
    else:
        src, meta = _emit_whole(p, nests, dtype)
    ns: dict = {}
    exec(compile(src, f"<codegen:{p.name}>", "exec"), ns)
    return PallasKernel(program_name=p.name, mode=meta["mode"],
                        buffering=buffering if meta["mode"] == "streamed"
                        else "whole", source=src, fn=ns["run"],
                        outputs=meta["outputs"], grid=meta["grid"],
                        block_rows=meta["block_rows"], halo=meta["halo"],
                        vmem_window_elems=meta["vmem_window_elems"],
                        soft_reasons=soft)


def _point_block_rows(point) -> Optional[int]:
    """block_rows from a design point: the tile pass marks the outer strip
    loop with ``tile_block``; fall back to the LoopTile pass config."""
    blocks = [l.tile_block for l in point.program.loops()
              if getattr(l, "tile_block", None)]
    if blocks:
        return max(blocks)
    from .transforms import LoopTile
    sizes = []
    for ps in point.passes:
        if isinstance(ps, LoopTile):
            sz = ps.seq if ps.seq is not None else tuple(ps.sizes.values())
            sizes.extend(sz)
    return max(sizes) if sizes else None


def emit_pallas(result, point=None, *, buffering: str = "double",
                block_rows: Optional[int] = None,
                dtype: str = "float32") -> PallasKernel:
    """Lower a ``CompileResult`` design point (default: ``result.best``) to
    an executable Pallas kernel.  The tile pass supplies ``block_rows``, the
    fusion log rides along as ``kernel.fusion_shifts`` (the streamed
    window's ``halo`` generalizes the fusion row shift).  Unlowerable
    programs raise :class:`UnlowerableProgram` *and* record a
    ``codegen-unlowerable`` diagnostic on the result."""
    point = point if point is not None else result.best
    if block_rows is None:
        block_rows = _point_block_rows(point)
    try:
        k = lower_program(result.program, block_rows=block_rows,
                          buffering=buffering, dtype=dtype)
    except UnlowerableProgram as e:
        result.diagnostics.append({
            "kind": "codegen-unlowerable", "program": e.program_name,
            "reasons": list(e.reasons),
            "codes": [v.code for v in e.violations]})
        raise
    k.modeled_latency = point.latency
    k.point_desc = point.desc
    k.fusion_shifts = [dict(x) for x in
                       getattr(point.program, "_fusion_log", [])]
    return k

"""The "affine dialect": loop trees with constant bounds + affine accesses.

Mirrors the paper's input language (C lowered through Polygeist into the MLIR
affine dialect with HLS pragmas preserved as attributes):

  * ``pipeline``       -> Loop.pipeline / Loop.ii (target initiation interval)
  * ``unroll``         -> Loop.unroll (complete unrolling, done by normalize())
  * ``bind_storage``   -> ArrayDecl.kind / ports
  * ``array_partition``-> ArrayDecl.partition (complete partitioning of dims)
  * ``interface``      -> ArrayDecl.is_arg + port latencies
  * ``bind_op``        -> Program.op_delays (external Verilog IP latencies)

The default op latencies are the paper's: fp add/sub 5 cycles, fp mul 4,
loads/stores 1 cycle (§3.1 / Fig. 3).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Optional, Union

# ---------------------------------------------------------------------------
# Affine expressions over loop induction variables
# ---------------------------------------------------------------------------


class AffExpr:
    """Affine expression: sum(coeff_i * iv_i) + const, integer coefficients."""

    __slots__ = ("coeffs", "const")

    def __init__(self, coeffs: Optional[dict[str, int]] = None, const: int = 0):
        self.coeffs = {k: int(v) for k, v in (coeffs or {}).items() if v != 0}
        self.const = int(const)

    # -- algebra ----------------------------------------------------------
    def __add__(self, other) -> "AffExpr":
        other = aff(other)
        co = dict(self.coeffs)
        for k, v in other.coeffs.items():
            co[k] = co.get(k, 0) + v
        return AffExpr(co, self.const + other.const)

    __radd__ = __add__

    def __sub__(self, other) -> "AffExpr":
        return self + aff(other) * (-1)

    def __rsub__(self, other) -> "AffExpr":
        return aff(other) + self * (-1)

    def __mul__(self, k: int) -> "AffExpr":
        k = int(k)
        return AffExpr({n: c * k for n, c in self.coeffs.items()}, self.const * k)

    __rmul__ = __mul__

    # -- utilities ---------------------------------------------------------
    def subst(self, name: str, value: Union[int, "AffExpr"]) -> "AffExpr":
        if name not in self.coeffs:
            return self
        co = dict(self.coeffs)
        c = co.pop(name)
        return AffExpr(co, self.const) + aff(value) * c

    def is_const(self) -> bool:
        return not self.coeffs

    def __eq__(self, other):
        if not isinstance(other, AffExpr):
            return NotImplemented
        return self.coeffs == other.coeffs and self.const == other.const

    def __hash__(self):
        return hash((tuple(sorted(self.coeffs.items())), self.const))

    def __repr__(self):
        parts = [f"{c}*{n}" if c != 1 else n for n, c in sorted(self.coeffs.items())]
        if self.const or not parts:
            parts.append(str(self.const))
        return "+".join(parts).replace("+-", "-")

    def eval(self, env: dict[str, int]) -> int:
        return self.const + sum(c * env[n] for n, c in self.coeffs.items())

    def interval(self, bounds: dict[str, tuple[int, int]]) -> tuple[int, int]:
        """Tight [lo, hi] range of the expression when each variable ranges
        over the inclusive interval ``bounds[name]`` — exact for affine
        expressions over independent variables.  Raises ``KeyError`` for a
        variable with no bound (callers report it as an unbound iv)."""
        lo = hi = self.const
        for n, c in self.coeffs.items():
            a, b = bounds[n]
            lo += c * (a if c > 0 else b)
            hi += c * (b if c > 0 else a)
        return lo, hi


def aff(x: Union[int, str, AffExpr]) -> AffExpr:
    if isinstance(x, AffExpr):
        return x
    if isinstance(x, str):
        return AffExpr({x: 1}, 0)
    return AffExpr({}, int(x))


def iv(name: str) -> AffExpr:
    return AffExpr({name: 1}, 0)


# ---------------------------------------------------------------------------
# Arrays (bind_storage / array_partition / interface pragmas)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArrayDecl:
    name: str
    shape: tuple[int, ...]
    # "bram": block RAM.  "reg": registers / fully-partitioned LUT RAM
    # (no port conflicts).
    kind: str = "bram"
    # port kinds, e.g. ("w", "r") = simple dual port; ("rw", "rw") = true dual
    # port; more entries model replicated BRAMs (costed in the resource model).
    ports: tuple[str, ...] = ("w", "r")
    partition: tuple[int, ...] = ()  # dims completely partitioned (banking)
    rd_latency: int = 1
    wr_latency: int = 1
    is_arg: bool = False  # function argument (Vitis dataflow cannot touch these)
    elem_bits: int = 32

    def num_elems(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    def read_ports(self) -> list[int]:
        return [i for i, p in enumerate(self.ports) if "r" in p]

    def write_ports(self) -> list[int]:
        return [i for i, p in enumerate(self.ports) if "w" in p]


# ---------------------------------------------------------------------------
# Ops and loops
# ---------------------------------------------------------------------------

_uid = itertools.count()


@dataclass
class Op:
    result: Optional[str] = None
    uid: int = field(default_factory=lambda: next(_uid))


@dataclass
class ConstOp(Op):
    value: float = 0.0


@dataclass
class LoadOp(Op):
    array: str = ""
    index: tuple[AffExpr, ...] = ()
    port: int = -1  # assigned by scheduler


@dataclass
class StoreOp(Op):
    array: str = ""
    index: tuple[AffExpr, ...] = ()
    value: str = ""  # ssa name
    port: int = -1


@dataclass
class ArithOp(Op):
    fn: str = "add"  # add|sub|mul|div|... (latency from Program.op_delays)
    args: tuple[str, ...] = ()


@dataclass
class Loop:
    ivname: str = ""
    lb: int = 0
    ub: int = 0  # exclusive
    body: list = field(default_factory=list)
    pipeline: bool = True
    ii: Optional[int] = None  # target II (pragma); None -> autotuned
    unroll: bool = False
    # Top-level nests emitted by one shift-and-peel fusion share a group id:
    # the peel nests are the SAME guarded datapath as the fused core (the IR
    # just lacks conditionals), so the resource model costs the group once.
    fuse_group: Optional[int] = None
    # True for prologue/epilogue loops peeled off a shifted fusion — their
    # ops replicate (a subrange of) the fused core's and run on its datapath.
    peel: bool = False
    # Set by LoopTile on the OUTER loop of a strip pair: the inner block
    # size.  Marks the nest as explicitly tiled, which is what lets the
    # resource model cost nest-local intermediates at their tile-window
    # footprint (a streamed line buffer) instead of the full array.
    tile_block: Optional[int] = None
    uid: int = field(default_factory=lambda: next(_uid))

    @property
    def trip(self) -> int:
        return self.ub - self.lb

    def sub_loops(self) -> list["Loop"]:
        return [it for it in self.body if isinstance(it, Loop)]

    def body_ops(self) -> list:
        return [it for it in self.body if not isinstance(it, Loop)]


# The paper's latency model (Fig. 3 / §3.1, Xilinx FP IP via bind_op).
# "exp" is not in the paper's benchmark set; 12 cycles matches the deep
# iterative fp units (div) of the same IP family — the tracing frontend
# emits it for softmax / decay math.
DEFAULT_OP_DELAYS = {
    "add": 5,
    "sub": 5,
    "mul": 4,
    "div": 12,
    "min": 1,
    "max": 1,
    "cmp": 1,
    "exp": 12,
    "const": 0,
}


@dataclass
class Program:
    name: str
    arrays: dict[str, ArrayDecl] = field(default_factory=dict)
    body: list = field(default_factory=list)  # list[Loop|Op]
    op_delays: dict[str, int] = field(default_factory=lambda: dict(DEFAULT_OP_DELAYS))

    # -- traversal helpers --------------------------------------------------
    def walk(self):
        """Yield (node, ancestors) where ancestors is the list of enclosing
        Loops outermost-first, for every op/loop in program order."""

        def rec(items, anc):
            for it in items:
                yield it, list(anc)
                if isinstance(it, Loop):
                    yield from rec(it.body, anc + [it])

        yield from rec(self.body, [])

    def loops(self):
        return [n for n, _ in self.walk() if isinstance(n, Loop)]

    def mem_ops(self):
        return [(n, a) for n, a in self.walk() if isinstance(n, (LoadOp, StoreOp))]

    def op_latency(self, op) -> int:
        if isinstance(op, LoadOp):
            return self.arrays[op.array].rd_latency
        if isinstance(op, StoreOp):
            return self.arrays[op.array].wr_latency
        if isinstance(op, ArithOp):
            return self.op_delays[op.fn]
        if isinstance(op, ConstOp):
            return 0
        if isinstance(op, Loop):
            return 0
        raise TypeError(op)


# ---------------------------------------------------------------------------
# Builder (the "C frontend": gives benchmarks a compact construction API)
# ---------------------------------------------------------------------------


class ProgramBuilder:
    def __init__(self, name: str, op_delays: Optional[dict[str, int]] = None):
        self.program = Program(name)
        if op_delays:
            self.program.op_delays.update(op_delays)
        self._stack: list[list] = [self.program.body]
        self._ssa = itertools.count()

    # arrays ---------------------------------------------------------------
    def array(self, name: str, shape: tuple[int, ...], **kw) -> str:
        self.program.arrays[name] = ArrayDecl(name=name, shape=tuple(shape), **kw)
        return name

    # scoping ---------------------------------------------------------------
    class _LoopCtx:
        def __init__(self, builder, loop):
            self.builder = builder
            self.loop = loop

        def __enter__(self):
            self.builder._stack.append(self.loop.body)
            return iv(self.loop.ivname)

        def __exit__(self, *a):
            self.builder._stack.pop()

    def loop(self, ivname: str, lb: int, ub: int, *, pipeline: bool = True,
             ii: Optional[int] = None, unroll: bool = False):
        lp = Loop(ivname=ivname, lb=lb, ub=ub, pipeline=pipeline, ii=ii,
                  unroll=unroll)
        self._stack[-1].append(lp)
        return self._LoopCtx(self, lp)

    # ops --------------------------------------------------------------------
    def _name(self, prefix="v"):
        return f"%{prefix}{next(self._ssa)}"

    def const(self, value: float) -> str:
        op = ConstOp(result=self._name("c"), value=float(value))
        self._stack[-1].append(op)
        return op.result

    def load(self, array: str, *index) -> str:
        idx = tuple(aff(i) for i in index)
        op = LoadOp(result=self._name("ld"), array=array, index=idx)
        self._stack[-1].append(op)
        return op.result

    def store(self, array: str, value: str, *index) -> None:
        idx = tuple(aff(i) for i in index)
        self._stack[-1].append(StoreOp(array=array, index=idx, value=value))

    def arith(self, fn: str, *args: str) -> str:
        op = ArithOp(result=self._name(fn[0]), fn=fn, args=tuple(args))
        self._stack[-1].append(op)
        return op.result

    def add(self, a, b):
        return self.arith("add", a, b)

    def sub(self, a, b):
        return self.arith("sub", a, b)

    def mul(self, a, b):
        return self.arith("mul", a, b)

    def div(self, a, b):
        return self.arith("div", a, b)

    def sum_tree(self, vals: list[str]) -> str:
        """Balanced adder tree (shorter critical path than a chain)."""
        vals = list(vals)
        while len(vals) > 1:
            nxt = []
            for i in range(0, len(vals) - 1, 2):
                nxt.append(self.add(vals[i], vals[i + 1]))
            if len(vals) % 2:
                nxt.append(vals[-1])
            vals = nxt
        return vals[0]

    def build(self) -> Program:
        return normalize(self.program)


# ---------------------------------------------------------------------------
# Normalization: complete unrolling (the paper's only supported unroll mode)
# ---------------------------------------------------------------------------


def _clone_item(item, env: dict[str, int], ssa_map: dict[str, str], fresh):
    """Deep-copy an op/loop substituting unrolled ivs and renaming SSA."""
    if isinstance(item, Loop):
        new = Loop(ivname=item.ivname, lb=item.lb, ub=item.ub,
                   pipeline=item.pipeline, ii=item.ii, unroll=item.unroll)
        new.body = [_clone_item(ch, env, ssa_map, fresh) for ch in item.body]
        return new
    if isinstance(item, ConstOp):
        r = fresh(item.result)
        ssa_map[item.result] = r
        return ConstOp(result=r, value=item.value)
    if isinstance(item, LoadOp):
        r = fresh(item.result)
        ssa_map[item.result] = r
        idx = tuple(_subst_env(e, env) for e in item.index)
        return LoadOp(result=r, array=item.array, index=idx)
    if isinstance(item, StoreOp):
        idx = tuple(_subst_env(e, env) for e in item.index)
        return StoreOp(array=item.array, index=idx,
                       value=ssa_map.get(item.value, item.value))
    if isinstance(item, ArithOp):
        r = fresh(item.result)
        ssa_map[item.result] = r
        return ArithOp(result=r, fn=item.fn,
                       args=tuple(ssa_map.get(a, a) for a in item.args))
    raise TypeError(item)


def _subst_env(e: AffExpr, env: dict[str, int]) -> AffExpr:
    for k, v in env.items():
        e = e.subst(k, v)
    return e


def normalize(p: Program) -> Program:
    """Expand all ``unroll`` loops; returns the same Program mutated."""
    counter = itertools.count()

    def fresh(old: str) -> str:
        return f"{old}_u{next(counter)}"

    def expand(items):
        out = []
        for it in items:
            if isinstance(it, Loop):
                it.body = expand(it.body)
                if not it.unroll and it.lb != 0:
                    raise ValueError(
                        f"non-unrolled loop {it.ivname} must start at 0 "
                        "(normalize bounds in the frontend)")
                if it.unroll:
                    for val in range(it.lb, it.ub):
                        env = {it.ivname: val}
                        ssa_map: dict[str, str] = {}
                        for ch in it.body:
                            out.append(_clone_item(ch, env, ssa_map, fresh))
                else:
                    out.append(it)
            else:
                out.append(it)
        return out

    p.body = expand(p.body)
    return p


# ---------------------------------------------------------------------------
# Program-order keys (for happens-before)
# ---------------------------------------------------------------------------


def position_keys(p: Program) -> dict[int, tuple[int, ...]]:
    """Map op/loop uid -> tuple of child indices from the root ("syntactic
    position").  Lexicographic comparison of the suffixes after the common
    ancestor region gives static program order."""
    keys: dict[int, tuple[int, ...]] = {}

    def rec(items, prefix):
        for idx, it in enumerate(items):
            keys[it.uid] = prefix + (idx,)
            if isinstance(it, Loop):
                rec(it.body, prefix + (idx,))

    rec(p.body, ())
    return keys


# ---------------------------------------------------------------------------
# The loop-nest contract: one classifier, consulted by every layer
# ---------------------------------------------------------------------------
#
# Historically each layer re-derived (and silently assumed) the program's
# nest structure: dataflow rejected multi-chain tasks in `_access_sequence`,
# transforms returned None from `_perfect_chain`, codegen hand-rolled its own
# depth/reduction checks.  `nest_shape` is now the single source of truth:
# it names every shape the IR can express — perfect nests, imperfect nests
# (ops alongside a sub-loop), multi-loop tasks (sequential sub-loops under
# one task), reduction carries (arrays a task both reads and writes) — and
# downstream layers decide what they support in terms of this vocabulary.

#: TaskShape.kind values, from most to least restrictive.
TASK_KINDS = ("perfect", "imperfect", "multi_loop", "ops")


@dataclass(frozen=True)
class TaskShape:
    """Structural classification of one top-level item (a "task")."""

    index: int                 # position in Program.body
    kind: str                  # one of TASK_KINDS
    depth: int                 # max loop depth under the task (0 for bare ops)
    #: every root->innermost loop chain, as loop-uid tuples in program order;
    #: a perfect nest has exactly one, sequential sub-loops contribute more.
    chains: tuple[tuple[int, ...], ...]
    #: uids of "loose" ops — ops whose enclosing body also holds a sub-loop
    #: (i.e. not in an innermost body); nonempty marks the nest imperfect.
    loose_ops: tuple[int, ...]
    #: arrays the task both loads and stores (reduction / recurrence carries).
    reductions: tuple[str, ...]

    @property
    def is_perfect(self) -> bool:
        return self.kind == "perfect"

    @property
    def multi_chain(self) -> bool:
        return len(self.chains) > 1


@dataclass(frozen=True)
class NestShape:
    """`nest_shape(p)` result: per-task shapes plus whole-program views."""

    tasks: tuple[TaskShape, ...]

    @property
    def kinds(self) -> tuple[str, ...]:
        return tuple(t.kind for t in self.tasks)

    @property
    def all_perfect(self) -> bool:
        return all(t.is_perfect for t in self.tasks)

    @property
    def max_depth(self) -> int:
        return max((t.depth for t in self.tasks), default=0)

    def task(self, index: int) -> TaskShape:
        return self.tasks[index]


def _classify_task(index: int, item) -> TaskShape:
    if not isinstance(item, Loop):
        return TaskShape(index=index, kind="ops", depth=0, chains=(),
                         loose_ops=(item.uid,), reductions=())
    chains: list[tuple[int, ...]] = []
    loose: list[int] = []
    loaded: set[str] = set()
    stored: set[str] = set()
    depth = 0

    def rec(loop: Loop, path: tuple[int, ...]):
        nonlocal depth
        path = path + (loop.uid,)
        depth = max(depth, len(path))
        subs = loop.sub_loops()
        ops = loop.body_ops()
        for op in ops:
            if isinstance(op, LoadOp):
                loaded.add(op.array)
            elif isinstance(op, StoreOp):
                stored.add(op.array)
            if subs:
                loose.append(op.uid)
        if not subs:
            chains.append(path)
        for sub in subs:
            rec(sub, path)

    rec(item, ())
    kind = ("multi_loop" if len(chains) > 1
            else "imperfect" if loose else "perfect")
    return TaskShape(index=index, kind=kind, depth=depth,
                     chains=tuple(chains), loose_ops=tuple(loose),
                     reductions=tuple(sorted(loaded & stored)))


def nest_shape(p: Program) -> NestShape:
    """Classify every top-level task of ``p`` (the loop-nest contract).

    This is the ONE place nest structure is derived; `dataflow`,
    `transforms`, `codegen` and the tracing frontend all consult it instead
    of re-deriving (or silently assuming) the shape locally.
    """
    return NestShape(tasks=tuple(_classify_task(i, it)
                                 for i, it in enumerate(p.body)))

"""Dependency-free sharded checkpointing with elastic restore.

Layout:  <dir>/step_<N>/
           manifest.json      — pytree structure, leaf shapes/dtypes, step
           arrays.npz         — flattened leaves (this container = 1 host;
                                 the multi-host layout would shard by
                                 process index, same manifest)

Elastic restore: leaves are saved UNSHARDED (gathered), so a restart may use
a different mesh/DP degree — the trainer re-applies its own shardings when
feeding the restored pytree into the jitted step (device_put).  Writes are
atomic (tmp dir + rename) and an AsyncCheckpointer overlaps serialization
with the next training steps.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "shapes": [list(np.shape(x)) for x in leaves],
        "dtypes": [str(np.asarray(x).dtype) for x in leaves],
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # prune older checkpoints, keep last 3
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-3]:
        shutil.rmtree(os.path.join(ckpt_dir, d))
    return final


def latest_step(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, tree_like,
                       shardings=None):
    """Restore into the structure of ``tree_like``; optionally device_put
    with new shardings (elastic restore onto a different mesh)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten(tree_like)
    n = json.load(open(os.path.join(path, "manifest.json")))["n_leaves"]
    assert n == len(leaves), f"checkpoint has {n} leaves, model has {len(leaves)}"
    new_leaves = [data[f"leaf_{i}"] for i in range(n)]
    restored = jax.tree.unflatten(treedef, new_leaves)
    if shardings is not None:
        restored = jax.tree.map(
            lambda x, s: jax.device_put(x, s), restored, shardings)
    return restored


class AsyncCheckpointer:
    """Overlaps checkpoint serialization with training (one in flight)."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._thread = None

    def save(self, step: int, tree):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot off-device
        self._thread = threading.Thread(
            target=save_checkpoint, args=(self.ckpt_dir, step, host_tree),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

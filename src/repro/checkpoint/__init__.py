from .checkpoint import (latest_step, restore_checkpoint, save_checkpoint,
                         AsyncCheckpointer)

__all__ = ["latest_step", "restore_checkpoint", "save_checkpoint",
           "AsyncCheckpointer"]

"""Unified model composition for all 10 assigned architectures.

Layers are grouped into *periods* (cfg.period; 1 for uniform stacks, 8 for
Jamba's 7-mamba+1-attention interleave).  Parameters of all periods are
stacked on a leading axis and the stack is applied with ``jax.lax.scan`` so
the lowered HLO is one period body regardless of depth — this is what keeps
126-layer/512-device dry-run compiles tractable.  ``dense_prefix_layers``
(DeepSeek-V2 / Kimi-K2 first dense layer) are applied unstacked.

Three entry points per architecture:
  * loss_fn(params, batch)      — training (next-token CE)
  * prefill_fn(params, batch)   — full-sequence forward returning logits
  * decode_fn(params, cache, batch) — one-token serve step with caches
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.models import layers as L


# ---------------------------------------------------------------------------
# per-position layer spec within a period
# ---------------------------------------------------------------------------


def period_specs(cfg: ArchConfig) -> list[tuple[str, str]]:
    """[(mix_kind, ffn_kind)] for each position in a period (after the dense
    prefix).  mix: attn|mla|mamba|rwkv.  ffn: mlp|moe|rwkv (fused)."""
    specs = []
    base = cfg.dense_prefix_layers
    for pos in range(cfg.period):
        li = base + pos
        mix = cfg.layer_kind(li)
        if mix == "rwkv":
            ffn = "rwkv"
        elif cfg.is_moe_layer(li):
            ffn = "moe"
        else:
            ffn = "mlp"
        specs.append((mix, ffn))
    return specs


def n_periods(cfg: ArchConfig) -> int:
    body = cfg.n_layers - cfg.dense_prefix_layers
    assert body % cfg.period == 0, (cfg.name, body, cfg.period)
    return body // cfg.period


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(cfg: ArchConfig, key, mix: str, ffn: str, cross: bool):
    ks = jax.random.split(key, 3)
    p = {}
    if mix == "attn":
        p["mix"] = L.init_attn(cfg, ks[0])
    elif mix == "mla":
        p["mix"] = L.init_mla(cfg, ks[0])
    elif mix == "mamba":
        p["mix"] = L.init_mamba(cfg, ks[0])
    elif mix == "rwkv":
        p["mix"] = L.init_rwkv(cfg, ks[0])
    if ffn == "moe":
        p["ffn"] = L.init_moe(cfg, ks[1])
    elif ffn == "mlp":
        p["ffn"] = L.init_mlp(cfg, ks[1])
    if cross:
        p["cross"] = L.init_cross_attn(cfg, ks[2])
    return p


def init_params(cfg: ArchConfig, key):
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    D, V = cfg.d_model, cfg.vocab
    params = {
        "embed": (jax.random.normal(keys[0], (V, D)) * 0.02).astype(dt),
        "final_norm": jnp.ones((D,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(keys[1], (D, V)) * D ** -0.5).astype(dt)

    cross = cfg.family == "encdec"
    # dense prefix (unstacked)
    prefix = []
    for i in range(cfg.dense_prefix_layers):
        prefix.append(_init_layer(cfg, jax.random.fold_in(keys[2], i),
                                  cfg.layer_kind(i), "mlp", cross))
    if prefix:
        params["prefix"] = prefix

    specs = period_specs(cfg)
    NP = n_periods(cfg)

    def one_period(k):
        ks = jax.random.split(k, len(specs))
        return {f"pos{i}": _init_layer(cfg, ks[i], m, f, cross)
                for i, (m, f) in enumerate(specs)}

    periods = [one_period(jax.random.fold_in(keys[3], i)) for i in range(NP)]
    params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *periods)

    if cfg.family == "encdec":
        # encoder: uniform attn+mlp stack (bidirectional), own embed for frames
        enc = [
            {"mix": L.init_attn(cfg, jax.random.fold_in(keys[4], i)),
             "ffn": L.init_mlp(cfg, jax.random.fold_in(keys[5], i))}
            for i in range(cfg.n_enc_layers)]
        params["encoder"] = jax.tree.map(lambda *xs: jnp.stack(xs), *enc)
        params["enc_norm"] = jnp.ones((D,), dt)
    if cfg.family == "vlm":
        # projection of (stub) patch embeddings into the LM width
        params["img_proj"] = (jax.random.normal(keys[6], (D, D)) * D ** -0.5).astype(dt)
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _apply_layer(cfg, spec, p, x, positions, enc_out):
    mix, ffn = spec
    if mix == "attn":
        x = L.attn_forward(cfg, p["mix"], x, positions)
    elif mix == "mla":
        x = L.mla_forward(cfg, p["mix"], x, positions)
    elif mix == "mamba":
        x = L.mamba_forward(cfg, p["mix"], x)
    elif mix == "rwkv":
        x = L.rwkv_forward(cfg, p["mix"], x)
    if enc_out is not None and "cross" in p:
        x = L.cross_attn_forward(cfg, p["cross"], x, enc_out)
    if ffn == "moe":
        x = L.moe_forward(cfg, p["ffn"], x)
    elif ffn == "mlp":
        x = L.mlp_forward(cfg, p["ffn"], x)
    return x


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    policy = None
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return jax.checkpoint(fn, policy=policy)


def backbone(cfg: ArchConfig, params, x, positions, enc_out=None):
    """Apply prefix + scanned periods + final norm.  x: (B,S,D)."""
    specs = period_specs(cfg)
    for i in range(cfg.dense_prefix_layers):
        p = params["prefix"][i]
        x = _remat(cfg, partial(_apply_layer, cfg, (cfg.layer_kind(i), "mlp")))(
            p, x, positions, enc_out)

    def period_body(x, pslice):
        for i, spec in enumerate(specs):
            x = _apply_layer(cfg, spec, pslice[f"pos{i}"], x, positions, enc_out)
        return x

    def scan_step(x, pslice):
        return _remat(cfg, period_body)(x, pslice), None

    x, _ = jax.lax.scan(scan_step, x, params["blocks"])
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps)


def encode(cfg: ArchConfig, params, frames):
    """Whisper encoder over (stub) frame embeddings (B, T_enc, D)."""
    B, T, D = frames.shape
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))

    def body(x, pslice):
        def f(x):
            x = L.attn_forward(cfg, pslice["mix"], x, positions, causal=False)
            return L.mlp_forward(cfg, pslice["ffn"], x)
        return _remat(cfg, f)(x), None

    x, _ = jax.lax.scan(body, frames, params["encoder"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def logits_from_hidden(cfg: ArchConfig, params, h):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ head
    return logits.astype(jnp.float32) if cfg.logits_fp32 else logits


def embed_inputs(cfg: ArchConfig, params, batch):
    """Token ids (+ modality stub embeddings) -> (x, positions, enc_out)."""
    tokens = batch["tokens"]
    x = params["embed"][tokens]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    enc_out = None
    if cfg.family == "encdec":
        enc_out = encode(cfg, params, batch["frames"].astype(x.dtype))
    if cfg.family == "vlm":
        img = batch["patches"].astype(x.dtype) @ params["img_proj"]
        x = jnp.concatenate([img, x], axis=1)
        S = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    return x, positions, enc_out


def forward(cfg: ArchConfig, params, batch):
    x, positions, enc_out = embed_inputs(cfg, params, batch)
    h = backbone(cfg, params, x, positions, enc_out)
    if cfg.family == "vlm":  # logits over the text positions only
        h = h[:, cfg.n_img_tokens:]
    return logits_from_hidden(cfg, params, h)


def loss_fn(cfg: ArchConfig, params, batch):
    logits = forward(cfg, params, batch)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask", jnp.ones_like(ll))
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# decode (one token, KV/state caches)
# ---------------------------------------------------------------------------


def _init_layer_cache(cfg, mix, B, Smax, dt):
    if mix == "attn":
        return L.init_attn_cache(cfg, B, Smax, dt)
    if mix == "mla":
        return L.init_mla_cache(cfg, B, Smax, dt)
    if mix == "mamba":
        return L.init_mamba_cache(cfg, B, dt)
    if mix == "rwkv":
        return L.init_rwkv_cache(cfg, B, dt)
    raise ValueError(mix)


def init_cache(cfg: ArchConfig, B: int, Smax: int):
    dt = jnp.dtype(cfg.dtype)
    cache = {}
    if cfg.dense_prefix_layers:
        cache["prefix"] = [
            _init_layer_cache(cfg, cfg.layer_kind(i), B, Smax, dt)
            for i in range(cfg.dense_prefix_layers)]
    specs = period_specs(cfg)
    NP = n_periods(cfg)

    def one(i):
        return {f"pos{k}": _init_layer_cache(cfg, m, B, Smax, dt)
                for k, (m, _) in enumerate(specs)}

    per = [one(i) for i in range(NP)]
    cache["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
    return cache


def _decode_layer(cfg, spec, p, x, cache, pos, enc_out):
    mix, ffn = spec
    if mix == "attn":
        x, cache = L.attn_decode(cfg, p["mix"], x, cache, pos)
    elif mix == "mla":
        x, cache = L.mla_decode(cfg, p["mix"], x, cache, pos)
    elif mix == "mamba":
        x, cache = L.mamba_decode(cfg, p["mix"], x, cache)
    elif mix == "rwkv":
        x, cache = L.rwkv_decode(cfg, p["mix"], x, cache)
    if enc_out is not None and "cross" in p:
        x = L.cross_attn_forward(cfg, p["cross"], x, enc_out)
    if ffn == "moe":
        x = L.moe_forward(cfg, p["ffn"], x)
    elif ffn == "mlp":
        x = L.mlp_forward(cfg, p["ffn"], x)
    return x, cache


def decode_step(cfg: ArchConfig, params, cache, batch):
    """batch: {token: (B,1) int32, pos: (B,) int32, [frames/patches stubs]}.
    Returns (logits (B,1,V), new cache)."""
    tok = batch["token"]
    pos = batch["pos"]
    x = params["embed"][tok]
    enc_out = None
    if cfg.family == "encdec":
        enc_out = encode(cfg, params, batch["frames"].astype(x.dtype))
    specs = period_specs(cfg)
    new_cache = {}
    if cfg.dense_prefix_layers:
        pc = []
        for i in range(cfg.dense_prefix_layers):
            x, c = _decode_layer(cfg, (cfg.layer_kind(i), "mlp"),
                                 params["prefix"][i], x,
                                 cache["prefix"][i], pos, enc_out)
            pc.append(c)
        new_cache["prefix"] = pc

    def body(carry, sl):
        x = carry
        pslice, cslice = sl
        ncs = {}
        for i, spec in enumerate(specs):
            x, nc = _decode_layer(cfg, spec, pslice[f"pos{i}"], x,
                                  cslice[f"pos{i}"], pos, enc_out)
            ncs[f"pos{i}"] = nc
        return x, ncs

    x, blocks_cache = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
    new_cache["blocks"] = blocks_cache
    h = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return logits_from_hidden(cfg, params, h), new_cache

"""Batch builders + ShapeDtypeStruct input specs for every (arch x shape).

The modality frontends are STUBS per the assignment: whisper gets precomputed
frame embeddings (B, enc_seq, D) and paligemma gets precomputed SigLIP patch
embeddings (B, n_img_tokens, D)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ArchConfig, ShapeConfig


def batch_shapes(cfg: ArchConfig, shape: ShapeConfig) -> dict[str, tuple]:
    B, S = shape.global_batch, shape.seq_len
    dt = cfg.dtype
    if shape.kind in ("train", "prefill"):
        n_txt = S - cfg.n_img_tokens if cfg.family == "vlm" else S
        d = {"tokens": ((B, n_txt), "int32")}
        if shape.kind == "train":
            d["labels"] = ((B, n_txt), "int32")
            d["mask"] = ((B, n_txt), "float32")
        if cfg.family == "encdec":
            d["frames"] = ((B, cfg.enc_seq, cfg.d_model), dt)
        if cfg.family == "vlm":
            d["patches"] = ((B, cfg.n_img_tokens, cfg.d_model), dt)
        return d
    # decode: one new token against a seq_len-deep cache
    d = {"token": ((B, 1), "int32"), "pos": ((B,), "int32")}
    if cfg.family == "encdec":
        d["frames"] = ((B, cfg.enc_seq, cfg.d_model), dt)
    return d


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict[str, jax.ShapeDtypeStruct]:
    return {k: jax.ShapeDtypeStruct(shp, jnp.dtype(dt))
            for k, (shp, dt) in batch_shapes(cfg, shape).items()}


def make_batch(cfg: ArchConfig, shape: ShapeConfig, seed: int = 0):
    """Concrete random batch (CPU smoke tests / examples)."""
    rng = np.random.default_rng(seed)
    out = {}
    for k, (shp, dt) in batch_shapes(cfg, shape).items():
        if dt == "int32":
            hi = cfg.vocab if k in ("tokens", "labels", "token") else shape.seq_len - 1
            if k == "pos":
                out[k] = jnp.full(shp, shape.seq_len - 1, jnp.int32)
            else:
                out[k] = jnp.asarray(rng.integers(0, hi, size=shp), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.normal(0, 1, size=shp), jnp.dtype(dt))
    return out

"""Shared model layers (pure functions over pytrees of jnp arrays).

Everything is written to be (a) `lax.scan`-stackable over layers so the HLO
stays compact for 512-device dry-run compiles, and (b) shardable by the
declarative rules in ``repro/parallel/sharding.py`` (attention heads / FFN
columns on the "model" axis, batch on "data"/"pod", experts on "model").
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ArchConfig


def _dt(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# normalization + rotary
# ---------------------------------------------------------------------------


def rms_norm(x, w, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * w


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta):
    """x: (..., S, H, hd); positions: (..., S)"""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# dense attention (GQA / MQA) with optional KV cache
# ---------------------------------------------------------------------------


def init_attn(cfg: ArchConfig, key):
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = D ** -0.5
    dt = _dt(cfg)
    return {
        "wq": (jax.random.normal(k1, (D, H, hd)) * s).astype(dt),
        "wk": (jax.random.normal(k2, (D, K, hd)) * s).astype(dt),
        "wv": (jax.random.normal(k3, (D, K, hd)) * s).astype(dt),
        "wo": (jax.random.normal(k4, (H, hd, D)) * s).astype(dt),
        "norm": jnp.ones((D,), dt),
    }


def _repeat_kv(k, n_rep):
    """(B,T,K,hd) -> (B,T,K*n_rep,hd).  Materializing the repeat keeps the
    attention einsums 4-D with a single head axis, which XLA's SPMD
    propagation shards cleanly over "model" (the 5-D grouped form was
    replicated across the model axis — a 3x compute bug found in the
    dry-run roofline; see EXPERIMENTS.md §Perf)."""
    if n_rep == 1:
        return k
    B, T, K, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (B, T, K, n_rep, hd)) \
        .reshape(B, T, K * n_rep, hd)


def _sdpa(cfg, q, k, v, mask, dtype):
    """q: (B,S,H,hd); k,v: (B,T,H,hd); mask broadcastable to (B,H,S,T).

    cfg.scores_bf16 keeps the (S x T) score tensor in bf16 with fp32 row
    statistics (flash-attention numerics) — halves the dominant attention
    traffic on memory-bound train/prefill cells (§Perf)."""
    from repro.parallel.sharding import constrain
    hd = q.shape[-1]
    q = constrain(q, "dp", None, "model", None)
    k = constrain(k, "dp", None, "model", None)
    v = constrain(v, "dp", None, "model", None)
    sd = jnp.bfloat16 if cfg.scores_bf16 else jnp.float32
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(sd) * \
        jnp.asarray(hd ** -0.5, sd)
    scores = constrain(scores, "dp", "model", None, None)
    neg = jnp.asarray(jnp.finfo(sd).min / 2, sd)
    scores = jnp.where(mask, scores, neg)
    m = jax.lax.stop_gradient(scores.max(axis=-1, keepdims=True))
    p = jnp.exp(scores - m)
    l = jnp.sum(p, axis=-1, keepdims=True, dtype=jnp.float32)
    w = (p / l.astype(sd)).astype(dtype)
    o = jnp.einsum("bhst,bthd->bshd", w, v)
    return constrain(o, "dp", None, "model", None)


def _sdpa_chunked(cfg, q, k, v, pos_q, pos_k, dtype):
    """Flash-style chunked attention in pure JAX (hillclimb lever for the
    memory-bound train/prefill cells): lax.scan over kv blocks with online
    softmax — the (S x T) score matrix never materializes at once; the mask
    is an iota comparison per block instead of a (B,1,S,T) bool tensor.
    q: (B,S,H,hd); k,v: (B,T,H,hd); pos_*: (B,S)/(B,T)."""
    from repro.parallel.sharding import constrain
    B, S, H, hd = q.shape
    T = k.shape[1]
    C = min(cfg.attn_chunk, T)
    assert T % C == 0, (T, C)
    q = constrain(q, "dp", None, "model", None).astype(jnp.float32)
    scale = hd ** -0.5
    kc = k.reshape(B, T // C, C, H, hd).swapaxes(0, 1)
    vc = v.reshape(B, T // C, C, H, hd).swapaxes(0, 1)
    pc = pos_k.reshape(B, T // C, C).swapaxes(0, 1)

    def body(carry, blk):
        acc, m, l = carry
        kb, vb, pb = blk
        s = jnp.einsum("bshd,bchd->bhsc", q, kb.astype(jnp.float32)) * scale
        valid = pos_q[:, None, :, None] >= pb[:, None, None, :]
        s = jnp.where(valid, s, -1e30)
        m1 = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m1[..., None])
        alpha = jnp.exp(m - m1)
        l1 = l * alpha + p.sum(axis=-1)
        acc1 = acc * alpha[..., None] + jnp.einsum(
            "bhsc,bchd->bhsd", p, vb.astype(jnp.float32))
        return (acc1, m1, l1), None

    acc0 = jnp.zeros((B, H, S, hd), jnp.float32)
    m0 = jnp.full((B, H, S), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (kc, vc, pc))
    o = (acc / (l[..., None] + 1e-30)).swapaxes(1, 2).astype(dtype)
    return constrain(o, "dp", None, "model", None)


def attn_forward(cfg: ArchConfig, p, x, positions, causal=True):
    """Full-sequence attention (train / prefill). x: (B, S, D)."""
    B, S, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    kr, vr = _repeat_kv(k, H // K), _repeat_kv(v, H // K)
    if cfg.attn_impl == "chunked" and causal:
        o = _sdpa_chunked(cfg, q, kr, vr, positions, positions, x.dtype)
    else:
        if causal:
            mask = (positions[:, :, None] >= positions[:, None, :])[:, None]
        else:
            mask = jnp.ones((B, 1, S, S), bool)
        o = _sdpa(cfg, q, kr, vr, mask, x.dtype)
    return x + jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def attn_decode(cfg: ArchConfig, p, x, cache, pos):
    """One-token decode. x: (B, 1, D); cache: {k,v: (B, Smax, K, hd)};
    pos: (B,) current write position."""
    B = x.shape[0]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    ck = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
        c, u, (i, 0, 0)))(cache["k"], k[:, 0:1], pos)
    cv = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
        c, u, (i, 0, 0)))(cache["v"], v[:, 0:1], pos)
    Smax = ck.shape[1]
    valid = (jnp.arange(Smax)[None, :] <= pos[:, None])[:, None, None, :]
    o = _sdpa(cfg, q, _repeat_kv(ck, H // K), _repeat_kv(cv, H // K), valid,
              x.dtype)
    out = x + jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, {"k": ck, "v": cv}


def init_attn_cache(cfg: ArchConfig, B, Smax, dt):
    K, hd = cfg.n_kv_heads, cfg.hd
    return {"k": jnp.zeros((B, Smax, K, hd), dt),
            "v": jnp.zeros((B, Smax, K, hd), dt)}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(cfg: ArchConfig, key):
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    dt = _dt(cfg)
    s = D ** -0.5
    qd = m.nope_head_dim + m.rope_head_dim
    return {
        "wdq": (jax.random.normal(ks[0], (D, m.q_lora_rank)) * s).astype(dt),
        "wuq": (jax.random.normal(ks[1], (m.q_lora_rank, H, qd))
                * m.q_lora_rank ** -0.5).astype(dt),
        "wdkv": (jax.random.normal(ks[2], (D, m.kv_lora_rank + m.rope_head_dim))
                 * s).astype(dt),
        "wukv": (jax.random.normal(
            ks[3], (m.kv_lora_rank, H, m.nope_head_dim + m.v_head_dim))
            * m.kv_lora_rank ** -0.5).astype(dt),
        "wo": (jax.random.normal(ks[4], (H, m.v_head_dim, D)) * s).astype(dt),
        "norm": jnp.ones((D,), dt),
    }


def _mla_qkv(cfg, p, h, positions):
    m = cfg.mla
    H = cfg.n_heads
    q = jnp.einsum("bsd,dr->bsr", h, p["wdq"])
    q = jnp.einsum("bsr,rhq->bshq", q, p["wuq"])
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv = jnp.einsum("bsd,dr->bsr", h, p["wdkv"])
    c_kv, k_rope = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope[:, :, 0, :]


def _mla_attend(cfg, p, x, q_nope, q_rope, c_kv, k_rope, valid):
    """c_kv: (B, T, r); k_rope: (B, T, rope_hd) shared across heads."""
    from repro.parallel.sharding import constrain
    m = cfg.mla
    B, S = q_nope.shape[:2]
    kv = jnp.einsum("btr,rhe->bthe", c_kv, p["wukv"])
    kv = constrain(kv, "dp", None, "model", None)
    k_nope, v = jnp.split(kv, [m.nope_head_dim], axis=-1)
    q_nope = constrain(q_nope, "dp", None, "model", None)
    sc = jnp.einsum("bshq,bthq->bhst", q_nope, k_nope)
    sc = sc + jnp.einsum("bshq,btq->bhst", q_rope, k_rope)
    sc = sc.astype(jnp.float32) * ((m.nope_head_dim + m.rope_head_dim) ** -0.5)
    sc = constrain(sc, "dp", "model", None, None)
    sc = jnp.where(valid, sc, -1e30)
    w = jax.nn.softmax(sc, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhst,bthv->bshv", w, v)
    return x + jnp.einsum("bshv,hvd->bsd", o, p["wo"])


def mla_forward(cfg: ArchConfig, p, x, positions, causal=True):
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(cfg, p, h, positions)
    valid = (positions[:, None, :, None] >= positions[:, None, None, :]) \
        if causal else True
    return _mla_attend(cfg, p, x, q_nope, q_rope, c_kv, k_rope, valid)


def mla_decode(cfg: ArchConfig, p, x, cache, pos):
    """Cache stores the COMPRESSED latents (B, Smax, r + rope_hd) — the whole
    point of MLA: the per-token cache is kv_lora + rope wide, not 2*H*hd."""
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv(cfg, p, h, pos[:, None])
    upd = jnp.concatenate([c_kv_new, k_rope_new], axis=-1)
    ck = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
        c, u, (i, 0)))(cache["ckv"], upd, pos)
    m = cfg.mla
    c_kv, k_rope = jnp.split(ck, [m.kv_lora_rank], axis=-1)
    Smax = ck.shape[1]
    valid = (jnp.arange(Smax)[None, :] <= pos[:, None])[:, None, None, :]
    out = _mla_attend(cfg, p, x, q_nope, q_rope, c_kv, k_rope, valid)
    return out, {"ckv": ck}


def init_mla_cache(cfg: ArchConfig, B, Smax, dt):
    m = cfg.mla
    return {"ckv": jnp.zeros((B, Smax, m.kv_lora_rank + m.rope_head_dim), dt)}


# ---------------------------------------------------------------------------
# FFN: swiglu / geglu / gelu  + MoE
# ---------------------------------------------------------------------------


def init_mlp(cfg: ArchConfig, key, d_ff=None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    dt = _dt(cfg)
    p = {"norm": jnp.ones((D,), dt),
         "w_up": (jax.random.normal(k2, (D, F)) * D ** -0.5).astype(dt),
         "w_down": (jax.random.normal(k3, (F, D)) * F ** -0.5).astype(dt)}
    if cfg.act in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(k1, (D, F)) * D ** -0.5).astype(dt)
    return p


def mlp_forward(cfg: ArchConfig, p, x):
    from repro.parallel.sharding import constrain
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    up = h @ p["w_up"]
    if cfg.act == "swiglu":
        up = jax.nn.silu(h @ p["w_gate"]) * up
    elif cfg.act == "geglu":
        up = jax.nn.gelu(h @ p["w_gate"]) * up
    else:  # gelu (whisper-style 2-matrix MLP)
        up = jax.nn.gelu(up)
    up = constrain(up, "dp", None, "model")
    return x + up @ p["w_down"]


def init_moe(cfg: ArchConfig, key):
    D = cfg.d_model
    mc = cfg.moe
    E, F = mc.n_experts, mc.d_ff
    ks = jax.random.split(key, 5)
    dt = _dt(cfg)
    p = {
        "norm": jnp.ones((D,), dt),
        "router": (jax.random.normal(ks[0], (D, E)) * D ** -0.5).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, D, F)) * D ** -0.5).astype(dt),
        "w_up": (jax.random.normal(ks[2], (E, D, F)) * D ** -0.5).astype(dt),
        "w_down": (jax.random.normal(ks[3], (E, F, D)) * F ** -0.5).astype(dt),
    }
    if mc.n_shared:
        p["shared"] = init_mlp(cfg, ks[4], d_ff=mc.d_ff * mc.n_shared)
    return p


def moe_forward(cfg: ArchConfig, p, x):
    """Grouped capacity-based top-k MoE with gather/scatter dispatch.

    The textbook one-hot *einsum* dispatch costs O(T * E * C * D) dense FLOPs
    — at DeepSeek/Kimi scale that dwarfs the experts themselves (observed
    175x overcount in the dry-run roofline).  Instead we scatter token ids
    into (E, C) slot tables and gather activations, so dispatch costs bytes,
    not FLOPs.  Groups = batch rows (data-sharded); experts shard over
    "model" (EP) and the gathers become XLA all-to-alls."""
    from repro.parallel.sharding import constrain

    B, S, D = x.shape
    mc = cfg.moe
    E, K = mc.n_experts, mc.top_k
    h = rms_norm(x, p["norm"], cfg.norm_eps)          # (G, Tg, D); G=B, Tg=S
    G, Tg = B, S
    logits = h.astype(jnp.float32) @ p["router"]      # (G, Tg, E)
    gates = jax.nn.softmax(logits, axis=-1)
    gval, gidx = jax.lax.top_k(gates, K)              # (G, Tg, K)
    gval = gval / (jnp.sum(gval, axis=-1, keepdims=True) + 1e-9)
    C = max(1, int(Tg * K * mc.capacity_factor / E))
    # position-in-expert WITHOUT the (T, K, E) one-hot cumsum (which costs
    # O(T*K*E) memory — 13 TB at Kimi scale, the dominant traffic in the
    # baseline roofline): sort the flat expert ids, rank within runs, and
    # scatter the ranks back.
    N = Tg * K
    eflat = gidx.reshape(G, N)
    order = jnp.argsort(eflat, axis=1, stable=True)           # (G, N)
    sorted_e = jnp.take_along_axis(eflat, order, axis=1)
    first = jax.vmap(lambda s: jnp.searchsorted(s, s, side="left"))(sorted_e)
    ranks = jnp.arange(N)[None, :] - first                    # pos in expert
    gdx0 = jnp.arange(G)[:, None]
    posc = jnp.zeros((G, N), jnp.int32).at[gdx0, order].set(
        ranks.astype(jnp.int32)).reshape(G, Tg, K)
    keep = posc < C                                    # (G, Tg, K)
    slot = gidx * C + posc                             # unique per kept (t,k)
    flat_slot = jnp.where(keep, slot, E * C)           # overflow bucket
    gdx = jnp.arange(G)[:, None, None]
    tok = jnp.broadcast_to(jnp.arange(Tg)[None, :, None], (G, Tg, K))
    src = jnp.zeros((G, E * C + 1), jnp.int32).at[gdx, flat_slot].set(
        tok, mode="drop")[:, :E * C]
    vld = jnp.zeros((G, E * C + 1), x.dtype).at[gdx, flat_slot].set(
        jnp.ones((G, Tg, K), x.dtype), mode="drop")[:, :E * C]
    # dispatch: gather tokens into (G, E, C, D) expert buffers
    xin = jnp.take_along_axis(h, src[..., None], axis=1) * vld[..., None]
    xin = xin.reshape(G, E, C, D)
    xin = constrain(xin, "dpx", "ep", None, None)
    act = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin, p["w_gate"]))
    mid = act * jnp.einsum("gecd,edf->gecf", xin, p["w_up"])
    xout = jnp.einsum("gecf,efd->gecd", mid, p["w_down"])
    xout = constrain(xout, "dpx", "ep", None, None)
    # combine: gather each (t, k)'s slot back and weight by the gate
    flat = xout.reshape(G, E * C, D)
    vals = jnp.take_along_axis(
        flat, jnp.clip(slot, 0, E * C - 1).reshape(G, Tg * K)[..., None],
        axis=1).reshape(G, Tg, K, D)
    w = (gval.astype(x.dtype) * keep.astype(x.dtype))[..., None]
    out = jnp.sum(vals * w, axis=2)                    # (G, Tg, D)
    if mc.n_shared:
        return mlp_forward(cfg, p["shared"], x) + out
    return x + out


# ---------------------------------------------------------------------------
# Mamba (selective SSM) — chunked scan, O(S) memory in the chunk size
# ---------------------------------------------------------------------------


def init_mamba(cfg: ArchConfig, key):
    D = cfg.d_model
    di = cfg.mamba_expand * D
    N = cfg.mamba_d_state
    kc = cfg.mamba_d_conv
    dt_rank = max(1, D // 16)
    ks = jax.random.split(key, 7)
    dt = _dt(cfg)
    return {
        "norm": jnp.ones((D,), dt),
        "w_in": (jax.random.normal(ks[0], (D, 2 * di)) * D ** -0.5).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (kc, di)) * kc ** -0.5).astype(dt),
        "w_bc": (jax.random.normal(ks[2], (di, 2 * N)) * di ** -0.5).astype(dt),
        "w_dt": (jax.random.normal(ks[3], (di, dt_rank)) * di ** -0.5).astype(dt),
        "w_dt2": (jax.random.normal(ks[4], (dt_rank, di)) * dt_rank ** -0.5).astype(dt),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32), (di, 1))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_out": (jax.random.normal(ks[5], (di, D)) * di ** -0.5).astype(dt),
    }


def _mamba_core(cfg, p, xz, h0, conv_tail):
    """xz: (B, S, 2*di). Returns (y, h_final, new_conv_tail)."""
    B, S, _ = xz.shape
    di = cfg.mamba_expand * cfg.d_model
    N = cfg.mamba_d_state
    kc = cfg.mamba_d_conv
    x, z = jnp.split(xz, 2, axis=-1)
    # causal short conv along S (tail carries state across calls)
    xp = jnp.concatenate([conv_tail, x], axis=1)
    c = sum(xp[:, i:i + S, :] * p["conv_w"][i] for i in range(kc))
    new_tail = xp[:, S:S + kc - 1, :]
    c = jax.nn.silu(c)
    bc = jnp.einsum("bsd,dn->bsn", c, p["w_bc"])
    Bm, Cm = jnp.split(bc, 2, axis=-1)                       # (B,S,N)
    dt_ = jax.nn.softplus(
        jnp.einsum("bsd,dr,re->bse", c, p["w_dt"], p["w_dt2"]).astype(jnp.float32))
    A = -jnp.exp(p["a_log"])                                  # (di, N)
    decay = jnp.exp(dt_[..., None] * A)                       # (B,S,di,N)
    drive = (dt_ * c.astype(jnp.float32))[..., None] * Bm[:, :, None, :]

    def assoc(a, b):
        return (a[0] * b[0], a[1] * b[0] + b[1])

    dec_c, drv_c = jax.lax.associative_scan(assoc, (decay, drive), axis=1)
    # fold in the carried state h0: h_t = dec_c * h0 + drv_c
    h = dec_c * h0[:, None] + drv_c                           # (B,S,di,N)
    y = jnp.einsum("bsdn,bsn->bsd", h, Cm.astype(jnp.float32))
    y = y + p["d_skip"] * c.astype(jnp.float32)
    y = y.astype(xz.dtype) * jax.nn.silu(z)
    return y, h[:, -1], new_tail


def mamba_forward(cfg: ArchConfig, p, x, chunk=256):
    B, S, D = x.shape
    di = cfg.mamba_expand * D
    N = cfg.mamba_d_state
    kc = cfg.mamba_d_conv
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    xz = jnp.einsum("bsd,de->bse", h, p["w_in"])
    from repro.parallel.sharding import constrain
    xz = constrain(xz, "dp", None, "model")
    chunk = min(chunk, S)
    assert S % chunk == 0, "seq_len must be divisible by the mamba chunk"
    xz_c = xz.reshape(B, S // chunk, chunk, 2 * di).swapaxes(0, 1)

    def step(carry, xc):
        h0, tail = carry
        y, h1, tail1 = _mamba_core(cfg, p, xc, h0, tail)
        return (h1, tail1), y

    h0 = jnp.zeros((B, di, N), jnp.float32)
    tail0 = jnp.zeros((B, kc - 1, di), xz.dtype)
    _, ys = jax.lax.scan(step, (h0, tail0), xz_c)
    y = ys.swapaxes(0, 1).reshape(B, S, di)
    return x + jnp.einsum("bse,ed->bsd", y, p["w_out"])


def mamba_decode(cfg: ArchConfig, p, x, cache):
    """One-token decode; cache = {h: (B,di,N) fp32, tail: (B,kc-1,di)}."""
    y, h1, tail1 = _mamba_core(
        cfg, p,
        jnp.einsum("bsd,de->bse", rms_norm(x, p["norm"], cfg.norm_eps), p["w_in"]),
        cache["h"], cache["tail"])
    out = x + jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, {"h": h1, "tail": tail1}


def init_mamba_cache(cfg: ArchConfig, B, dt):
    di = cfg.mamba_expand * cfg.d_model
    return {"h": jnp.zeros((B, di, cfg.mamba_d_state), jnp.float32),
            "tail": jnp.zeros((B, cfg.mamba_d_conv - 1, di), dt)}


# ---------------------------------------------------------------------------
# RWKV-6 (Finch): token shift + data-dependent decay WKV
# ---------------------------------------------------------------------------


def init_rwkv(cfg: ArchConfig, key):
    D = cfg.d_model
    ks = jax.random.split(key, 8)
    dt = _dt(cfg)
    s = D ** -0.5
    return {
        "norm_a": jnp.ones((D,), dt),
        "norm_f": jnp.ones((D,), dt),
        "mix": (jax.random.normal(ks[0], (5, D)) * 0.01).astype(dt),
        "wr": (jax.random.normal(ks[1], (D, D)) * s).astype(dt),
        "wk": (jax.random.normal(ks[2], (D, D)) * s).astype(dt),
        "wv": (jax.random.normal(ks[3], (D, D)) * s).astype(dt),
        "wg": (jax.random.normal(ks[4], (D, D)) * s).astype(dt),
        "wdecay": (jax.random.normal(ks[5], (D, D)) * 0.01).astype(dt),
        "u_bonus": (jax.random.normal(ks[6], (D,)) * 0.1).astype(jnp.float32),
        "wo": (jax.random.normal(ks[7], (D, D)) * s).astype(dt),
        # channel mix
        "ck": (jax.random.normal(ks[0], (D, cfg.d_ff)) * s).astype(dt),
        "cv": (jax.random.normal(ks[1], (cfg.d_ff, D)) * cfg.d_ff ** -0.5).astype(dt),
        "cmix": (jax.random.normal(ks[2], (D,)) * 0.01).astype(dt),
    }


def _token_shift(x, last):
    """shift right by one along S; ``last`` is (B,1,D) carry."""
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _wkv_chunk(r, k, v, w, u, s0):
    """Chunked WKV6 recurrence (per head).  r,k,v: (B,H,C,hd); w: decay in
    (0,1) (B,H,C,hd); u: (H,hd) bonus; s0: (B,H,hd,hd) carried state.
    Returns (out (B,H,C,hd), s1).  fp32 math."""
    B, H, C, hd = r.shape
    logw = jnp.log(w)
    cw = jnp.cumsum(logw, axis=2)                        # (B,H,C,hd)
    # decay from token j (exclusive) to token t: exp(cw[t] - cw[j])
    # intra-chunk: out[t] += sum_{j<t} r[t]·(exp(cw[t-1]-cw[j]) k[j]) v[j]
    cw_prev = cw - logw                                   # cw[t-1]
    rd = r * jnp.exp(cw_prev)                             # (B,H,C,hd)
    kd = k * jnp.exp(-cw)
    att = jnp.einsum("bhtd,bhjd->bhtj", rd, kd)
    mask = jnp.tril(jnp.ones((C, C)), -1)
    att = att * mask
    out = jnp.einsum("bhtj,bhje->bhte", att, v)
    # bonus (current token)
    out = out + jnp.einsum("bhtd,bhtd,bhte->bhte", r, k * u[None, :, None, :], v)
    # carried state
    out = out + jnp.einsum("bhtd,bhde->bhte", rd, s0)
    # state update: s1 = diag(exp(cw[-1])) s0 + sum_j exp(cw[-1]-cw[j]) k_j v_j^T
    wtot = jnp.exp(cw[:, :, -1])                          # (B,H,hd)
    s1 = s0 * wtot[..., None] + jnp.einsum(
        "bhjd,bhje->bhde", k * jnp.exp(cw[:, :, -1:] - cw), v)
    return out, s1


def rwkv_time_mix(cfg: ArchConfig, p, x, shift_last, s0, chunk=128):
    B, S, D = x.shape
    H = D // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    h = rms_norm(x, p["norm_a"], cfg.norm_eps)
    prev = _token_shift(h, shift_last)
    mix = jax.nn.sigmoid(p["mix"])                        # (5, D)
    feats = [h + (prev - h) * mix[i] for i in range(5)]
    r = feats[0] @ p["wr"]
    k = feats[1] @ p["wk"]
    v = feats[2] @ p["wv"]
    g = jax.nn.silu(feats[3] @ p["wg"])
    w = jnp.exp(-jnp.exp((feats[4] @ p["wdecay"]).astype(jnp.float32) - 4.0))

    def heads(t):
        return t.reshape(B, S, H, hd).swapaxes(1, 2)      # (B,H,S,hd)

    from repro.parallel.sharding import constrain
    rh, kh, vh, wh = map(
        lambda t: constrain(heads(t).astype(jnp.float32),
                            "dp", "model", None, None), (r, k, v, w))
    u = p["u_bonus"].reshape(H, hd)
    chunk = min(chunk, S)
    assert S % chunk == 0
    nch = S // chunk

    def step(s, args):
        rc, kc, vc, wc = args
        out, s1 = _wkv_chunk(rc, kc, vc, wc, u, s)
        return s1, out

    split = lambda t: t.reshape(B, H, nch, chunk, hd).swapaxes(0, 2).swapaxes(1, 2)
    s_fin, outs = jax.lax.scan(step, s0, tuple(map(split, (rh, kh, vh, wh))))
    out = outs.swapaxes(1, 2).swapaxes(0, 2).reshape(B, H, S, hd)
    out = out.swapaxes(1, 2).reshape(B, S, D).astype(x.dtype) * g
    y = x + (out @ p["wo"])
    return y, h[:, -1:], s_fin


def rwkv_channel_mix(cfg: ArchConfig, p, x, shift_last):
    h = rms_norm(x, p["norm_f"], cfg.norm_eps)
    prev = _token_shift(h, shift_last)
    mixed = h + (prev - h) * jax.nn.sigmoid(p["cmix"])
    v = jnp.square(jax.nn.relu(mixed @ p["ck"])) @ p["cv"]
    return x + v, h[:, -1:]


def rwkv_forward(cfg: ArchConfig, p, x):
    B, S, D = x.shape
    H = D // cfg.rwkv_head_dim
    s0 = jnp.zeros((B, H, cfg.rwkv_head_dim, cfg.rwkv_head_dim), jnp.float32)
    zero = jnp.zeros((B, 1, D), x.dtype)
    y, _, _ = rwkv_time_mix(cfg, p, x, zero, s0)
    y, _ = rwkv_channel_mix(cfg, p, y, zero)
    return y


def rwkv_decode(cfg: ArchConfig, p, x, cache):
    y, sa, s1 = rwkv_time_mix(cfg, p, x, cache["shift_a"], cache["s"], chunk=1)
    y, sf = rwkv_channel_mix(cfg, p, y, cache["shift_f"])
    return y, {"shift_a": sa, "shift_f": sf, "s": s1}


def init_rwkv_cache(cfg: ArchConfig, B, dt):
    D = cfg.d_model
    H = D // cfg.rwkv_head_dim
    return {"shift_a": jnp.zeros((B, 1, D), dt),
            "shift_f": jnp.zeros((B, 1, D), dt),
            "s": jnp.zeros((B, H, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                           jnp.float32)}


# ---------------------------------------------------------------------------
# cross attention (whisper decoder)
# ---------------------------------------------------------------------------


def init_cross_attn(cfg: ArchConfig, key):
    return init_attn(cfg, key)


def cross_attn_forward(cfg: ArchConfig, p, x, enc_out):
    B, S, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    T = enc_out.shape[1]
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    o = _sdpa(cfg, q, _repeat_kv(k, H // K), _repeat_kv(v, H // K),
              jnp.ones((B, 1, S, T), bool), x.dtype)
    return x + jnp.einsum("bshk,hkd->bsd", o, p["wo"])

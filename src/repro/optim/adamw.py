"""AdamW with global-norm clipping (pure pytree implementation).

Moments are kept in the parameter dtype by default (the large-model memory
budget in DESIGN.md); pass ``moment_dtype='float32'`` for small-scale runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params, moment_dtype=None):
    def zeros(p):
        dt = jnp.dtype(moment_dtype) if moment_dtype else p.dtype
        return jnp.zeros(p.shape, dt)

    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


def adamw_update(params, grads, state, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    count = state["count"] + 1
    cf = count.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m1 = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v1 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        mhat = m1 / (1 - b1 ** cf)
        vhat = v1 / (1 - b2 ** cf)
        step = lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay *
                     p.astype(jnp.float32))
        return ((p.astype(jnp.float32) - step).astype(p.dtype),
                m1.astype(m.dtype), v1.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": count}

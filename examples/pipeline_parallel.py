import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8").strip()
"""Pipeline parallelism driven by the ILP schedule, executed with
shard_map + lax.ppermute on an 8-device host-platform mesh.

    python examples/pipeline_parallel.py        (sets its own XLA_FLAGS)
"""
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pipeline_ilp
from repro.parallel.pipeline import (pipelined_forward, pipelined_loss,
                                     reference_forward)


def main():
    S, M, D = 8, 16, 64
    mesh = jax.make_mesh((S,), ("stage",))
    print("ILP-synthesized schedule:")
    ps = pipeline_ilp.synthesize(S, M, t_f=1, t_b=2)
    print(f"  II={ps.ii} latency={ps.latency} "
          f"peak_act={ps.peak_live_activations} "
          f"(gpipe latency {pipeline_ilp.gpipe_latency(S, M)}, "
          f"gpipe peak act {S * M})")

    key = jax.random.key(0)
    stage_params = {
        "w": jax.random.normal(key, (S, D, D)) * (D ** -0.5),
        "b": jnp.zeros((S, D)),
    }

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])

    mbs = jax.random.normal(jax.random.key(1), (M, 4, D))
    out = pipelined_forward(stage_fn, stage_params, mbs, mesh, "stage")
    ref = reference_forward(stage_fn, stage_params, mbs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    print("pipelined forward == sequential reference ✓")

    tgt = jnp.zeros_like(ref)
    g = jax.grad(lambda p: pipelined_loss(stage_fn, p, mbs, tgt, mesh,
                                          "stage"))(stage_params)
    gref = jax.grad(lambda p: jnp.mean(
        jnp.square(reference_forward(stage_fn, p, mbs) - tgt)))(stage_params)
    np.testing.assert_allclose(np.asarray(g["w"]), np.asarray(gref["w"]),
                               rtol=2e-4, atol=2e-5)
    print("backward through the pipeline (AD transpose of the ILP schedule) ✓")


if __name__ == "__main__":
    main()

"""End-to-end training example.

Default: a 2-minute CPU-sized run (reduced llama3-8b family).  The ~100M
configuration from the assignment brief:

    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

trains a 12L/768d/12H model (~134M params incl. embeddings) for a few
hundred steps with checkpointing + the fault-tolerant loop.
"""
import argparse
import sys
sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    from repro.launch import train

    if args.preset == "tiny":
        steps = args.steps or 30
        argv = ["--arch", "llama3_8b", "--reduced", "--steps", str(steps),
                "--batch", "4", "--seq", "128",
                "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "10"]
        losses = train.main(argv)
    else:
        # ~100M: build the config inline (configs define the assigned archs;
        # this one is the example-scale model from the brief)
        import dataclasses
        from repro.config import get_config
        import repro.configs.llama3_8b as base

        cfg100 = dataclasses.replace(
            base.config(), n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, head_dim=64, d_ff=3072, vocab=32000,
            dtype="float32")
        print(f"params ~= {cfg100.param_count()/1e6:.0f}M")
        steps = args.steps or 300
        # monkey-patch get_config path: drive the trainer with the custom cfg
        from repro.launch import steps as steps_mod
        import repro.launch.train as T
        orig = T.get_config
        T.get_config = lambda *a, **k: cfg100
        try:
            losses = T.main(["--arch", "llama3_8b", "--steps", str(steps),
                             "--batch", "4", "--seq", "256",
                             "--ckpt-dir", args.ckpt_dir,
                             "--ckpt-every", "50"])
        finally:
            T.get_config = orig
    if len(losses) >= 20:  # too noisy to assert on very short runs
        assert losses[-1] < losses[0], "loss must decrease"
        print("OK: loss decreased", losses[0], "->", losses[-1])
    else:
        print("short run:", losses[0], "->", losses[-1])


if __name__ == "__main__":
    main()

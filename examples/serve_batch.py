"""Batched serving example: prefill + greedy decode with KV/state caches for
an attention-free (RWKV-6) and an attention (llama) architecture.

    PYTHONPATH=src python examples/serve_batch.py
"""
import sys
sys.path.insert(0, "src")

from repro.launch import serve


def main():
    for arch in ("rwkv6_3b", "llama3_8b"):
        print(f"=== serving {arch} (reduced) ===")
        serve.main(["--arch", arch, "--reduced", "--batch", "4",
                    "--prompt-len", "16", "--gen", "12"])


if __name__ == "__main__":
    main()

"""Quickstart: the paper's ILP scheduler in 60 seconds.

Runs the Fig.1 convolution chain through dependence analysis -> II autotune
-> scheduling ILP, prints the HIR-style schedule, validates it against the
sequential semantics, and shows the same engine deriving a 1F1B-class
pipeline-parallel schedule and a compute/comm overlap plan.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

import numpy as np

from repro.core import emit_hir, hls
from repro.core.autotune import compile_program
from repro.core.programs import blur_chain, fig1_conv_chain, fig3_conv1d
from repro.core.sim import make_inputs, sequential_exec, timed_exec, \
    validate_schedule
from repro.core import pipeline_ilp, overlap


def main():
    print("=" * 70)
    print("1. Paper Fig.3: 1-D convolution — the scheduler must find II=7")
    print("=" * 70)
    p = fig3_conv1d()
    s = compile_program(p, verbose=True)
    print(emit_hir(s))

    print("=" * 70)
    print("2. Paper Fig.1: chained convolutions — producer-consumer overlap")
    print("=" * 70)
    p = fig1_conv_chain(n=8)
    s = compile_program(p)
    seq = s.sequential_nests_latency()
    ovl = s.completion_time()
    print(f"loop-only pipelining: {seq} cycles")
    print(f"multi-dimensional pipelining: {ovl} cycles  "
          f"({seq / ovl:.2f}x, paper band 1.7-3.7x)")
    inp = make_inputs(p, 0)
    np.testing.assert_allclose(timed_exec(p, s, inp)["convY"],
                               sequential_exec(p, inp)["convY"], rtol=1e-12)
    assert validate_schedule(p, s) == []
    print("schedule validated: timed execution == sequential semantics")

    print("=" * 70)
    print("3. Declarative front end: hls.compile + the Pareto frontier")
    print("=" * 70)
    p = blur_chain(8, storage="bram")
    r = hls.compile(p, objectives=(hls.minimize("latency"),
                                   hls.minimize("bram")),
                    search=hls.SearchConfig(max_candidates=10,
                                            unroll_factors=(),
                                            tile_sizes=(2, 4)))
    print(f"{len(r.frontier)} non-dominated designs "
          f"(latency x BRAM x DSP x FF):")
    for c in r.frontier:
        print(f"  latency={c.latency:4d} bram={c.res['bram_bytes']:6.0f}B "
              f"ff={c.res['ff_bits']:5.0f}b  pipeline: "
              f"{r.pipeline_of(c) or '<none>'}")
    knee = r.knee("latency", "bram")
    print(f"knee point: {r.pipeline_of(knee) or '<none>'} "
          f"(what the Pallas stencil kernel reads its block/halo from)")

    print("=" * 70)
    print("4. Same ILP, new fabric: pipeline-parallel schedule synthesis")
    print("=" * 70)
    ps = pipeline_ilp.synthesize(4, 8, t_f=1, t_b=2)
    print(f"4 stages x 8 microbatches: II={ps.ii} ticks/microbatch "
          f"(optimal = t_f+t_b = 3)")
    print(f"fwd starts {ps.fwd_start}  bwd starts {ps.bwd_start}")
    print(f"latency {ps.latency} ticks; peak in-flight activations "
          f"{ps.peak_live_activations} (GPipe would hold "
          f"{4 * 8})")

    print("=" * 70)
    print("5. Compute/comm overlap plan (ring all-gather matmul)")
    print("=" * 70)
    plan = overlap.plan_ring_overlap(8)
    print(f"8-step ring: II={plan.ii} (1 = send/matmul fully overlapped), "
          f"latency {plan.latency} vs serial {plan.serial_latency} "
          f"({plan.overlap_speedup:.2f}x)")


if __name__ == "__main__":
    main()

"""Codegen backend (DESIGN.md §10): generated Pallas kernels vs the
``sim.sequential_exec`` oracle.

The float64 lowerings run under ``jax.experimental.enable_x64`` and are
bit-comparable to the float64 numpy oracle (same DAG, same order), so the
equivalence assertions use rtol=1e-12/atol=0 — anything looser would let a
structurally wrong window/halo slip through as "close enough".
"""
import random

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from jax.experimental import enable_x64  # noqa: E402

from repro.core import sim  # noqa: E402
from repro.core.codegen import PallasKernel, lower_program  # noqa: E402
from repro.core.errors import UnlowerableProgram  # noqa: E402
from repro.core.ir import ProgramBuilder  # noqa: E402
from repro.core.programs import (BENCHMARKS, CHAIN_BENCHMARKS,  # noqa: E402
                                 blur_chain, fig1_conv_chain, fig3_conv1d)
from repro.core.transforms import (FuseProducerConsumer, LoopTile,  # noqa: E402
                                   Normalize, PassManager)


def _exact(kernel, p, seed=0):
    """Assert the float64 kernel (interpret mode) matches sequential_exec
    exactly on every produced output."""
    inputs = sim.make_inputs(p, seed=seed)
    ref = sim.sequential_exec(p, inputs)
    with enable_x64():
        got = kernel(inputs, interpret=True)
    for a in kernel.outputs:
        np.testing.assert_allclose(np.asarray(got[a], np.float64), ref[a],
                                   rtol=1e-12, atol=0, err_msg=a)


# ---------------------------------------------------------------------------
# corpus coverage: every program either lowers + matches, or rejects
# structurally
# ---------------------------------------------------------------------------

_CORPUS = {**{k: v for k, v in BENCHMARKS.items()},
           **CHAIN_BENCHMARKS, "fig1_conv_chain": fig1_conv_chain}
_CORPUS_N = {"optical_flow": 6, "two_mm": 6}
# nothing in the corpus is structurally rejected anymore: two_mm's 3-deep
# canonical accumulations now lower in Mode B via a fori_loop left fold
_EXPECTED_UNLOWERABLE: set = set()


@pytest.mark.parametrize("name", sorted(_CORPUS))
@pytest.mark.parametrize("buffering", ["double", "single"])
def test_corpus_equivalence(name, buffering):
    p = _CORPUS[name](_CORPUS_N.get(name, 8), storage="bram")
    if name in _EXPECTED_UNLOWERABLE:
        with pytest.raises(UnlowerableProgram):
            lower_program(p, buffering=buffering, dtype="float64")
        return
    k = lower_program(p, buffering=buffering, dtype="float64")
    assert isinstance(k, PallasKernel) and k.outputs
    _exact(k, p)


def test_fig3_conv1d_unlowerable():
    """The flipped-kernel 1-D conv reads ``w[i + j]`` — a non-separable
    (two-iv) index codegen rejects with the access named in the reason."""
    with pytest.raises(UnlowerableProgram, match="non-separable"):
        lower_program(fig3_conv1d(), dtype="float64")


def test_streamed_mode_on_chains():
    """Every mismatched-bounds chain takes the streamed (line-buffer) path,
    with a grid and a positive halo on its fused intermediate."""
    for name, mk in CHAIN_BENCHMARKS.items():
        k = lower_program(mk(8, storage="bram"))
        assert k.mode == "streamed", (name, k.soft_reasons)
        assert k.grid and k.grid[0] >= 1
        assert all(h >= 0 for h in k.halo.values())


def test_partial_tile_padding():
    """Output rows not divisible by block_rows: the last grid step computes
    into edge-padded rows and the wrapper trims them."""
    p = blur_chain(10, "bram", 3)
    k = lower_program(p, block_rows=4, dtype="float64")
    assert k.grid == (3,)
    _exact(k, p)


@pytest.mark.parametrize("name", sorted(CHAIN_BENCHMARKS))
def test_double_vs_single_bitexact(name):
    """Buffering is a schedule choice, never a numerics choice: the double-
    and single-buffered lowerings agree bit-for-bit (float32)."""
    p = CHAIN_BENCHMARKS[name](12, storage="bram")
    kd = lower_program(p, buffering="double")
    ks = lower_program(p, buffering="single")
    inputs = sim.make_inputs(p, seed=1)
    od, os_ = kd(inputs, interpret=True), ks(inputs, interpret=True)
    for a in kd.outputs:
        assert np.array_equal(np.asarray(od[a]), np.asarray(os_[a])), (name, a)


def test_bad_buffering_rejected():
    with pytest.raises(ValueError, match="buffering"):
        lower_program(blur_chain(8, "bram"), buffering="triple")


# ---------------------------------------------------------------------------
# golden: generated blur chain == hand-written stencil_pipeline, bit-exact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("buffering", ["double", "single"])
def test_blur_golden_matches_handwritten(buffering):
    """The generated blur-chain kernel reproduces the hand-written
    ``kernels/stencil_pipeline.py`` (the golden reference it generalizes)
    bit-exactly: same taps, same block_rows/halo, float32 both sides."""
    from repro.kernels.stencil_pipeline import stencil_pipeline

    n, br, taps = 16, 8, 3
    p = blur_chain(n, "bram", taps)
    k = lower_program(p, block_rows=br, buffering=buffering)

    # blur_chain's conv weights: w_t = 1 / (2^|t - mid| + 1)
    w = np.asarray([1.0 / (2 ** abs(t - (taps - 1) // 2) + 1)
                    for t in range(taps)], np.float32)
    img = np.asarray(np.random.default_rng(7).uniform(
        0.5, 2.0, p.arrays["img"].shape), np.float32)

    import jax.numpy as jnp
    hand = stencil_pipeline(jnp.asarray(img), jnp.asarray(w), jnp.asarray(w),
                            block_rows=br, halo=taps - 1, interpret=True)

    inputs = {a: np.zeros(p.arrays[a].shape) for a in p.arrays}
    inputs["img"] = img.astype(np.float64)
    gen = k(inputs, interpret=True)[k.outputs[0]]
    assert np.asarray(gen).dtype == np.float32
    assert np.array_equal(np.asarray(gen), np.asarray(hand))


# ---------------------------------------------------------------------------
# property test: randomized fused/tiled chains ≡ sequential_exec
# ---------------------------------------------------------------------------


def _random_chain(rng: random.Random):
    """A random 2-stage producer-consumer chain: conv-like stage over img
    into bx, then a row-stencil stage into out — random sizes, taps, ops and
    weights; occasionally a strided store (exercising the whole-array
    fallback's scatter-free strided writes)."""
    n = rng.randint(4, 10)
    w = rng.randint(4, 8)
    t1, t2 = rng.randint(1, 3), rng.randint(1, 3)
    ct = rng.randint(1, 2)
    strided = rng.random() < 0.2
    b = ProgramBuilder(f"rand_chain_{n}x{w}")
    H1 = n + t2 - 1                       # bx rows stage2 consumes
    b.array("img", (H1 + t1 - 1, w + ct - 1),
            partition=(0,), ports=("w", "r", "r", "r"))
    b.array("bx", (H1, w), partition=(0,), ports=("w", "r", "r", "r"))
    out_shape = (n, 2 * w) if strided else (n, w)
    b.array("out", out_shape, partition=(0,), ports=("w", "r", "r", "r"),
            is_arg=True)
    fns = ["add", "mul", "min", "max", "sub"]

    def combine(vals):
        acc = vals[0]
        for v in vals[1:]:
            acc = b.arith(rng.choice(fns), acc, v)
        return acc

    with b.loop("pi", 0, H1) as i:
        with b.loop("pj", 0, w) as j:
            vals = [b.mul(b.load("img", i + a_, j + c_),
                          b.const(round(rng.uniform(0.25, 1.5), 3)))
                    for a_ in range(t1) for c_ in range(ct)]
            b.store("bx", combine(vals), i, j)
    with b.loop("ci", 0, n) as i:
        with b.loop("cj", 0, w) as j:
            vals = [b.mul(b.load("bx", i + a_, j),
                          b.const(round(rng.uniform(0.25, 1.5), 3)))
                    for a_ in range(t2)]
            if strided:
                b.store("out", combine(vals), i, 2 * j)
            else:
                b.store("out", combine(vals), i, j)
    return b.build()


@pytest.mark.parametrize("seed", range(27))
def test_property_random_chain(seed):
    """≥25 randomized fused/tiled chains: the kernel lowered from the
    original program with the pipeline's tile size must match the
    transformed program's executable semantics exactly (float64)."""
    rng = random.Random(1000 + seed)
    p = _random_chain(rng)
    passes = [Normalize()]
    if rng.random() < 0.7:
        passes.append(FuseProducerConsumer())
    bs = rng.choice([None, 2, 3, 4])
    if bs is not None:
        # positional form: tiles the top-level nests (post-fusion names)
        passes.append(LoopTile((bs,)))
    q = PassManager(passes, verify=True).run(p)
    k = lower_program(p, block_rows=bs,
                      buffering=rng.choice(["double", "single"]),
                      dtype="float64")
    inputs = sim.make_inputs(p, seed=seed)
    ref = sim.sequential_exec(q, inputs)
    with enable_x64():
        got = k(inputs, interpret=True)
    for a in k.outputs:
        np.testing.assert_allclose(np.asarray(got[a], np.float64), ref[a],
                                   rtol=1e-12, atol=0,
                                   err_msg=f"seed={seed} array={a} "
                                           f"mode={k.mode}")


# ---------------------------------------------------------------------------
# emit_pallas: CompileResult integration + structured rejection
# ---------------------------------------------------------------------------


def _compile_small(p):
    from repro.core import hls
    return hls.compile(
        p, objectives=("latency", "bram"),
        search=hls.SearchConfig(moves=("fuse", "tile"), unroll_factors=(),
                                tile_sizes=(2, 4), max_candidates=6))


def test_emit_pallas_from_compile_result():
    """emit_pallas defaults to the best point, picks block_rows off its tile
    pass, and carries the modeled latency + fusion shifts for the
    modeled-vs-measured loop."""
    p = blur_chain(12, "bram", 3)
    r = _compile_small(p)
    k = r.emit_pallas()
    assert k.modeled_latency == r.best.latency
    assert k.point_desc == r.best.desc
    _exact(lower_program(p, block_rows=k.block_rows, dtype="float64"), p)
    fused = [c for c in r.frontier if getattr(c.program, "_fusion_log", [])]
    if fused:
        kf = r.emit_pallas(fused[0])
        assert kf.fusion_shifts and kf.halo.get("bx", 0) >= 1


def test_emit_pallas_unlowerable_records_diagnostic():
    """An unlowerable program raises the structured CompileError subclass
    AND records a codegen-unlowerable diagnostic on the result."""
    from repro.core import CompileError

    p = fig3_conv1d()
    r = _compile_small(p)
    with pytest.raises(UnlowerableProgram, match="non-separable") as ei:
        r.emit_pallas()
    assert isinstance(ei.value, CompileError)
    assert ei.value.reasons
    assert [v.code for v in ei.value.violations] == ["non-separable"]
    ds = [d for d in r.diagnostics if d.get("kind") == "codegen-unlowerable"]
    assert (ds and ds[0]["program"] == "fig3_conv1d" and ds[0]["reasons"]
            and ds[0]["codes"] == ["non-separable"])


def test_unlowerable_reduction_reason():
    """A nest reading the array it writes (a true reduction) is rejected
    with a reason naming the recurrence, not an opaque failure."""
    b = ProgramBuilder("running_sum")
    b.array("x", (8, 4), partition=(0,), ports=("w", "r"))
    b.array("acc", (8, 4), partition=(0,), ports=("w", "r"), is_arg=True)
    with b.loop("i", 0, 7) as i:
        with b.loop("j", 0, 4) as j:
            b.store("acc", b.add(b.load("acc", i, j), b.load("x", i, j)),
                    i + 1, j)
    with pytest.raises(UnlowerableProgram):
        lower_program(b.build())


def test_kernel_source_is_the_artifact():
    """The emitted source is a self-contained module: it exec's standalone
    and exposes the same run() the kernel wraps."""
    p = blur_chain(8, "bram", 3)
    k = lower_program(p)
    assert "pl.pallas_call" in k.source and "def run(" in k.source
    ns = {}
    exec(compile(k.source, "<re-exec>", "exec"), ns)
    inputs = sim.make_inputs(p, seed=2)
    a = k.outputs[0]
    assert np.array_equal(
        np.asarray(ns["run"](inputs, interpret=True)[a]),
        np.asarray(k(inputs, interpret=True)[a]))

"""Reduced-size versions of the paper's five benchmarks: schedule validity,
functional equivalence, and the qualitative paper claims."""
import numpy as np
import pytest

from repro.core.autotune import compile_program
from repro.core.dataflow import (analyze_dataflow, to_spsc,
                                 vitis_dataflow_latency)
from repro.core.programs import BENCHMARKS, dus, harris, two_mm, unsharp
from repro.core.sim import (make_inputs, sequential_exec, timed_exec,
                            validate_schedule)


@pytest.mark.parametrize("name", ["unsharp", "dus", "two_mm"])
def test_benchmark_small_functional(name):
    p = BENCHMARKS[name](8)
    s = compile_program(p)
    assert s.feasible
    assert validate_schedule(p, s) == []
    inp = make_inputs(p, 0)
    got, want = timed_exec(p, s, inp), sequential_exec(p, inp)
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-12, err_msg=k)


def test_benchmark_overlap_speedup_band():
    """Producer-consumer pipelining must actually help (paper: 1.7-3.7x)."""
    for name in ("unsharp", "dus"):
        p = BENCHMARKS[name](16)
        s = compile_program(p)
        speedup = s.sequential_nests_latency() / s.completion_time()
        assert speedup > 1.5, (name, speedup)


def test_dus_defeats_vitis_dataflow():
    """Paper §5.2: every DUS channel is window-read -> ping-pong -> Vitis
    dataflow gives no intra-invocation overlap; ours still overlaps."""
    p = dus(16)
    s = compile_program(p)
    info = analyze_dataflow(p)
    assert info.applicable
    assert all(c.kind == "pingpong" for c in info.channels)
    lat, _ = vitis_dataflow_latency(p, s)
    assert lat == s.sequential_nests_latency()      # no gain for Vitis
    assert s.completion_time() < lat                 # ours overlaps


def test_2mm_dataflow_inapplicable():
    """Paper §5.2: 2mm writes the intermediate to a function argument."""
    p = two_mm(4)
    info = analyze_dataflow(p)
    assert not info.applicable
    assert "tmp" in info.reason


def test_unsharp_non_spsc_and_conversion():
    p = unsharp(8)
    info = analyze_dataflow(p)
    assert not info.applicable          # img/by have multiple consumers
    sp = to_spsc(p)
    info2 = analyze_dataflow(sp)
    assert info2.applicable
    # conversion must preserve semantics
    s = compile_program(sp)
    inp = make_inputs(sp, 2)
    got, want = timed_exec(sp, s, inp), sequential_exec(sp, inp)
    np.testing.assert_allclose(got["out"], want["out"], rtol=1e-12)


def test_spsc_pointwise_chain_is_fifo():
    sp = to_spsc(unsharp(8))
    info = analyze_dataflow(sp)
    kinds = dict((c.array, c.kind) for c in info.channels)
    assert kinds["sharp"] == "fifo"     # pointwise producer/consumer
    assert kinds["bx"] == "pingpong"    # window read breaks FIFO order


def test_harris_small():
    p = harris(6)
    s = compile_program(p)
    assert validate_schedule(p, s) == []
    inp = make_inputs(p, 1)
    got, want = timed_exec(p, s, inp), sequential_exec(p, inp)
    np.testing.assert_allclose(got["R"], want["R"], rtol=1e-12)
    assert s.completion_time() < s.sequential_nests_latency()

"""Chunked (flash-style) attention must match dense attention exactly."""
import dataclasses

import jax
import numpy as np

from repro.config import get_config, ShapeConfig
from repro.models import api, lm

SHAPE = ShapeConfig("t", "train", 64, 2)


def _logits(cfg, params, batch):
    return lm.forward(cfg, params, batch)


def test_chunked_equals_dense():
    base = dataclasses.replace(get_config("llama3_8b", reduced=True),
                               dtype="float32")
    params = lm.init_params(base, jax.random.key(0))
    batch = api.make_batch(base, SHAPE, seed=0)
    dense = _logits(base, params, batch)
    for chunk in (16, 32, 64):
        cfg = dataclasses.replace(base, attn_impl="chunked", attn_chunk=chunk)
        got = _logits(cfg, params, batch)
        np.testing.assert_allclose(np.asarray(got), np.asarray(dense),
                                   rtol=2e-4, atol=2e-4, err_msg=str(chunk))


def test_chunked_grads_match():
    base = dataclasses.replace(get_config("llama3_8b", reduced=True),
                               dtype="float32")
    params = lm.init_params(base, jax.random.key(1))
    batch = api.make_batch(base, SHAPE, seed=1)
    gd = jax.grad(lambda p: lm.loss_fn(base, p, batch))(params)
    cfg = dataclasses.replace(base, attn_impl="chunked", attn_chunk=32)
    gc = jax.grad(lambda p: lm.loss_fn(cfg, p, batch))(params)
    for a, b in zip(jax.tree.leaves(gd), jax.tree.leaves(gc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=1e-5)

"""MoE layer vs a brute-force numpy oracle, including capacity dropping and
position-in-expert assignment order (the invariants the sort-based dispatch
must preserve)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ArchConfig, MoEConfig
from repro.models import layers as L


def _cfg(E=4, K=2, cf=1.0):
    return ArchConfig(
        name="t", family="moe", n_layers=1, d_model=16, n_heads=2,
        n_kv_heads=2, head_dim=8, d_ff=32, vocab=64, dtype="float32",
        moe=MoEConfig(n_experts=E, top_k=K, d_ff=8, capacity_factor=cf))


def _oracle(cfg, p, x):
    """Sequential-scan-order dispatch with capacity, in numpy."""
    G, Tg, D = x.shape
    mc = cfg.moe
    E, K = mc.n_experts, mc.top_k
    C = max(1, int(Tg * K * mc.capacity_factor / E))
    h = np.asarray(L.rms_norm(jnp.asarray(x), p["norm"], cfg.norm_eps))
    logits = h.astype(np.float32) @ np.asarray(p["router"])
    gates = np.exp(logits - logits.max(-1, keepdims=True))
    gates = gates / gates.sum(-1, keepdims=True)
    out = np.zeros_like(x)
    wg, wu, wd = (np.asarray(p[k]) for k in ("w_gate", "w_up", "w_down"))
    for g in range(G):
        counts = np.zeros(E, np.int64)
        for t in range(Tg):
            idx = np.argsort(-gates[g, t])[:K]
            val = gates[g, t, idx]
            val = val / (val.sum() + 1e-9)
            for k in range(K):
                e = idx[k]
                if counts[e] >= C:
                    counts[e] += 1
                    continue
                counts[e] += 1
                hin = h[g, t]
                silu = lambda v: v / (1 + np.exp(-v))
                mid = silu(hin @ wg[e]) * (hin @ wu[e])
                out[g, t] += val[k] * (mid @ wd[e])
    return x + out


def test_moe_matches_oracle_with_drops():
    cfg = _cfg(E=4, K=2, cf=0.75)  # deliberately tight capacity
    p = L.init_moe(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 12, cfg.d_model))
    got = np.asarray(L.moe_forward(cfg, p, x))
    want = _oracle(cfg, p, x)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_moe_matches_oracle_no_drops():
    cfg = _cfg(E=4, K=2, cf=4.0)
    p = L.init_moe(cfg, jax.random.key(2))
    x = jax.random.normal(jax.random.key(3), (1, 8, cfg.d_model))
    got = np.asarray(L.moe_forward(cfg, p, x))
    want = _oracle(cfg, p, x)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_moe_shared_expert():
    cfg = _cfg(E=4, K=2, cf=4.0)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_shared=1))
    p = L.init_moe(cfg, jax.random.key(4))
    x = jax.random.normal(jax.random.key(5), (1, 8, cfg.d_model))
    got = L.moe_forward(cfg, p, x)
    assert jnp.isfinite(got).all()
    # shared expert contributes: zeroing it changes the output
    p2 = jax.tree.map(jnp.zeros_like, p["shared"])
    got2 = L.moe_forward(cfg, {**p, "shared": p2}, x)
    assert float(jnp.max(jnp.abs(got - got2))) > 1e-6

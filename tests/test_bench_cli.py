"""benchmarks/run.py CLI: suite names are validated up front.

The old ``only = sys.argv[1]`` filter silently ran *nothing* on a typo'd
suite name; argparse now rejects unknown names with a hard error."""
import pytest

from benchmarks.run import SUITES, main


def test_unknown_suite_is_hard_error(capsys):
    with pytest.raises(SystemExit) as ei:
        main(["definitely-not-a-suite"])
    assert ei.value.code == 2
    assert "invalid choice" in capsys.readouterr().err


def test_suites_cover_known_sections():
    for s in ("paper", "dse", "pareto", "dse-perf", "faults", "fusion",
              "codegen", "trace", "kernels"):
        assert s in SUITES


def test_help_lists_suites(capsys):
    with pytest.raises(SystemExit) as ei:
        main(["--help"])
    assert ei.value.code == 0
    assert "codegen" in capsys.readouterr().out

"""Multi-device behaviour, run in a SUBPROCESS with 8 host-platform devices
so the main pytest process keeps seeing exactly 1 CPU device (required by
the smoke tests and the dry-run isolation rules)."""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P

assert len(jax.devices()) == 8

# ---- 1. pipelined forward/backward == reference --------------------------
from repro.parallel.pipeline import (pipelined_forward, pipelined_loss,
                                     reference_forward)
S, M, D = 8, 12, 32
mesh = jax.make_mesh((S,), ("stage",))
key = jax.random.key(0)
params = {"w": jax.random.normal(key, (S, D, D)) * D ** -0.5,
          "b": jnp.zeros((S, D))}
def stage_fn(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])
mbs = jax.random.normal(jax.random.key(1), (M, 4, D))
out = pipelined_forward(stage_fn, params, mbs, mesh, "stage")
ref = reference_forward(stage_fn, params, mbs)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                           atol=2e-5)
tgt = jnp.zeros_like(ref)
g = jax.grad(lambda p: pipelined_loss(stage_fn, p, mbs, tgt, mesh, "stage"))(params)
gr = jax.grad(lambda p: jnp.mean(jnp.square(reference_forward(stage_fn, p, mbs) - tgt)))(params)
np.testing.assert_allclose(np.asarray(g["w"]), np.asarray(gr["w"]),
                           rtol=2e-4, atol=2e-5)
print("pipeline OK")

# ---- 2. ring all-gather matmul == plain matmul ----------------------------
from repro.parallel.collective_matmul import ag_matmul
mesh2 = jax.make_mesh((8,), ("model",))
x = jax.random.normal(jax.random.key(2), (32, 16))
w = jax.random.normal(jax.random.key(3), (16, 24))
y = ag_matmul(x, w, mesh2, "model")
np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-4,
                           atol=1e-4)
print("ag_matmul OK")

# ---- 3. int8 compressed gradient psum ------------------------------------
from repro.parallel.compression import compressed_psum
from jax.experimental.shard_map import shard_map
mesh3 = jax.make_mesh((8,), ("data",))
grads = {"w": jax.random.normal(jax.random.key(4), (8, 64)) * 0.1}
def red(g):
    return compressed_psum(jax.tree.map(lambda x: x[0], g), "data",
                           jax.random.key(0))
out = shard_map(red, mesh=mesh3, in_specs=({"w": P("data")},),
                out_specs={"w": P()}, check_rep=False)(grads)
want = jnp.mean(grads["w"], axis=0)
err = jnp.max(jnp.abs(out["w"] - want)) / (jnp.max(jnp.abs(want)) + 1e-9)
assert err < 0.02, f"int8 psum relative error {err}"
print("compression OK")

# ---- 4. per-arch sharded train step really runs on 8 devices -------------
import dataclasses
from repro.config import get_config, ShapeConfig
from repro.launch import steps as steps_mod
from repro.models import lm, api
from repro.optim import adamw_init
from repro.parallel.sharding import ctx_mesh
from jax.sharding import NamedSharding
mesh4 = jax.make_mesh((4, 2), ("data", "model"))
cfg = dataclasses.replace(get_config("llama3_8b", reduced=True), dtype="float32")
shape = ShapeConfig("t", "train", 32, 8)
fn, in_sh, out_sh, _ = steps_mod.build(cfg, shape, mesh4)
def named(t):
    return jax.tree.map(lambda s: NamedSharding(mesh4, s) if isinstance(s, P) else s,
                        t, is_leaf=lambda x: isinstance(x, P) or x is None)
with ctx_mesh(mesh4):
    js = jax.jit(fn, in_shardings=named(in_sh), out_shardings=named(out_sh))
    params = lm.init_params(cfg, jax.random.key(0))
    opt = adamw_init(params)
    batch = api.make_batch(cfg, shape, seed=0)
    batch["mask"] = jnp.ones_like(batch["labels"], jnp.float32)
    p2, o2, m = js(params, opt, batch)
    assert jnp.isfinite(m["loss"])
print("sharded train step OK")

# ---- 5. production meshes construct (512 devices not needed: shape math) --
print("ALL-OK")
"""


@pytest.mark.slow
def test_multidevice_suite():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], cwd="/root/repo",
                       env=env, capture_output=True, text=True, timeout=900)
    assert "ALL-OK" in r.stdout, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"

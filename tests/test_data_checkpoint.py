"""Data-pipeline determinism + checkpoint round-trip / elastic restore."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (latest_step, restore_checkpoint,
                              save_checkpoint, AsyncCheckpointer)
from repro.data import SyntheticLMData, make_train_iterator


def test_data_deterministic_per_step():
    ds = SyntheticLMData(vocab=100, seq_len=32, batch=4, seed=3)
    a = ds.batch_at(17)
    b = ds.batch_at(17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch_at(18)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # different hosts draw different data
    ds2 = SyntheticLMData(vocab=100, seq_len=32, batch=4, seed=3, host_id=1)
    assert not np.array_equal(a["tokens"], ds2.batch_at(17)["tokens"])


def test_iterator_resumes_mid_stream():
    ds = SyntheticLMData(vocab=100, seq_len=16, batch=2, seed=0)
    it = make_train_iterator(ds, start_step=5)
    step, batch = next(it)
    it.close()
    assert step == 5
    np.testing.assert_array_equal(batch["tokens"], ds.batch_at(5)["tokens"])


def test_labels_are_shifted_tokens():
    ds = SyntheticLMData(vocab=100, seq_len=16, batch=2, seed=1)
    b = ds.batch_at(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": [jnp.ones((4,), jnp.int32), jnp.zeros(())]}
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    out = restore_checkpoint(str(tmp_path), 7, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_prunes_old(tmp_path):
    tree = {"a": jnp.zeros(2)}
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, tree)
    import os
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 3 and kept[-1].endswith("00000005")


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    ck.save(1, {"x": jnp.ones(3)})
    ck.save(2, {"x": jnp.ones(3) * 2})  # waits for the first
    ck.wait()
    assert latest_step(str(tmp_path)) == 2
    out = restore_checkpoint(str(tmp_path), 2, {"x": jnp.zeros(3)})
    np.testing.assert_allclose(np.asarray(out["x"]), 2.0)


def test_elastic_restore_respects_new_sharding(tmp_path):
    """Restore with explicit (single-device) shardings — the elastic path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(8.0)}
    save_checkpoint(str(tmp_path), 3, tree)
    sh = {"w": NamedSharding(mesh, P("data"))}
    out = restore_checkpoint(str(tmp_path), 3, tree, shardings=sh)
    assert out["w"].sharding == sh["w"]

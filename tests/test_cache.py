"""Persistent compile cache + serving-scale DSE (DESIGN.md §8).

Covers, per the serving-scale-DSE acceptance criteria:

  * fingerprint sensitivity: any program / pipeline / mode / salt mutation
    changes the key (property over randomized programs), while rebuilding
    the same program (fresh uids) does not;
  * positional schedule round-trip onto a structurally identical program
    with different uids;
  * cold-vs-warm byte identity of candidates, schedules and whole
    frontiers, in-process and against a store written by this process;
  * corrupted and stale (salt-mismatch) entries are detected, discarded,
    and transparently recompiled;
  * concurrent writers never corrupt the store (atomic replace);
  * LRU eviction bounds the store;
  * parallel (jobs=2) expansion is bit-identical to serial;
  * macro-moves reach the blur_chain fuse+tile frontier point in strictly
    fewer compiles than the classic max_candidates=24 search;
  * the hypervolume selector is deterministic and exact on knowns;
  * deps.cache_stats() exposes the bounded data-pair cache counters.
"""
import json
import multiprocessing
import os

import pytest

from repro.core import deps, hls
from repro.core.autotune import (_hv, measure_candidate, pareto_explore)
from repro.core.cache import (CacheStore, SCHEDULER_SALT, fingerprint,
                              get_store, pack_schedule, program_text,
                              string_key, unpack_schedule)
from repro.core.autotune import compile_program
from repro.core.programs import blur_chain, conv_pool, two_mm
from repro.core.transforms import Normalize
from test_property import random_program


@pytest.fixture
def store(tmp_path, monkeypatch):
    """A fresh persistent store in a tmpdir, with the global cache enabled
    for this test only (the suite-wide conftest default is off)."""
    monkeypatch.setenv("REPRO_HLS_CACHE", "1")
    monkeypatch.setenv("REPRO_HLS_CACHE_DIR", str(tmp_path / "cache"))
    st = get_store()
    assert st is not None
    return st


def _explore(p, store, **kw):
    kw.setdefault("rel_caps", {"bram_bytes": 1.0, "dsp": 1.0})
    kw.setdefault("max_candidates", 12)
    return pareto_explore(p, store=store, **kw)


def _result_sig(r):
    """Everything observable about a ParetoResult, schedules included."""
    def cand(c):
        return (c.desc, c.latency, dict(c.res), c.status, c.within_budget,
                sorted(c.schedule.iis.values()),
                sorted(c.schedule.theta.values()))
    return ([cand(c) for c in r.candidates], [cand(c) for c in r.frontier],
            r.rejected, r.caps, r.compiles)


# ---------------------------------------------------------------------------
# Fingerprint sensitivity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
def test_fingerprint_stable_across_rebuilds(seed):
    """Rebuilding the same program (fresh process-local uids) yields the
    same fingerprint — the property that makes cross-process reuse work."""
    a, b = random_program(seed), random_program(seed)
    assert program_text(a) == program_text(b)
    assert fingerprint(a) == fingerprint(b)


def test_fingerprint_distinguishes_random_programs():
    keys = [fingerprint(random_program(s)) for s in range(10)]
    assert len(set(keys)) == len(keys)


def test_fingerprint_sensitive_to_every_input():
    p = blur_chain(8, storage="bram")
    base = fingerprint(p)
    # pipeline text, resource mode, salt, caller-extra all key separately
    assert fingerprint(p, pipeline="fuse") != base
    assert fingerprint(p, mode="vitis_seq") != base
    assert fingerprint(p, salt="other-compiler-version") != base
    assert fingerprint(p, extra="frontier") != base
    # program mutations: bounds, pragmas, array metadata, op latencies
    q = blur_chain(8, storage="bram")
    q.body[0].ub += 1
    assert fingerprint(q) != base
    q2 = blur_chain(8, storage="bram")
    q2.body[0].ii = 3
    assert fingerprint(q2) != base
    assert fingerprint(blur_chain(8, storage="reg")) != base
    assert fingerprint(blur_chain(16, storage="bram")) != base
    q3 = blur_chain(8, storage="bram")
    q3.op_delays = dict(q3.op_delays, mul=7)
    assert fingerprint(q3) != base


# ---------------------------------------------------------------------------
# Positional schedule round-trip
# ---------------------------------------------------------------------------


def test_schedule_roundtrip_across_rebuild():
    p = two_mm(4)
    s = compile_program(p)
    blob = json.loads(json.dumps(pack_schedule(s)))   # through JSON, as disk
    q = two_mm(4)                                     # fresh uids
    s2 = unpack_schedule(q, blob)
    assert s2.feasible
    assert sorted(s.iis.values()) == sorted(s2.iis.values())
    assert sorted(s.theta.values()) == sorted(s2.theta.values())
    assert s.completion_time() == s2.completion_time()
    assert len(s.edges) == len(s2.edges)


def test_schedule_unpack_rejects_mismatched_program():
    s = compile_program(two_mm(4))
    blob = pack_schedule(s)
    with pytest.raises(ValueError):
        unpack_schedule(blur_chain(8), blob)


# ---------------------------------------------------------------------------
# Cold vs warm byte identity
# ---------------------------------------------------------------------------


def test_cold_warm_identity_same_process(store):
    cold = _explore(blur_chain(8, storage="bram"), store)
    warm = _explore(blur_chain(8, storage="bram"), store)
    assert _result_sig(cold) == _result_sig(warm)
    assert not any(c.cached for c in cold.candidates)
    assert all(c.cached for c in warm.candidates)


def test_cold_warm_identity_fresh_store_view(store):
    """A second CacheStore over the same directory (simulating a new
    process: empty in-memory layer, different uids via rebuild) serves the
    identical frontier from disk."""
    cold = _explore(conv_pool(8, storage="bram"), store)
    fresh = CacheStore(store.root)
    warm = _explore(conv_pool(8, storage="bram"), fresh)
    assert _result_sig(cold) == _result_sig(warm)
    assert all(c.cached for c in warm.candidates)
    assert fresh.hits >= 1 and fresh.puts == 0


def test_candidate_noop_is_cached(store):
    p = blur_chain(8)
    assert measure_candidate(p, "normalize", [Normalize()], store=store) is None
    misses = store.misses
    assert measure_candidate(p, "normalize", [Normalize()], store=store) is None
    assert store.misses == misses          # served from the cache


def test_explain_reports_cache_hits(store, monkeypatch):
    p = blur_chain(8, storage="bram")
    sc = hls.SearchConfig(max_candidates=6, unroll_factors=(2,),
                          tile_sizes=(2,))
    cold = hls.compile(p, search=sc)
    warm = hls.compile(blur_chain(8, storage="bram"), search=sc)
    assert "{cache hit}" not in cold.explain()
    assert "{cache hit}" in warm.explain()
    assert [c.desc for c in warm.frontier] == [c.desc for c in cold.frontier]


def test_unverified_entries_do_not_serve_verified_requests(store):
    p = two_mm(4)
    r1 = _explore(p, store, verify=False)
    r2 = _explore(two_mm(4), store, verify=True)    # must NOT reuse
    assert not any(c.cached for c in r2.candidates)
    r3 = _explore(two_mm(4), store, verify=True)    # now it may
    assert all(c.cached for c in r3.candidates)
    assert _result_sig(r1) == _result_sig(r2) == _result_sig(r3)


# ---------------------------------------------------------------------------
# Corruption / staleness
# ---------------------------------------------------------------------------


def _entry_files(root):
    return [os.path.join(d, f) for d, _, fs in os.walk(root) for f in fs
            if f.endswith(".json")]


def test_corrupt_entries_are_discarded_and_recompiled(store):
    cold = _explore(blur_chain(8, storage="bram"), store)
    files = _entry_files(store.root)
    assert files
    for path in files:
        with open(path, "w") as f:
            f.write('{"truncated": ')
    fresh = CacheStore(store.root)
    again = _explore(blur_chain(8, storage="bram"), fresh)
    assert _result_sig(cold) == _result_sig(again)
    assert not any(c.cached for c in again.candidates)
    assert fresh.misses > 0 and fresh.hits == 0


def test_salt_mismatch_invalidates(store):
    """Entries written by a different compiler version (salt) are stale by
    definition: detected, deleted, recompiled."""
    cold = _explore(blur_chain(8, storage="bram"), store)
    old = CacheStore(store.root, salt="repro-hls-ancient")
    again = _explore(blur_chain(8, storage="bram"), old)
    assert _result_sig(cold) == _result_sig(again)
    assert not any(c.cached for c in again.candidates)
    # and the store now serves the NEW salt's entries
    warm = _explore(blur_chain(8, storage="bram"),
                    CacheStore(store.root, salt="repro-hls-ancient"))
    assert all(c.cached for c in warm.candidates)


# ---------------------------------------------------------------------------
# Concurrent writers / eviction
# ---------------------------------------------------------------------------


def _hammer_store(args):
    root, wid = args
    st = CacheStore(root)
    for i in range(40):
        st.put(string_key("contended", str(i % 8)),
               {"writer": wid, "i": i, "pad": "x" * 256})
    return wid


def test_concurrent_writers_do_not_corrupt(store):
    with multiprocessing.Pool(4) as pool:
        pool.map(_hammer_store, [(store.root, w) for w in range(4)])
    # every surviving file is a complete, valid wrapper (atomic replace:
    # last writer wins, torn writes are impossible)
    files = _entry_files(store.root)
    assert len(files) == 8
    for path in files:
        with open(path) as f:
            wrapper = json.load(f)
        assert wrapper["salt"] == SCHEDULER_SALT
        assert wrapper["data"]["i"] >= 0
    fresh = CacheStore(store.root)
    for i in range(8):
        assert fresh.get(string_key("contended", str(i))) is not None


def test_lru_eviction_bounds_the_store(tmp_path):
    st = CacheStore(str(tmp_path / "c"), max_entries=8)
    for i in range(40):
        st.put(string_key("evict", str(i)), {"i": i})
    st.sweep()
    assert len(_entry_files(st.root)) <= 8
    assert st.evictions >= 32


# ---------------------------------------------------------------------------
# Parallel expansion determinism
# ---------------------------------------------------------------------------


def test_parallel_bit_identical_to_serial():
    for make in (blur_chain, conv_pool):
        serial = pareto_explore(make(8), rel_caps={"bram_bytes": 1.5,
                                                   "dsp": 4.0},
                                max_candidates=10, store=None)
        par = pareto_explore(make(8), rel_caps={"bram_bytes": 1.5,
                                                "dsp": 4.0},
                             max_candidates=10, store=None, jobs=2)
        assert _result_sig(serial) == _result_sig(par)


def test_parallel_with_cache_interplay(store):
    cold = _explore(blur_chain(8, storage="bram"), store, jobs=2)
    warm = _explore(blur_chain(8, storage="bram"), store, jobs=2)
    assert _result_sig(cold) == _result_sig(warm)
    assert all(c.cached for c in warm.candidates)


# ---------------------------------------------------------------------------
# Macro-moves + hypervolume selector
# ---------------------------------------------------------------------------


def test_macro_moves_reach_fuse_tile_in_fewer_compiles():
    """Acceptance: the composite fuse>tile step reaches the blur_chain
    fuse+tile frontier point in strictly fewer compiles than the classic
    one-move-at-a-time max_candidates=24 search."""
    caps = {"bram_bytes": 1.0, "dsp": 1.0}
    classic = pareto_explore(blur_chain(8), rel_caps=caps,
                             max_candidates=24, store=None)
    assert any("fuse" in c.desc and "tile" in c.desc
               for c in classic.frontier)
    macro = pareto_explore(blur_chain(8), rel_caps=caps, max_candidates=6,
                           macro_moves=True, store=None)
    assert any(c.desc.startswith("fuse>tile") for c in macro.frontier)
    assert macro.compiles < classic.compiles
    # the macro point matches the classic fuse|tile point exactly
    classic_pt = next(c for c in classic.frontier
                      if "fuse" in c.desc and "tile" in c.desc)
    macro_pt = next(c for c in macro.frontier
                    if c.desc.startswith("fuse>tile"))
    assert macro_pt.objectives() == classic_pt.objectives()


def test_hv_selector_deterministic():
    kw = dict(rel_caps={"bram_bytes": 1.5, "dsp": 4.0}, max_candidates=10,
              selector="hv", macro_moves=True, store=None)
    a = pareto_explore(blur_chain(8), **kw)
    b = pareto_explore(blur_chain(8), **kw)
    assert _result_sig(a) == _result_sig(b)
    from repro.core.autotune import dominates
    for c in a.frontier:
        assert not any(dominates(d.objectives(), c.objectives())
                       for d in a.frontier if d is not c)


def test_hv_exact_on_knowns():
    # two staircase points, union of boxes = 3.0
    assert _hv([(0.0, 1.0), (1.0, 0.0)], (2.0, 2.0)) == pytest.approx(3.0)
    # dominated point adds nothing
    assert _hv([(0.0, 1.0), (1.0, 0.0), (1.0, 1.0)],
               (2.0, 2.0)) == pytest.approx(3.0)
    # point outside the reference contributes nothing
    assert _hv([(3.0, 3.0)], (2.0, 2.0)) == 0.0
    # 3D sanity: single point
    assert _hv([(0.5, 0.5, 0.5)], (1.0, 1.0, 1.0)) == pytest.approx(0.125)


def test_unknown_selector_rejected():
    with pytest.raises(ValueError, match="unknown selector"):
        pareto_explore(two_mm(4), selector="random", store=None)


# ---------------------------------------------------------------------------
# deps data-pair cache stats
# ---------------------------------------------------------------------------


def test_deps_cache_stats_counters():
    stats0 = deps.cache_stats()
    assert stats0["max_entries"] == 64
    p = blur_chain(8)
    deps.DepAnalysis(p)
    mid = deps.cache_stats()
    deps.DepAnalysis(p)   # same uids + spaces: served from the shared cache
    after = deps.cache_stats()
    assert after["hits"] >= mid["hits"] + 1
    assert after["misses"] == mid["misses"]
    assert after["entries"] <= after["max_entries"]


# ---------------------------------------------------------------------------
# Kill switch
# ---------------------------------------------------------------------------


def test_cache_disabled_by_env(monkeypatch):
    monkeypatch.setenv("REPRO_HLS_CACHE", "0")
    assert get_store() is None

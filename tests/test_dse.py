"""Resource-aware DSE driver (autotune.explore, DESIGN.md §6).

The acceptance demo: for real benchmarks, ``explore`` must find a
transformed program whose scheduled latency beats the untransformed
``compile_program`` schedule at equal-or-lower BRAM/DSP, and the winner
must pass the brute-force schedule validator + timed-execution oracle
(``validate=True`` asserts both inside explore).
"""
import pytest

from repro.core.api import explore
from repro.core.autotune import compile_program
from repro.core.programs import harris, two_mm, unsharp


@pytest.mark.parametrize("mk,n", [(two_mm, 6), (harris, 6)])
def test_explore_beats_baseline_iso_resources(mk, n):
    p = mk(n, storage="bram")
    r = explore(p, verify=True, validate=True, max_candidates=8,
                unroll_factors=(2,), tile_sizes=())
    assert r.best.latency < r.baseline.latency, (r.best.desc, r.best.latency)
    assert r.best.res["bram_bytes"] <= r.baseline.res["bram_bytes"] + 1e-9
    assert r.best.res["dsp"] <= r.baseline.res["dsp"] + 1e-9
    assert r.best.within_budget
    assert r.speedup > 1.0


def test_explore_default_budget_is_iso_resource():
    p = two_mm(4)
    r = explore(p, verify=True, max_candidates=4, unroll_factors=(),
                tile_sizes=())
    assert r.budget == {"bram_bytes": r.baseline.res["bram_bytes"],
                        "dsp": r.baseline.res["dsp"]}
    for c in r.candidates:
        assert c.within_budget == all(
            c.res[k] <= v + 1e-9 for k, v in r.budget.items())


def test_explore_budget_gates_unroll():
    """Unrolling doubles datapath DSPs: it must be flagged over-budget under
    the iso-resource budget, but become eligible when the budget allows."""
    p = unsharp(8, storage="bram")
    iso = explore(p, max_candidates=6, unroll_factors=(2,), tile_sizes=())
    unrolled = [c for c in iso.candidates if "unroll" in c.desc]
    assert unrolled and all(not c.within_budget for c in unrolled)
    assert iso.best.within_budget

    roomy = explore(p, budget={"dsp": 1e9, "bram_bytes": 1e9},
                    max_candidates=6, unroll_factors=(2,), tile_sizes=())
    unrolled = [c for c in roomy.candidates if "unroll" in c.desc]
    assert unrolled and all(c.within_budget for c in unrolled)


def test_explore_baseline_matches_compile_program():
    p = two_mm(4)
    r = explore(p, max_candidates=2, unroll_factors=(), tile_sizes=())
    assert r.baseline.latency == compile_program(p).completion_time()
    assert r.best.latency <= r.baseline.latency


def test_explore_enumerates_shifted_fusion():
    """On a mismatched-bounds chain the DSE must enumerate (and here win
    with) a shift-and-peel fused candidate under the iso-resource budget."""
    from repro.core.programs import blur_chain
    p = blur_chain(8, storage="bram")
    r = explore(p, verify=True, validate=True, max_candidates=8,
                unroll_factors=(), tile_sizes=())
    fused = [c for c in r.candidates if getattr(c.program, "_fusion_log", [])]
    assert fused, "no shifted-fusion candidate enumerated"
    best_fused = min(fused, key=lambda c: c.latency)
    assert best_fused.program._fusion_log[0]["shift"] == [2, 0]
    assert best_fused.within_budget
    assert best_fused.latency < r.baseline.latency
    assert r.best.latency <= best_fused.latency


def test_tiling_is_not_resource_neutral():
    """The tile-window footprint term (DESIGN.md §6): a nest-local
    intermediate of an explicitly tiled nest is costed at its streamed
    window, so (a) tiling changes the resource vector at all, (b) different
    tile sizes cost differently — the knob the DSE uses to pick block_rows
    for real."""
    from repro.core.dataflow import resources, tile_window_elems
    from repro.core.programs import blur_chain
    from repro.core.transforms import (FuseProducerConsumer, LoopTile,
                                       PassManager)
    from repro.core.autotune import compile_program

    p = blur_chain(8, storage="bram")
    fused = PassManager([FuseProducerConsumer()], verify=True).run(p)
    core_iv = next(it.ivname for it in fused.body if not it.peel)
    r_untiled = resources(fused, compile_program(fused), "ours")
    by_size = {}
    for s in (2, 4):
        q = PassManager([LoopTile({core_iv: s})], verify=True).run(fused)
        # window = (block + halo) rows x full width; halo = taps - 1 = 2
        assert tile_window_elems(q) == {"bx": (s + 2) * 8}
        by_size[s] = resources(q, compile_program(q), "ours")
    assert by_size[2] != r_untiled and by_size[4] != r_untiled
    assert by_size[2] != by_size[4]
    assert by_size[2]["bram_bytes"] < by_size[4]["bram_bytes"] \
        < r_untiled["bram_bytes"]
    # untiled programs are untouched by the footprint term
    assert tile_window_elems(p) == {}


def test_frontier_point_differs_by_tile_size():
    """At least one Pareto frontier point must differ from another by its
    tile size (the ISSUE acceptance for the VMEM/BRAM footprint term), and
    the tiled point must be strictly cheaper in BRAM."""
    from repro.core import hls
    from repro.core.programs import blur_chain
    from repro.core.transforms import LoopTile

    p = blur_chain(8, storage="bram")
    r = hls.compile(p, search=hls.SearchConfig(
        moves=("fuse", "tile"), unroll_factors=(), tile_sizes=(2, 4),
        max_candidates=8))

    def tile_sizes_of(c):
        out = []
        for ps in c.passes:
            if isinstance(ps, LoopTile):
                out += list(ps.seq or ps.sizes.values())
        return tuple(out)

    tiled = [c for c in r.frontier if tile_sizes_of(c)]
    untiled = [c for c in r.frontier if not tile_sizes_of(c)]
    assert tiled and untiled, [c.desc for c in r.frontier]
    assert min(c.res["bram_bytes"] for c in tiled) < \
        min(c.res["bram_bytes"] for c in untiled)
    # the stencil kernel config reads its block_rows off this exact knob,
    # via the knee point's generated kernel (emit_pallas)
    from repro.kernels.stencil_pipeline import (_stencil_codegen_config,
                                                stencil_config_source)
    block_rows, halo = _stencil_codegen_config()
    assert stencil_config_source() == "dse"
    assert halo == 2
    assert block_rows in tile_sizes_of(r.knee("latency", "bram",
                                              among=tiled))


def test_metadata_only_candidates_share_pair_enumeration():
    """ArrayPartition only rewrites array metadata: a DepAnalysis over the
    partitioned clone must reuse the original's data-dependence pair
    enumeration (probed via the module call counter) — while a transform
    that changes the iteration space must not."""
    from repro.core import deps
    from repro.core.deps import DepAnalysis
    from repro.core.transforms import ArrayPartition, LoopUnroll

    p = harris(6, storage="bram")
    before = deps.DATA_PAIR_ENUM_RUNS
    d1 = DepAnalysis(p)
    assert deps.DATA_PAIR_ENUM_RUNS == before + 1

    q = ArrayPartition().apply(p)
    d2 = DepAnalysis(q)
    assert deps.DATA_PAIR_ENUM_RUNS == before + 1, \
        "metadata-only clone re-ran pair enumeration"
    # the shared half must produce identical data pairs (kinds + uids)
    data = lambda d: sorted((pr.X.uid, pr.Y.uid, pr.kind) for pr in d._pairs
                            if pr.kind != "PORT")
    assert data(d1) == data(d2)

    # re-analyzing the SAME program also shares
    DepAnalysis(p)
    assert deps.DATA_PAIR_ENUM_RUNS == before + 1

    # an iteration-space change must NOT share
    u = LoopUnroll(2).apply(p)
    DepAnalysis(u)
    assert deps.DATA_PAIR_ENUM_RUNS == before + 2

    # and the shared analyses still compile to working schedules
    s = compile_program(q)
    assert s.feasible

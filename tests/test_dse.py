"""Resource-aware DSE driver (autotune.explore, DESIGN.md §6).

The acceptance demo: for real benchmarks, ``explore`` must find a
transformed program whose scheduled latency beats the untransformed
``compile_program`` schedule at equal-or-lower BRAM/DSP, and the winner
must pass the brute-force schedule validator + timed-execution oracle
(``validate=True`` asserts both inside explore).
"""
import pytest

from repro.core import compile_program, explore
from repro.core.programs import harris, two_mm, unsharp


@pytest.mark.parametrize("mk,n", [(two_mm, 6), (harris, 6)])
def test_explore_beats_baseline_iso_resources(mk, n):
    p = mk(n, storage="bram")
    r = explore(p, verify=True, validate=True, max_candidates=8,
                unroll_factors=(2,), tile_sizes=())
    assert r.best.latency < r.baseline.latency, (r.best.desc, r.best.latency)
    assert r.best.res["bram_bytes"] <= r.baseline.res["bram_bytes"] + 1e-9
    assert r.best.res["dsp"] <= r.baseline.res["dsp"] + 1e-9
    assert r.best.within_budget
    assert r.speedup > 1.0


def test_explore_default_budget_is_iso_resource():
    p = two_mm(4)
    r = explore(p, verify=True, max_candidates=4, unroll_factors=(),
                tile_sizes=())
    assert r.budget == {"bram_bytes": r.baseline.res["bram_bytes"],
                        "dsp": r.baseline.res["dsp"]}
    for c in r.candidates:
        assert c.within_budget == all(
            c.res[k] <= v + 1e-9 for k, v in r.budget.items())


def test_explore_budget_gates_unroll():
    """Unrolling doubles datapath DSPs: it must be flagged over-budget under
    the iso-resource budget, but become eligible when the budget allows."""
    p = unsharp(8, storage="bram")
    iso = explore(p, max_candidates=6, unroll_factors=(2,), tile_sizes=())
    unrolled = [c for c in iso.candidates if "unroll" in c.desc]
    assert unrolled and all(not c.within_budget for c in unrolled)
    assert iso.best.within_budget

    roomy = explore(p, budget={"dsp": 1e9, "bram_bytes": 1e9},
                    max_candidates=6, unroll_factors=(2,), tile_sizes=())
    unrolled = [c for c in roomy.candidates if "unroll" in c.desc]
    assert unrolled and all(c.within_budget for c in unrolled)


def test_explore_baseline_matches_compile_program():
    p = two_mm(4)
    r = explore(p, max_candidates=2, unroll_factors=(), tile_sizes=())
    assert r.baseline.latency == compile_program(p).completion_time()
    assert r.best.latency <= r.baseline.latency

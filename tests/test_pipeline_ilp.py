"""The ILP scheduler applied to pipeline parallelism + overlap planning."""

from repro.core import overlap, pipeline_ilp as pp


def test_schedule_is_dependency_clean():
    s = pp.synthesize(4, 8, t_f=1, t_b=2)
    # activations flow forward: stage s+1 fwd strictly after stage s fwd
    for a in range(3):
        assert s.fwd_start[a + 1] > s.fwd_start[a]
    # gradients flow backward
    for a in range(3):
        assert s.bwd_start[a] > s.bwd_start[a + 1]
    # bwd of a stage after its own fwd
    for a in range(4):
        assert s.bwd_start[a] > s.fwd_start[a]
    for m in range(8):
        for a in range(3):
            assert s.fwd_tick(a + 1, m) >= s.fwd_tick(a, m) + 1
            assert s.bwd_tick(a, m) >= s.bwd_tick(a + 1, m) + 2


def test_steady_state_ii_is_optimal():
    """Each device runs one fwd (t_f) + one bwd (t_b) per microbatch:
    II = t_f + t_b is a lower bound; the ILP must reach it."""
    s = pp.synthesize(4, 6, t_f=1, t_b=2)
    assert s.ii == 3
    s = pp.synthesize(3, 6, t_f=2, t_b=2)
    assert s.ii == 4


def test_fwd_only_ii_1():
    s = pp.synthesize(4, 8, t_f=1, backward=False)
    assert s.ii == 1
    assert s.fwd_start == sorted(s.fwd_start)


def test_memory_beats_gpipe():
    """The derived (1F1B-class) schedule must hold far fewer live
    activations than all-forward-then-all-backward."""
    S, M = 4, 16
    s = pp.synthesize(S, M, t_f=1, t_b=2)
    assert s.peak_live_activations < S * M / 2


def test_latency_beats_sequential():
    S, M = 4, 8
    s = pp.synthesize(S, M, t_f=1, t_b=2)
    assert s.latency < 0.6 * pp.sequential_latency(S, M)


def test_encdec_multiconsumer_graph():
    """Encoder output consumed by several decoder stages (non-SPSC) — the
    exact pattern FIFO dataflow rejects — must still schedule."""
    s = pp.synthesize(5, 6, t_f=1, backward=False, cross_from=1)
    assert s.ii == 1
    assert s.latency < pp.sequential_latency(5, 6, 1, 0) + 6


def test_ring_overlap_plan():
    plan = overlap.plan_ring_overlap(8)
    assert plan.ii == 1            # send + matmul overlap per tick
    assert plan.latency < plan.serial_latency
    plan2 = overlap.plan_ring_overlap(8, send_ticks=2, mm_ticks=1)
    assert plan2.ii == 2           # link-bound: II follows the slower port


def test_interleaved_negative_result():
    """Megatron-style virtual-stage interleaving does NOT pay under the
    affine (single-II) schedule class: the chunk chain is 2x longer at the
    same steady-state II, so fill/drain grows — the ILP quantifies what the
    schedule-class restriction costs (EXPERIMENTS.md §Pipeline).  Real
    interleaving gains need per-chunk phase offsets (non-affine warmup)."""
    si = pp.synthesize_interleaved(4, 2, 8, t_f=1, t_b=2)
    sn = pp.synthesize(4, 8, t_f=2, t_b=4)  # same per-device work
    assert si.ii == sn.ii == 6              # steady state identical
    assert si.latency >= sn.latency         # fill/drain is what differs

"""Property tests for the pass pipeline (DESIGN.md §6).

Every transform carries one obligation: ``sequential_exec(p) ==
sequential_exec(T(p))`` on ``p``'s arrays for any input, plus "the
transformed program still schedules" (``compile_program`` succeeds and the
brute-force ``validate_schedule`` oracle is clean).  We discharge it over
the benchmark corpus, ~30 random affine programs, and random transform
compositions.  Full-size corpus runs are ``-m slow``.
"""
import numpy as np
import pytest

from repro.core.autotune import compile_program
from repro.core.ir import Loop, Program, ProgramBuilder, StoreOp
from repro.core.programs import BENCHMARKS
from repro.core.sim import (make_inputs, sequential_exec, timed_exec,
                            validate_schedule)
from repro.core.transforms import (ArrayPartition, FuseProducerConsumer,
                                   LoopTile, LoopUnroll, Normalize, Pass,
                                   PassManager, PassVerificationError, ToSPSC,
                                   differential_check, to_spsc)

from test_property import random_program

# Reduced benchmark sizes keep a corpus x transforms sweep inside tier-1.
_SMALL = {"unsharp": 8, "harris": 6, "dus": 8, "optical_flow": 6, "two_mm": 4}


def _small(name, storage="reg"):
    return BENCHMARKS[name](_SMALL[name], storage=storage)


def _transform_menu(p):
    """One instance of every transform, parameterized from the program."""
    inner = [l for l in p.loops()
             if not any(isinstance(ch, Loop) for ch in l.body)]
    unroll_f = next((f for f in (2, 4) for l in inner if l.trip % f == 0), 2)
    tiles = {l.ivname: 2 for l in p.loops() if l.trip % 2 == 0 and l.trip >= 4}
    menu = [Normalize(), FuseProducerConsumer(), ArrayPartition(),
            LoopUnroll(unroll_f), ToSPSC()]
    if tiles:
        menu.append(LoopTile(tiles))
    return menu


# ---------------------------------------------------------------------------
# Corpus: every transform preserves sequential semantics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(BENCHMARKS))
@pytest.mark.parametrize("storage", ["reg", "bram"])
def test_corpus_transform_equivalence(name, storage):
    p = _small(name, storage)
    for T in _transform_menu(p):
        q = T.apply(p)
        differential_check(p, q, seeds=(0, 1))


@pytest.mark.parametrize("name", ["unsharp", "dus", "two_mm"])
def test_corpus_transformed_still_schedules(name):
    """Transformed programs must still compile, and their schedules must
    pass the brute-force validator and the timed-execution oracle."""
    p = _small(name, "bram")
    pipelines = [
        [FuseProducerConsumer()],
        [ArrayPartition()],
        [ArrayPartition(), FuseProducerConsumer()],
    ]
    for passes in pipelines:
        q = PassManager(passes, verify=True).run(p)
        s = compile_program(q)
        assert s.feasible
        assert validate_schedule(q, s) == []
        inp = make_inputs(q, 0)
        got, want = timed_exec(q, s, inp), sequential_exec(q, inp)
        for k in want:
            np.testing.assert_allclose(got[k], want[k], rtol=1e-12, err_msg=k)


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(BENCHMARKS))
def test_corpus_transform_equivalence_fullsize(name):
    p = BENCHMARKS[name](storage="bram")
    for T in _transform_menu(p):
        differential_check(p, T.apply(p), seeds=(0,))


# ---------------------------------------------------------------------------
# Random programs + random compositions
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(30))
def test_random_program_transform_composition(seed):
    """Random affine program, random 2-3 transform composition: sequential
    equivalence must hold and the result must still schedule cleanly."""
    rng = np.random.default_rng(5000 + seed)
    p = random_program(seed)
    menu = _transform_menu(p)
    picks = [menu[int(rng.integers(0, len(menu)))]
             for _ in range(int(rng.integers(2, 4)))]
    pm = PassManager(picks, verify=True, seeds=(seed,))
    q = pm.run(p)  # verify=True raises PassVerificationError on mismatch
    s = compile_program(q)
    assert s.feasible
    assert validate_schedule(q, s) == []
    inp = make_inputs(q, seed)
    got, want = timed_exec(q, s, inp), sequential_exec(q, inp)
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-12, err_msg=k)


@pytest.mark.parametrize("seed", range(12))
def test_random_imperfect_multiloop_transform_composition(seed):
    """The generalized nest contract: random imperfect / scan-style
    multi-loop tasks survive random transform compositions with sequential
    equivalence intact and still schedule cleanly."""
    from test_deps_fastpath import (_random_imperfect_program,
                                    _random_multiloop_program)

    mk = _random_imperfect_program if seed % 2 else _random_multiloop_program
    p = mk(seed)
    rng = np.random.default_rng(9000 + seed)
    menu = [Normalize(), Normalize(sink=False), ArrayPartition(),
            FuseProducerConsumer()]
    picks = [menu[int(rng.integers(0, len(menu)))]
             for _ in range(int(rng.integers(2, 4)))]
    pm = PassManager(picks, verify=True, seeds=(seed,))
    q = pm.run(p)
    s = compile_program(q)
    assert s.feasible
    assert validate_schedule(q, s) == []
    inp = make_inputs(q, seed)
    got, want = timed_exec(q, s, inp), sequential_exec(q, inp)
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-12, err_msg=k)


# ---------------------------------------------------------------------------
# Fusion legality
# ---------------------------------------------------------------------------


def _chain(n, consumer_offset):
    """Producer writes X[i][j]; consumer (same bounds) reads
    X[i + consumer_offset][j]."""
    b = ProgramBuilder("chain")
    b.array("inp", (n + 1, n), is_arg=True, partition=(0, 1), ports=("w", "r"))
    b.array("X", (n + 1, n), partition=(0, 1), ports=("w", "r"))
    b.array("out", (n, n), is_arg=True, partition=(0, 1), ports=("w", "r"))
    with b.loop("pi", 0, n) as i:
        with b.loop("pj", 0, n) as j:
            b.store("X", b.mul(b.load("inp", i, j), b.const(2.0)), i, j)
    with b.loop("ci", 0, n) as i:
        with b.loop("cj", 0, n) as j:
            b.store("out", b.mul(b.load("X", i + consumer_offset, j),
                                 b.const(0.5)), i, j)
    return b.build()


def test_fusion_legal_pointwise():
    p = _chain(6, 0)
    q = FuseProducerConsumer().apply(p)
    assert len(q.body) == 1  # fused
    differential_check(p, q, seeds=(0, 1, 2))


def test_fusion_forward_read_shifts_and_peels():
    """Consumer reads a row the producer has not written yet at the fused
    iteration: zero-shift fusion is illegal (the exact ILP check refuses),
    but a one-row consumer shift with a peeled prologue row is legal — the
    noshift variant must still reject it."""
    p = _chain(6, 1)
    assert FuseProducerConsumer(enable_shift=False).apply(p) is p
    q = FuseProducerConsumer().apply(p)
    assert q is not p
    assert q._fusion_log[0]["shift"] == [1, 0]
    assert q._fusion_log[0]["peels"] >= 1
    differential_check(p, q, seeds=(0, 1, 2))
    # and the WAR direction: the second nest writes X[i+1][j], which the
    # first nest still has to read (as X[i][j]) at a LATER iteration — the
    # fused second nest would clobber it one iteration too early unless it
    # is shifted one row behind the producer
    b = ProgramBuilder("war")
    b.array("X", (7, 6), partition=(0, 1), ports=("w", "r"))
    b.array("Y", (6, 6), partition=(0, 1), ports=("w", "r"))
    with b.loop("pi", 0, 6) as i:
        with b.loop("pj", 0, 6) as j:
            b.store("Y", b.mul(b.load("X", i, j), b.const(2.0)), i, j)
    with b.loop("ci", 0, 6) as i:
        with b.loop("cj", 0, 6) as j:
            b.store("X", b.mul(b.load("Y", i, j), b.const(0.5)), i + 1, j)
    p2 = b.build()
    assert FuseProducerConsumer(enable_shift=False).apply(p2) is p2
    q2 = FuseProducerConsumer().apply(p2)
    assert q2 is not p2 and q2._fusion_log[0]["shift"] == [1, 0]
    differential_check(p2, q2)


def test_fusion_backward_flowing_dependence_is_rejected():
    """Consumer reads the producer's rows in REVERSE: the dependence
    distance grows with the problem size, so no finite shift leaves a
    usable fused core — the pass must refuse for every variant."""
    for n in (6, 8):
        b = ProgramBuilder("rev")
        b.array("inp", (n, n), is_arg=True, partition=(0, 1), ports=("w", "r"))
        b.array("X", (n, n), partition=(0, 1), ports=("w", "r"))
        b.array("out", (n, n), is_arg=True, partition=(0, 1), ports=("w", "r"))
        with b.loop("pi", 0, n) as i:
            with b.loop("pj", 0, n) as j:
                b.store("X", b.mul(b.load("inp", i, j), b.const(2.0)), i, j)
        with b.loop("ci", 0, n) as i:
            with b.loop("cj", 0, n) as j:
                b.store("out", b.mul(b.load("X", (n - 1) - i, j),
                                     b.const(0.5)), i, j)
        p = b.build()
        assert FuseProducerConsumer().apply(p) is p


def test_fusion_crossed_iv_names():
    """Consumer loops named like the producer's but CROSSED (its outer iv
    carries the producer's inner name): the B->A renaming must be applied
    simultaneously, or j->i->j chains and the fused body reads M[j][j]."""
    n = 6
    b = ProgramBuilder("crossed")
    b.array("inp", (n, n), is_arg=True, partition=(0, 1), ports=("w", "r"))
    b.array("M", (n, n), partition=(0, 1), ports=("w", "r"))
    b.array("O", (n, n), is_arg=True, partition=(0, 1), ports=("w", "r"))
    with b.loop("i", 0, n) as i:
        with b.loop("j", 0, n) as j:
            b.store("M", b.mul(b.load("inp", i, j), b.const(2.0)), i, j)
    with b.loop("j", 0, n) as j:     # reads M[j][i]: pointwise after the
        with b.loop("i", 0, n) as i:  # positional renaming j->i, i->j
            b.store("O", b.mul(b.load("M", j, i), b.const(0.5)), j, i)
    p = b.build()
    q = PassManager([FuseProducerConsumer()], verify=True).run(p)
    assert len(q.body) == 1
    differential_check(p, q, seeds=(0, 1, 2))


def test_fusion_collapses_pointwise_chain():
    """unsharp's by->sharpen->mask tail is pointwise: greedy fusion must
    collapse it (4 nests -> 2) and the fused program must still schedule."""
    p = _small("unsharp")
    q = FuseProducerConsumer().apply(p)
    assert len(q.body) == 2
    s = compile_program(q)
    assert s.feasible
    differential_check(p, q)


# ---------------------------------------------------------------------------
# Pass mechanics
# ---------------------------------------------------------------------------


def test_unroll_divisibility_noop():
    p = _small("unsharp")  # trips are 8/10: factor 3 divides nothing
    assert LoopUnroll(3).apply(p) is p


def test_transforms_do_not_mutate_input():
    p = _small("dus")

    def fingerprint(pr: Program) -> str:  # deep textual snapshot
        return repr([(type(n).__name__, vars(n)) for n, _ in pr.walk()]) + \
            repr(sorted(pr.arrays.items()))

    snapshot = fingerprint(p)
    for T in _transform_menu(p):
        T.apply(p)
    assert fingerprint(p) == snapshot


def test_pass_manager_verify_catches_bad_pass():
    class DropLastStore(Pass):
        name = "drop_last_store"

        def apply(self, p):
            from repro.core.transforms import clone_program
            q = clone_program(p)
            inner = q.body[-1]
            while any(isinstance(ch, Loop) for ch in inner.body):
                inner = [ch for ch in inner.body if isinstance(ch, Loop)][-1]
            inner.body = [op for op in inner.body
                          if not isinstance(op, StoreOp)]
            return q

    p = _small("unsharp")
    with pytest.raises(PassVerificationError, match="drop_last_store"):
        PassManager([DropLastStore()], verify=True).run(p)


def test_to_spsc_alias_preserved():
    """dataflow.to_spsc must remain the transforms implementation."""
    from repro.core import dataflow
    assert dataflow.to_spsc is to_spsc
    p = _small("unsharp")
    q = ToSPSC().apply(p)
    info = dataflow.analyze_dataflow(q)
    assert info.applicable


def test_dataflow_models_multi_chain_task():
    """A fused (two-sibling-nest) task now has a well-defined access order
    (per-chain FIFO + cross-chain sequencing): the dataflow model accepts it
    when the process network is otherwise SPSC, and any remaining rejection
    carries a structured NestContractViolation code."""
    from repro.core.dataflow import analyze_dataflow
    b = ProgramBuilder("multi_chain_ok")
    b.array("A", (4, 4), partition=(0,), ports=("r",), is_arg=True)
    b.array("T", (4, 4), partition=(0,), ports=("w", "r"))
    b.array("U", (4, 4), partition=(0,), ports=("w", "r"))
    b.array("B", (4, 4), partition=(0,), ports=("w",), is_arg=True)
    with b.loop("ti", 0, 4) as i:
        with b.loop("ta", 0, 4) as j:
            b.store("T", b.add(b.load("A", i, j), b.const(1.0)), i, j)
        with b.loop("tb", 0, 4) as j:
            b.store("U", b.mul(b.load("T", i, j), b.const(2.0)), i, j)
    with b.loop("ci", 0, 4) as i:
        with b.loop("cj", 0, 4) as j:
            b.store("B", b.add(b.load("U", i, j), b.const(0.5)), i, j)
    p = b.build()
    from repro.core.ir import nest_shape
    assert nest_shape(p).kinds == ("multi_loop", "perfect")
    info = analyze_dataflow(p)
    assert info.applicable, info.reason
    # T is task-internal (written and read inside task 0) — only U crosses
    assert [(c.array, c.producer, c.consumer, c.kind)
            for c in info.channels] == [("U", 0, 1, "fifo")]

    # a multi-chain task whose second chain re-writes an array another task
    # also writes is still rejected — but for the real (SPSC) reason, with
    # a machine-readable code instead of a diagnostic string to match on
    b2 = ProgramBuilder("multi_chain_mpsc")
    b2.array("A", (4, 4), partition=(0,), ports=("w", "r"))
    b2.array("B", (4, 4), partition=(0,), ports=("w", "r"))
    with b2.loop("ti", 0, 4) as i:
        with b2.loop("ta", 0, 4) as j:
            b2.store("A", b2.mul(b2.load("A", i, j), b2.const(1.0)), i, j)
        with b2.loop("tb", 0, 4) as j:
            b2.store("B", b2.mul(b2.load("A", i, j), b2.const(1.0)), i, j)
    with b2.loop("ci", 0, 4) as i:
        with b2.loop("cj", 0, 4) as j:
            b2.store("B", b2.mul(b2.load("B", i, j), b2.const(2.0)), i, j)
    info2 = analyze_dataflow(b2.build())
    assert not info2.applicable
    assert info2.diagnostic is not None
    assert info2.diagnostic.code == "multi-producer"
    assert info2.diagnostic.as_diagnostic()["kind"] == "dataflow-rejection"
    assert info2.reason == info2.diagnostic.detail

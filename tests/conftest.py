"""Shared test configuration: a per-test wall-clock timeout guard.

``pytest-timeout`` is not available in this container, so the guard uses
SIGALRM (no-op on platforms without it).  The default keeps any single test
from stalling the tier-1 verify loop; override per test with
``@pytest.mark.timeout(seconds)`` or the REPRO_TEST_TIMEOUT env var.
"""
import os
import signal

import pytest

DEFAULT_TIMEOUT = int(os.environ.get("REPRO_TEST_TIMEOUT", "120"))

# The suite must be hermetic: a warm ~/.cache/repro-hls from an earlier run
# (or another test) would skip compiles that tests count (e.g. the
# DATA_PAIR_ENUM_RUNS probes).  The persistent compile cache is therefore
# OFF for every test; dedicated cache tests re-enable it against a tmpdir
# via monkeypatch (REPRO_HLS_CACHE=1 + REPRO_HLS_CACHE_DIR).
os.environ["REPRO_HLS_CACHE"] = "0"

# No fault plan leaks in from the calling environment: chaos tests opt in
# explicitly via repro.core.faults.inject(...).
os.environ.pop("REPRO_HLS_FAULTS", None)


@pytest.fixture(autouse=True)
def _fault_free():
    """Reset the fault-injection harness around every test so a failing
    chaos test can never leave a plan armed for its neighbours."""
    from repro.core import faults
    faults.reset()
    yield
    faults.reset()


@pytest.fixture(autouse=True)
def _timeout_guard(request):
    if not hasattr(signal, "SIGALRM"):
        yield
        return
    marker = request.node.get_closest_marker("timeout")
    seconds = int(marker.args[0]) if marker and marker.args else DEFAULT_TIMEOUT
    if request.node.get_closest_marker("slow"):
        seconds = max(seconds, 600)

    def _on_alarm(signum, frame):
        pytest.fail(f"timeout guard: test exceeded {seconds}s", pytrace=False)

    old = signal.signal(signal.SIGALRM, _on_alarm)
    signal.alarm(seconds)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)

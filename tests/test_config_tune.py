"""The tuned-config policy must encode the §Perf findings exactly."""
from repro.config import SHAPES, get_config, tune


def test_dense_small_train_goes_zero_only():
    cfg = tune(get_config("rwkv6_3b"), SHAPES["train_4k"])
    assert cfg.parallel_style == "fsdp"
    assert cfg.remat == "dots" and cfg.scores_bf16


def test_unshardable_batch_keeps_tp():
    # prefill_32k has global_batch 32 < 256 chips: pure DP would replicate
    cfg = tune(get_config("rwkv6_3b"), SHAPES["prefill_32k"])
    assert cfg.parallel_style == "tp"
    cfg = tune(get_config("llama3_8b"), SHAPES["decode_32k"])
    assert cfg.parallel_style == "tp"


def test_moe_keeps_tp():
    cfg = tune(get_config("kimi_k2_1t_a32b"), SHAPES["train_4k"])
    assert cfg.parallel_style == "tp"


def test_405b_fits_zero_only():
    cfg = tune(get_config("llama3_405b"), SHAPES["train_4k"])
    assert cfg.parallel_style == "fsdp"   # 3*2*405e9/256 = 9.5 GB/chip


def test_all_cells_have_a_tuned_config():
    from repro.config import ARCH_IDS
    for aid in ARCH_IDS:
        for s in SHAPES.values():
            cfg = tune(get_config(aid), s)
            assert cfg.parallel_style in ("tp", "fsdp")

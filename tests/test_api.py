"""The declarative hls.compile front end (DESIGN.md §6).

Covers, per the API-redesign acceptance criteria:

  * pipeline-string parser: round-trip property (parse -> print -> parse
    identity over randomized pass sequences) + golden error messages with
    source positions;
  * malformed CompileSpec errors (objectives, constraints, targets);
  * pinned golden Pareto frontiers for blur_chain / conv_pool / harris at
    n=8 (the Fig. 9 trade-off curve is deterministic);
  * no-regression vs the old greedy explore(): the new frontier contains a
    point dominating-or-equal to the greedy winner;
  * the deprecated shims (repro.core.explore / compile_program) emit
    exactly one DeprecationWarning per access and still work.
"""
import warnings

import numpy as np
import pytest

from repro.core import hls
from repro.core.autotune import _greedy_explore, dominates
from repro.core.pipeline_parse import (PipelineSyntaxError, parse_pipeline,
                                       print_pipeline)
from repro.core.programs import (CHAIN_BENCHMARKS, blur_chain, conv_pool,
                                 harris, optical_flow, two_mm)
from repro.core.transforms import (ArrayPartition, FuseProducerConsumer,
                                   LoopTile, LoopUnroll, Normalize,
                                   PASS_TAGS, PassManager, ToSPSC)


# ---------------------------------------------------------------------------
# Pipeline string syntax
# ---------------------------------------------------------------------------


def _random_pass(rng):
    k = rng.integers(0, 7)
    if k == 0:
        return Normalize() if rng.integers(0, 2) else Normalize(sink=False)
    if k == 1:
        return ToSPSC()
    if k == 2:
        ivs = None if rng.integers(0, 2) else \
            tuple(f"iv{j}" for j in range(1 + rng.integers(0, 3)))
        return LoopUnroll(int(2 ** rng.integers(1, 4)), ivs)
    if k == 3:
        if rng.integers(0, 2):
            return LoopTile(tuple(int(2 * rng.integers(1, 9))
                                  for _ in range(1 + rng.integers(0, 3))))
        return LoopTile({f"l{j}": int(2 * rng.integers(1, 9))
                         for j in range(1 + rng.integers(0, 3))})
    if k == 4:
        arrays = None if rng.integers(0, 2) else ("a", "b")
        dims = None if rng.integers(0, 2) else tuple(
            int(d) for d in range(rng.integers(1, 3)))
        return ArrayPartition(arrays, dims)
    if k == 5:
        return FuseProducerConsumer(
            None if rng.integers(0, 2) else int(rng.integers(1, 4)),
            enable_shift=bool(rng.integers(0, 2)),
            min_core_fraction=float(rng.choice([0.25, 0.5, 0.75])))
    return FuseProducerConsumer()


@pytest.mark.parametrize("seed", range(25))
def test_pipeline_roundtrip_property(seed):
    """parse(print(passes)) reproduces every pass signature, and printing
    is a fixpoint: print(parse(print(p))) == print(p)."""
    rng = np.random.default_rng(1234 + seed)
    passes = [_random_pass(rng) for _ in range(int(rng.integers(1, 6)))]
    text = print_pipeline(passes)
    parsed = parse_pipeline(text)
    assert [p.signature() for p in parsed] == \
        [p.signature() for p in passes], text
    assert print_pipeline(parsed) == text


def test_pipeline_parse_example_from_spec():
    ps = parse_pipeline("normalize,fuse{shift=true,min_core_fraction=0.5},"
                        "tile{sizes=8,8},unroll{factor=2}")
    assert [type(p) for p in ps] == [Normalize, FuseProducerConsumer,
                                     LoopTile, LoopUnroll]
    assert ps[1].enable_shift is True
    assert ps[2].seq == (8, 8)
    assert ps[3].factor == 2
    # whitespace-insensitive
    ps2 = parse_pipeline(" normalize , fuse { shift = true , "
                         "min_core_fraction = 0.5 } , tile { sizes = 8 , 8 } "
                         ", unroll { factor = 2 } ")
    assert [p.signature() for p in ps2] == [p.signature() for p in ps]


def test_pipeline_parse_empty_and_registry():
    assert parse_pipeline("") == []
    assert parse_pipeline("   ") == []
    assert set(PASS_TAGS) == {"normalize", "unroll", "tile", "partition",
                              "fuse", "spsc"}


# golden error messages: the caret must point at the offending token and the
# message must name the fix — these strings are part of the API surface
_GOLDEN_ERRORS = [
    ("frobnicate",
     "unknown pass 'frobnicate' (known: fuse, normalize, partition, spsc, "
     "tile, unroll)\n  at position 0:"),
    ("fuse{shift=banana}",
     "fuse shift: expected bool, got 'banana'\n  at position 0:"),
    ("normalize{sink=banana}",
     "normalize sink: expected bool, got 'banana'\n  at position 0:"),
    ("normalize{sank=true}",
     "normalize: unknown parameter(s) ['sank'] (valid: sink)\n"
     "  at position 0:"),
    ("unroll{ivs=i,j}",
     "unroll requires factor=<int>\n  at position 0:"),
    ("tile{8,8}",
     "value '8' has no parameter name (write key=value)\n  at position 5:"),
    ("tile{i=4,sizes=8}",
     "tile: cannot mix sizes= with named loops ['i']\n  at position 0:"),
    ("unroll{factor=2",
     "expected ',' or '}' in the parameter block, got end of input\n"
     "  at position 15:"),
    ("fuse,,tile{i=4}",
     "expected a pass name, got ','\n  at position 5:"),
    ("fuse{shift=true,shift=false}",
     "duplicate parameter 'shift'\n  at position 16:"),
    ("fuse,",
     "trailing ',' with no pass after it\n  at position 4:"),
]


@pytest.mark.parametrize("text,prefix",
                         _GOLDEN_ERRORS, ids=[t for t, _ in _GOLDEN_ERRORS])
def test_pipeline_parse_golden_errors(text, prefix):
    with pytest.raises(PipelineSyntaxError) as ei:
        parse_pipeline(text)
    msg = str(ei.value)
    assert msg.startswith(prefix), f"\ngot:  {msg!r}\nwant prefix: {prefix!r}"
    # the caret line (4-space indented source echo) points at the position
    assert msg.splitlines()[-1] == " " * (4 + ei.value.pos) + "^"
    assert 0 <= ei.value.pos <= len(text)


# ---------------------------------------------------------------------------
# Spec validation (malformed-spec goldens)
# ---------------------------------------------------------------------------


def test_malformed_spec_errors():
    with pytest.raises(ValueError, match=r"unknown objective 'brams'"):
        hls.minimize("brams")
    with pytest.raises(ValueError, match=r"weight must be > 0"):
        hls.minimize("latency", weight=0)
    with pytest.raises(ValueError, match=r"malformed constraint 'bram >= 3'"):
        hls.Constraint.parse("bram >= 3")
    with pytest.raises(ValueError, match=r"unknown constraint resource"):
        hls.Constraint.parse("latency <= 10")
    with pytest.raises(ValueError, match=r"exactly one of limit= .* scale="):
        hls.Constraint("bram")
    with pytest.raises(ValueError, match=r"unknown target mode 'fpga'"):
        hls.Target(mode="fpga")
    with pytest.raises(ValueError, match=r"unknown capacity resource"):
        hls.Target(capacities={"sram": 1})
    with pytest.raises(ValueError, match=r"unknown combine mode 'sum'"):
        hls.CompileSpec(combine="sum")
    with pytest.raises(ValueError, match=r"at least one objective"):
        hls.CompileSpec(objectives=())
    with pytest.raises(TypeError, match=r"spec must be a CompileSpec"):
        hls.compile(two_mm(4), {"objective": "latency"})


def test_constraint_parse_forms():
    c = hls.Constraint.parse("dsp <= 48")
    assert (c.resource, c.limit, c.scale) == ("dsp", 48.0, None)
    c = hls.Constraint.parse("bram <= 1.5x baseline")
    assert (c.resource, c.limit, c.scale) == ("bram_bytes", None, 1.5)
    assert hls.constraint("ff <= 2.0x baseline").resource == "ff_bits"


# ---------------------------------------------------------------------------
# Fixed-pipeline compilation
# ---------------------------------------------------------------------------


def test_fixed_pipeline_matches_manual_composition():
    p = blur_chain(8, storage="bram")
    r = hls.compile(p, pipeline="fuse")
    q = PassManager([FuseProducerConsumer()], verify=True).run(p)
    from repro.core.autotune import compile_program as raw_compile
    assert r.best.latency == raw_compile(q).completion_time()
    assert r.best.desc == "fuse"
    assert r.frontier == [r.best]
    # the printed pipeline of the result re-parses to the same design
    r2 = hls.compile(p, pipeline=r.pipeline_of())
    assert r2.best.latency == r.best.latency


def test_fixed_pipeline_with_trailing_noop_keeps_applied_passes():
    """A fixed pipeline whose LAST pass happens not to fire must still
    deliver the earlier passes' design — only a wholly no-op pipeline
    degrades to the baseline (regression: the DSE's incremental no-op
    convention leaked into the fixed-pipeline path and silently returned
    the baseline)."""
    p = blur_chain(8, storage="bram")
    r = hls.compile(p, pipeline="fuse,normalize")  # normalize is a no-op
    fused = hls.compile(p, pipeline="fuse")
    assert r.best.latency == fused.best.latency
    assert r.best.program._fusion_log
    # wholly no-op pipeline -> baseline
    r0 = hls.compile(p, pipeline="normalize")
    assert r0.best is r0.baseline


def test_empty_pipeline_is_compile_program():
    p = two_mm(4)
    from repro.core.autotune import compile_program as raw_compile
    r = hls.compile(p, pipeline=())
    assert r.best is r.baseline
    assert r.best.latency == raw_compile(p).completion_time()
    assert r.schedule is r.best.schedule


def test_fixed_pipeline_capacity_rejection():
    p = blur_chain(8, storage="bram")
    r = hls.compile(p, pipeline="fuse", constraints=("dsp <= 1",))
    assert r.frontier == []
    assert not r.best.within_budget
    assert r.rejected and "dsp" in r.rejected[0][1]
    assert "over budget" in r.explain()


# ---------------------------------------------------------------------------
# Pareto frontiers
# ---------------------------------------------------------------------------


def _frontier_tuples(r):
    return [(c.latency, c.res["bram_bytes"], c.res["dsp"], c.res["ff_bits"])
            for c in r.frontier]


# Golden frontiers (latency, bram_bytes, dsp, ff_bits), objective-sorted.
# Regenerate with the same SearchConfig if the resource model or scheduler
# changes intentionally; any other drift is a regression.
_GOLDEN_FRONTIERS = {
    "blur_chain": dict(
        n=8, max_candidates=12, unroll_factors=(2,), tile_sizes=(2, 4),
        frontier=[
            (67, 0, 52, 9280),      # fuse | partition | unroll(x2)
            (103, 0, 26, 7328),     # fuse | partition | tile(core:2)
            (103, 1568, 26, 1056),  # fuse | tile(core:2)
            (103, 1952, 26, 992),   # fuse
            (106, 1952, 26, 512),   # baseline
        ]),
    "conv_pool": dict(
        n=8, max_candidates=12, unroll_factors=(2,), tile_sizes=(2, 4),
        frontier=[
            (52, 0, 86, 12288),     # fuse | partition | unroll(x2)
            (73, 0, 43, 10720),     # fuse | partition
            (84, 1440, 43, 6400),   # fuse
            (92, 0, 43, 6432),      # partition
            (92, 1440, 43, 704),    # baseline
        ]),
    "harris": dict(
        n=8, max_candidates=6, unroll_factors=(2,), tile_sizes=(),
        frontier=[
            (157, 0, 157, 23488),   # partition
            (225, 4800, 157, 3392), # baseline
            (268, 4800, 157, 2112), # fuse(noshift)
        ]),
}
_GOLDEN_MAKERS = {"blur_chain": blur_chain, "conv_pool": conv_pool,
                  "harris": harris}


@pytest.mark.parametrize("name", sorted(_GOLDEN_FRONTIERS))
def test_golden_pareto_frontier(name):
    g = _GOLDEN_FRONTIERS[name]
    p = _GOLDEN_MAKERS[name](g["n"], storage="bram")
    r = hls.compile(p, search=hls.SearchConfig(
        max_candidates=g["max_candidates"],
        unroll_factors=g["unroll_factors"], tile_sizes=g["tile_sizes"]))
    assert _frontier_tuples(r) == g["frontier"]
    # structural invariants: mutual non-dominance, feasibility, best on it
    for c in r.frontier:
        assert c.within_budget
        assert not any(dominates(d.objectives(), c.objectives())
                       for d in r.frontier if d is not c)
    assert r.best in r.frontier
    # a >= 2-point NON-degenerate frontier: two mutually non-dominated
    # points with distinct latency AND distinct BRAM
    assert any(c1.latency != c2.latency and
               c1.res["bram_bytes"] != c2.res["bram_bytes"]
               for c1 in r.frontier for c2 in r.frontier)


def test_objective_selection_modes():
    p = blur_chain(8, storage="bram")
    r = hls.compile(p, search=hls.SearchConfig(max_candidates=12,
                                               unroll_factors=(2,),
                                               tile_sizes=(2, 4)))
    lat = hls.compile(p, spec=None, objectives=hls.minimize("latency"),
                      search=r.spec.search)
    assert lat.best.latency == min(c.latency for c in lat.frontier)
    # lexicographic (bram, latency): min-BRAM first, latency breaks ties
    bram_first = hls.compile(
        p, objectives=(hls.minimize("bram"), hls.minimize("latency")),
        search=r.spec.search)
    min_bram = min(c.res["bram_bytes"] for c in bram_first.frontier)
    assert bram_first.best.res["bram_bytes"] == min_bram
    assert bram_first.best.latency == min(
        c.latency for c in bram_first.frontier
        if c.res["bram_bytes"] == min_bram)
    # weighted: an overwhelming BRAM weight must agree with bram-lex on
    # the chosen point's BRAM
    w = hls.compile(p, objectives=(hls.minimize("bram", weight=100.0),
                                   hls.minimize("latency")),
                    combine="weighted", search=r.spec.search)
    assert w.best.res["bram_bytes"] == min_bram


def test_constraints_cap_the_frontier():
    p = blur_chain(8, storage="bram")
    r = hls.compile(p, constraints=("dsp <= 1.0x baseline",
                                    "bram <= 1.0x baseline"),
                    search=hls.SearchConfig(max_candidates=12,
                                            unroll_factors=(2,),
                                            tile_sizes=(2, 4)))
    base = r.baseline.res
    assert r.caps == {"dsp": base["dsp"], "bram_bytes": base["bram_bytes"]}
    for c in r.frontier:
        assert c.res["dsp"] <= base["dsp"] + 1e-9
        assert c.res["bram_bytes"] <= base["bram_bytes"] + 1e-9
    # the unrolled point (2x DSP) must be among the rejected with a reason
    assert any("unroll" in desc and "dsp" in reason
               for desc, reason in r.rejected)
    assert "over budget" in r.explain()


def test_knee_point():
    p = blur_chain(8, storage="bram")
    r = hls.compile(p, search=hls.SearchConfig(max_candidates=12,
                                               unroll_factors=(),
                                               tile_sizes=(2, 4)))
    k = r.knee("latency", "bram")
    assert k in r.frontier
    # knee of a 2-point degenerate set is the single closest point
    with pytest.raises(ValueError, match="empty frontier"):
        r.knee(among=[])


_NOREG_SIZES = {"blur_chain": 8, "correlated_chain": 8, "gradient_harris": 6,
                "two_mm": 6}


@pytest.mark.parametrize("name", sorted(_NOREG_SIZES))
def test_frontier_dominates_greedy_winner(name):
    """No regression vs the old greedy single-frontier search: the Pareto
    frontier must contain a point dominating-or-equal to the greedy
    explore() winner."""
    from repro.core.programs import correlated_chain, gradient_harris
    makers = {"blur_chain": blur_chain, "correlated_chain": correlated_chain,
              "gradient_harris": gradient_harris, "two_mm": two_mm}
    p = makers[name](_NOREG_SIZES[name], storage="bram")
    g = _greedy_explore(p, max_candidates=12)
    r = hls.compile(p, search=hls.SearchConfig(max_candidates=12))
    gv = g.best.objectives()
    assert any(dominates(c.objectives(), gv) or c.objectives() == gv
               for c in r.frontier), (gv, _frontier_tuples(r))


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(list(CHAIN_BENCHMARKS) +
                                        ["harris", "optical_flow", "two_mm"]))
def test_frontier_dominates_greedy_winner_full(name):
    """The acceptance sweep: every CHAIN_BENCHMARKS + harris / optical_flow
    / two_mm program, frontier point dominating-or-equal the greedy
    winner."""
    makers = {**CHAIN_BENCHMARKS, "harris": harris,
              "optical_flow": optical_flow, "two_mm": two_mm}
    sizes = {"blur_chain": 8, "conv_pool": 8, "gradient_harris": 6,
             "correlated_chain": 8, "harris": 6, "optical_flow": 6,
             "two_mm": 6}
    p = makers[name](sizes[name], storage="bram")
    g = _greedy_explore(p, max_candidates=12)
    r = hls.compile(p, search=hls.SearchConfig(max_candidates=12))
    gv = g.best.objectives()
    assert any(dominates(c.objectives(), gv) or c.objectives() == gv
               for c in r.frontier), (gv, _frontier_tuples(r))


# ---------------------------------------------------------------------------
# Deprecated shims
# ---------------------------------------------------------------------------


def _access_explore():
    import repro.core
    return repro.core.explore


def _access_compile_program():
    import repro.core
    return repro.core.compile_program


def _access_stencil_dse_config():
    from repro.kernels.stencil_pipeline import stencil_dse_config
    return stencil_dse_config(3, 8)


@pytest.mark.parametrize("name,access,blessed", [
    ("explore", _access_explore, "hls.compile"),
    ("compile_program", _access_compile_program, "hls.compile"),
    ("stencil_dse_config", _access_stencil_dse_config, "emit_pallas"),
], ids=["explore", "compile_program", "stencil_dse_config"])
def test_deprecated_shim_warns_exactly_once_per_access(name, access, blessed):
    """Every deprecated shim emits exactly one DeprecationWarning per
    access, names itself, and points at the blessed replacement."""
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        access()
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 1, (name, [str(x.message) for x in w])
    assert name in str(dep[0].message)
    assert blessed in str(dep[0].message)
    assert "MIGRATION" in str(dep[0].message)


def test_blessed_path_does_not_warn():
    import repro.core
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        hls.compile(two_mm(4), pipeline=())
    assert not [x for x in w if issubclass(x.category, DeprecationWarning)]
    with pytest.raises(AttributeError):
        repro.core.no_such_attribute


def test_deprecated_explore_still_works():
    import repro.core
    p = blur_chain(8, storage="bram")
    r = repro.core.explore(p, max_candidates=6, unroll_factors=(),
                           tile_sizes=())
    assert r.best.latency <= r.baseline.latency
    assert r.best.within_budget
    assert r.speedup >= 1.0
    assert r.frontier  # the shim surfaces the Pareto frontier too
    s = repro.core.compile_program(p)
    assert s.completion_time() == r.baseline.latency


# ---------------------------------------------------------------------------
# Graceful empty-budget behavior (DSEResult satellite)
# ---------------------------------------------------------------------------


def test_explore_rejecting_budget_returns_baseline():
    """A budget no candidate can meet must return the baseline gracefully
    (no ZeroDivisionError, no arbitrary over-budget 'winner') and record
    every rejection reason."""
    import repro.core
    p = two_mm(4)
    r = repro.core.explore(p, budget={"dsp": 0.0}, max_candidates=4,
                           unroll_factors=(), tile_sizes=())
    assert r.best is r.baseline
    assert not r.best.within_budget
    assert r.speedup == 1.0          # guarded division
    assert r.table()                 # no crash on all-over-budget rows
    assert r.rejections and all("dsp" in reason
                                for _, reason in r.rejections)
    assert "over budget" in r.explain()


def test_dse_speedup_guard_degenerate_latency():
    from repro.core.autotune import DSECandidate, DSEResult
    c = DSECandidate(desc="baseline", passes=(), program=None, schedule=None,
                     latency=0, res={"bram_bytes": 0.0, "dsp": 0.0,
                                     "ff_bits": 0.0, "lut": 0.0},
                     within_budget=True)
    r = DSEResult(baseline=c, best=c, candidates=[c])
    assert r.speedup == 1.0
    assert r.table() == [("baseline", 0, 0.0, 0.0, True)]

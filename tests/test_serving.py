"""Continuous batching: correctness vs one-at-a-time serving, slot reuse,
and admission under a request stream longer than the slot count."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.models import lm
from repro.runtime.serving import ContinuousBatcher, Request


@pytest.fixture(scope="module")
def served_model():
    cfg = dataclasses.replace(get_config("llama3_8b", reduced=True),
                              dtype="float32")
    params = lm.init_params(cfg, jax.random.key(0))
    Smax = 48

    @jax.jit
    def decode(cache, tokens, pos):
        return lm.decode_step(cfg, params, cache,
                              {"token": tokens, "pos": pos})

    def init_cache(n_slots):
        return lm.init_cache(cfg, n_slots, Smax)

    return cfg, params, decode, init_cache, Smax


def _serve_single(cfg, params, prompt, max_new, Smax):
    """One-at-a-time reference."""
    cache = lm.init_cache(cfg, 1, Smax)
    out = []
    tok = None
    for t in range(len(prompt) + max_new - 1):
        cur = prompt[t] if t < len(prompt) else out[-1]
        batch = {"token": jnp.asarray([[cur]], jnp.int32),
                 "pos": jnp.asarray([t], jnp.int32)}
        logits, cache = lm.decode_step(cfg, params, cache, batch)
        if t >= len(prompt) - 1:
            nxt = int(jnp.argmax(logits[0, -1]))
            out.append(nxt)
            if nxt == 1:
                break
    return out


@pytest.mark.slow
def test_continuous_batching_matches_single(served_model):
    cfg, params, decode, init_cache, Smax = served_model
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(2, cfg.vocab, size=6),
                    max_new=5) for i in range(5)]
    batcher = ContinuousBatcher(decode, init_cache, n_slots=2, eos=1,
                                max_len=Smax)
    for r in reqs:
        batcher.submit(r)
    done = batcher.run()
    assert len(done) == 5
    for r in done:
        want = _serve_single(cfg, params, r.prompt, r.max_new, Smax)
        assert r.output == want, (r.rid, r.output, want)


def test_slots_are_reused(served_model):
    cfg, params, decode, init_cache, Smax = served_model
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, prompt=rng.integers(2, cfg.vocab, size=4),
                    max_new=3) for i in range(6)]
    b = ContinuousBatcher(decode, init_cache, n_slots=2, eos=1, max_len=Smax)
    for r in reqs:
        b.submit(r)
    b.run()
    assert len(b.completed) == 6
    # with 2 slots and 6 requests, occupancy must stay saturated mid-run
    assert max(b.occupancy) == 2
    # total steps far below one-at-a-time serial cost
    serial_steps = sum(len(r.prompt) + r.max_new for r in reqs)
    assert b.steps < serial_steps

"""Property suite for shift-and-peel producer-consumer fusion (DESIGN.md §6).

The obligations, over the mismatched-bounds chain corpus
(``programs.CHAIN_BENCHMARKS``) and ~30 random mismatched-bounds affine
chains:

  * the pass fuses (nonzero shift recorded in ``_fusion_log``) and the
    result is BIT-exact against unfused sequential execution — fusion only
    reorders whole operations, it never reassociates arithmetic;
  * the fused program still schedules, passes the brute-force
    ``validate_schedule`` oracle, and ``timed_exec`` agrees with
    ``sequential_exec``;
  * legality negatives: chains whose dependence distance grows with the
    problem size (backward-flowing) admit no finite shift and must be
    refused;
  * the fused schedule beats the unfused one on the chain corpus (the
    paper's producer-consumer pipelining claim, Fig. 7).

Full-size variants run under ``-m slow`` (weekly CI).
"""
import numpy as np
import pytest

from repro.core.autotune import compile_program
from repro.core.ir import ProgramBuilder
from repro.core.programs import CHAIN_BENCHMARKS
from repro.core.sim import (make_inputs, sequential_exec, timed_exec,
                            validate_schedule)
from repro.core.transforms import FuseProducerConsumer, PassManager

_SMALL = {"blur_chain": 8, "conv_pool": 8, "gradient_harris": 6,
          "correlated_chain": 8}

# the minimum legal shift of each chain (independent of n for finite-shift
# chains — that is what makes them fusable — except conv_pool's rate
# mismatch, whose shift is n/2).  correlated_chain pins the LEXICOGRAPHIC
# minimum: distances (2,0) and (0,5) must shift by their lex-max (2,0),
# not the componentwise maxima (2,5).
_EXPECT_SHIFT = {"blur_chain": lambda n: [2, 0],
                 "conv_pool": lambda n: [n // 2, n // 2],
                 "gradient_harris": lambda n: [2, 2],
                 "correlated_chain": lambda n: [2, 0]}


def _bit_exact(p, q, seed=0):
    inp = make_inputs(p, seed)
    got = sequential_exec(q, {k: v.copy() for k, v in inp.items()})
    want = sequential_exec(p, inp)
    for k in want:
        assert np.array_equal(want[k], got[k]), f"array {k} not bit-exact"


# ---------------------------------------------------------------------------
# Chain corpus
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(CHAIN_BENCHMARKS))
@pytest.mark.parametrize("storage", ["reg", "bram"])
def test_chain_fuses_bit_exact(name, storage):
    n = _SMALL[name]
    p = CHAIN_BENCHMARKS[name](n, storage=storage)
    q = PassManager([FuseProducerConsumer()], verify=True).run(p)
    assert q is not p, "mismatched-bounds chain must fuse"
    log = q._fusion_log
    assert log and log[0]["shift"] == _EXPECT_SHIFT[name](n)
    assert log[0]["peels"] >= 1
    _bit_exact(p, q)
    _bit_exact(p, q, seed=1)


@pytest.mark.parametrize("name", sorted(CHAIN_BENCHMARKS))
def test_chain_fused_schedule_valid_and_faster(name):
    n = _SMALL[name]
    p = CHAIN_BENCHMARKS[name](n, storage="bram")
    q = PassManager([FuseProducerConsumer()], verify=True).run(p)
    s_unfused = compile_program(p)
    s = compile_program(q)
    assert s.feasible
    assert validate_schedule(q, s) == []
    inp = make_inputs(q, 0)
    got, want = timed_exec(q, s, inp), sequential_exec(q, inp)
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-12, err_msg=k)
    # producer-consumer overlap must not regress the unfused schedule
    assert s.completion_time() <= s_unfused.completion_time(), name


def test_noshift_variant_cannot_fuse_chains():
    """The equal-bounds-only variant must leave every chain alone — the
    chains exist precisely because their bounds differ."""
    for name, mk in CHAIN_BENCHMARKS.items():
        p = mk(_SMALL[name])
        assert FuseProducerConsumer(enable_shift=False).apply(p) is p, name


def test_lexicographic_shift_beats_componentwise():
    """correlated_chain's distance vectors are (2,0) and (0,5): the lex
    shift fuses at (2,0) whose fused core covers the FULL consumer column
    range; the componentwise maxima (2,5) would also be legal but delay
    every row by 5 columns.  The lex fusion must (a) record shift [2,0],
    (b) stay bit-exact, and (c) schedule no slower than a fusion forced to
    the componentwise shift would."""
    from repro.core.programs import correlated_chain
    from repro.core.transforms import _fusion_hazard, _perfect_chain

    p = correlated_chain(8)
    q = PassManager([FuseProducerConsumer()], verify=True).run(p)
    assert q is not p
    assert q._fusion_log[0]["shift"] == [2, 0]
    # full-column core: no column peel at the inner level
    assert q._fusion_log[0]["core_trips"] == [8, 8]
    _bit_exact(p, q)
    # the componentwise shift (2, 5) is ALSO legal (it over-covers) — prove
    # the overshoot is real and that the lex choice is the smaller one
    a, b = p.body
    loopsA, _ = _perfect_chain(a)
    loopsB, _ = _perfect_chain(b)
    pairs = FuseProducerConsumer()._candidate(a, b)[2]
    assert not any(_fusion_hazard(oa, ob, loopsA, loopsB, [2, 5])
                   for oa, ob in pairs)
    assert not any(_fusion_hazard(oa, ob, loopsA, loopsB, [2, 0])
                   for oa, ob in pairs)
    assert any(_fusion_hazard(oa, ob, loopsA, loopsB, [1, 99])
               for oa, ob in pairs)


def test_two_mm_unprofitable_shift_is_refused():
    """two_mm's tmp dependence distance spans the whole j/k space: the
    legal shift leaves a single-iteration core, which the profitability
    gate must refuse (fusing would serialize, not pipeline)."""
    from repro.core.programs import two_mm
    p = two_mm(6)
    assert FuseProducerConsumer().apply(p) is p


# ---------------------------------------------------------------------------
# Random mismatched-bounds chains
# ---------------------------------------------------------------------------


def random_mismatched_chain(seed):
    """Producer over (H+dh, W+dw) writes X; consumer over (H, W) reads X at
    forward offsets (o1, o2) — the minimum legal shift — plus (0, 0)."""
    rng = np.random.default_rng(9000 + seed)
    H, W = int(rng.integers(4, 8)), int(rng.integers(4, 8))
    dh, dw = int(rng.integers(1, 4)), int(rng.integers(0, 4))
    o1 = int(rng.integers(0, dh + 1))
    o2 = int(rng.integers(0, dw + 1))
    fn = ["add", "mul", "sub"][int(rng.integers(0, 3))]
    b = ProgramBuilder(f"mchain{seed}")
    PH, PW = H + dh, W + dw
    b.array("inp", (PH + 1, PW + 1), is_arg=True, partition=(0, 1),
            ports=("w", "r"))
    b.array("X", (PH, PW), partition=(0, 1), ports=("w", "r"))
    b.array("out", (H, W), is_arg=True, partition=(0, 1), ports=("w", "r"))
    with b.loop("pi", 0, PH) as i:
        with b.loop("pj", 0, PW) as j:
            v = b.arith(fn, b.load("inp", i, j), b.load("inp", i + 1, j + 1))
            b.store("X", v, i, j)
    with b.loop("ci", 0, H) as i:
        with b.loop("cj", 0, W) as j:
            x = b.load("X", i + o1, j + o2)
            y = b.load("X", i, j)
            b.store("out", b.mul(b.arith(fn, x, y), b.const(0.5)), i, j)
    return b.build(), (o1, o2)


@pytest.mark.parametrize("seed", range(30))
def test_random_mismatched_chain_fusion(seed):
    p, (o1, o2) = random_mismatched_chain(seed)
    q = PassManager([FuseProducerConsumer()], verify=True).run(p)
    assert q is not p
    assert q._fusion_log[0]["shift"] == [o1, o2]
    _bit_exact(p, q, seed=seed)
    s = compile_program(q)
    assert s.feasible
    assert validate_schedule(q, s) == []
    inp = make_inputs(q, seed)
    got, want = timed_exec(q, s, inp), sequential_exec(q, inp)
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-12, err_msg=k)


@pytest.mark.parametrize("seed", range(8))
def test_random_backward_chain_rejected(seed):
    """Backward-flowing variants (consumer reads reversed rows) of the same
    random chains: no finite shift exists, the pass must refuse."""
    rng = np.random.default_rng(7000 + seed)
    n = int(rng.integers(4, 8))
    b = ProgramBuilder(f"bchain{seed}")
    b.array("inp", (n + 1, n + 1), is_arg=True, partition=(0, 1),
            ports=("w", "r"))
    b.array("X", (n, n), partition=(0, 1), ports=("w", "r"))
    b.array("out", (n, n), is_arg=True, partition=(0, 1), ports=("w", "r"))
    with b.loop("pi", 0, n) as i:
        with b.loop("pj", 0, n) as j:
            b.store("X", b.add(b.load("inp", i, j), b.load("inp", i + 1, j)),
                    i, j)
    rev_rows = bool(rng.integers(0, 2))
    with b.loop("ci", 0, n) as i:
        with b.loop("cj", 0, n) as j:
            idx = ((n - 1) - i, j) if rev_rows else (i, (n - 1) - j)
            b.store("out", b.mul(b.load("X", *idx), b.const(0.5)), i, j)
    p = b.build()
    assert FuseProducerConsumer().apply(p) is p


# ---------------------------------------------------------------------------
# Resource model: peels share the fused datapath
# ---------------------------------------------------------------------------


def test_peeled_fusion_is_dsp_neutral():
    """Shift-and-peel fusion replicates ops into peel nests, but those run
    on the fused core's guarded datapath: the resource model must report the
    same DSP count as the unfused program."""
    from repro.core.dataflow import resources
    p = CHAIN_BENCHMARKS["blur_chain"](8, storage="bram")
    q = PassManager([FuseProducerConsumer()], verify=True).run(p)
    assert any(getattr(l, "peel", False)
               for l in q.body), "expected a top-level peel nest"
    rp = resources(p, compile_program(p), "ours")
    rq = resources(q, compile_program(q), "ours")
    assert rq["dsp"] == rp["dsp"]
    assert rq["bram_bytes"] == rp["bram_bytes"]


# ---------------------------------------------------------------------------
# Full-size variants (weekly tier)
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(CHAIN_BENCHMARKS))
def test_chain_fusion_fullsize(name):
    p = CHAIN_BENCHMARKS[name](storage="bram")
    q = PassManager([FuseProducerConsumer()], verify=True).run(p)
    assert q is not p
    _bit_exact(p, q)
    s_unfused = compile_program(p)
    s = compile_program(q)
    assert s.feasible
    assert s.completion_time() < s_unfused.completion_time()

"""Whisper (enc-dec) and PaliGemma (VLM) specific behaviour."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.models import lm


@pytest.mark.slow
def test_whisper_decode_matches_teacher_forcing():
    cfg = dataclasses.replace(get_config("whisper_small", reduced=True),
                              dtype="float32")
    params = lm.init_params(cfg, jax.random.key(0))
    B, S = 2, 6
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    frames = jnp.asarray(rng.normal(size=(B, cfg.enc_seq, cfg.d_model)),
                         jnp.float32)
    full = lm.forward(cfg, params, {"tokens": tokens, "frames": frames})
    cache = lm.init_cache(cfg, B, S)
    logits = None
    for t in range(S):
        batch = {"token": tokens[:, t:t + 1],
                 "pos": jnp.full((B,), t, jnp.int32),
                 "frames": frames}
        logits, cache = lm.decode_step(cfg, params, cache, batch)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-3, atol=2e-3)


def test_whisper_encoder_is_used():
    cfg = dataclasses.replace(get_config("whisper_small", reduced=True),
                              dtype="float32")
    params = lm.init_params(cfg, jax.random.key(1))
    B, S = 1, 4
    tokens = jnp.zeros((B, S), jnp.int32)
    f1 = jnp.zeros((B, cfg.enc_seq, cfg.d_model))
    f2 = jnp.ones((B, cfg.enc_seq, cfg.d_model))
    l1 = lm.forward(cfg, params, {"tokens": tokens, "frames": f1})
    l2 = lm.forward(cfg, params, {"tokens": tokens, "frames": f2})
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-6


def test_vlm_patches_shift_text_logits():
    cfg = dataclasses.replace(get_config("paligemma_3b", reduced=True),
                              dtype="float32")
    params = lm.init_params(cfg, jax.random.key(2))
    B = 1
    n_txt = 8
    tokens = jnp.zeros((B, n_txt), jnp.int32)
    p1 = jnp.zeros((B, cfg.n_img_tokens, cfg.d_model))
    p2 = jnp.ones((B, cfg.n_img_tokens, cfg.d_model))
    l1 = lm.forward(cfg, params, {"tokens": tokens, "patches": p1})
    l2 = lm.forward(cfg, params, {"tokens": tokens, "patches": p2})
    assert l1.shape == (B, n_txt, cfg.vocab)  # text positions only
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-6

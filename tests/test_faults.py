"""Chaos suite for the fault-injection harness (DESIGN.md §9).

Every test runs under ``tests/conftest.py``'s SIGALRM guard, so "the compiler
terminated" is enforced by the suite itself: a hang fails the test instead of
stalling tier-1.  The scenarios mirror the failure-handling contract:

* truncated solvers degrade to conservative (sound) bounds, never infeasible;
* worker crashes/hangs are retried, rebuilt around, or quarantined — and the
  frontier stays bit-identical to serial whenever the faults were recovered;
* torn/corrupt cache blobs are detected, discarded and recompiled;
* a faulted ``hls.compile`` either reproduces the fault-free frontier exactly
  or labels the result ``provenance="degraded"`` with diagnostics.
"""
import importlib
import json
import os

import pytest

from repro.core import (CacheFault, CompileError, DepAnalysis,
                        ScheduleInfeasible, SolverTruncated, WorkerFault,
                        faults, hls, schedule)
from repro.core.cache import CacheStore
from repro.core.ilp import solve_ilp
from repro.core.programs import CHAIN_BENCHMARKS, blur_chain
from repro.core.transforms import FuseProducerConsumer, differential_check

autotune_mod = importlib.import_module("repro.core.autotune")
sim = importlib.import_module("repro.core.sim")


def _frontier_sig(r):
    """Everything observable about a frontier, for byte-identity checks.
    Op uids are normalized to program walk order so signatures compare
    across independently built (but structurally identical) programs."""
    out = []
    for c in r.frontier:
        prog = c.schedule.program
        order = {n.uid: i for i, (n, _) in enumerate(prog.walk())}
        out.append((c.desc, int(c.latency), tuple(sorted(c.res.items())),
                    tuple(sorted((order[u], v)
                                 for u, v in c.schedule.iis.items())),
                    tuple(sorted((order[u], t)
                                 for u, t in c.schedule.theta.items()))))
    return out


def _search(max_candidates=6, **kw):
    kw.setdefault("cache", False)
    return hls.SearchConfig(max_candidates=max_candidates, **kw)


# ---------------------------------------------------------------------------
# The plan itself: determinism, scoping, serialization
# ---------------------------------------------------------------------------


def test_should_fire_is_content_keyed_and_deterministic():
    with faults.inject(seed=7, worker_crash=0.5):
        first = [faults.should_fire("worker_crash", key=f"cand-{i}")
                 for i in range(64)]
    with faults.inject(seed=7, worker_crash=0.5):
        # different consultation order, same keys -> same decisions
        second = {i: faults.should_fire("worker_crash", key=f"cand-{i}")
                  for i in reversed(range(64))}
    assert first == [second[i] for i in range(64)]
    assert any(first) and not all(first)  # rate 0.5 actually splits
    with faults.inject(seed=8, worker_crash=0.5):
        third = [faults.should_fire("worker_crash", key=f"cand-{i}")
                 for i in range(64)]
    assert third != first  # the seed matters


def test_should_fire_rate_extremes_and_script():
    with faults.inject(seed=0, solver_timeout=1.0):
        assert faults.should_fire("solver_timeout", key="x")
        assert not faults.should_fire("worker_crash", key="x")  # rate 0
    with faults.inject(seed=0, script=(("worker_crash", (1, 3)),)):
        fired = [faults.should_fire("worker_crash") for _ in range(5)]
    assert fired == [False, True, False, True, False]


def test_inject_scopes_and_restores():
    assert faults.active() is None
    outer_env = os.environ.get(faults.ENV_VAR)
    with faults.inject(seed=1, cache_corrupt=0.5) as plan:
        assert faults.active() is plan
        assert os.environ[faults.ENV_VAR] == plan.to_json()
        with faults.inject(seed=2, worker_hang=1.0) as inner:
            assert faults.active() is inner
        assert faults.active() is plan
    assert faults.active() is None
    assert os.environ.get(faults.ENV_VAR) == outer_env


def test_plan_json_roundtrip():
    plan = faults.FaultPlan(seed=9, solver_timeout=0.25, worker_crash=0.5,
                            hang_seconds=1.5, crash_attempts=(0, 2),
                            script=(("cache_corrupt", (4,)),))
    assert faults.FaultPlan.from_json(plan.to_json()) == plan


def test_error_taxonomy():
    for sub in (ScheduleInfeasible, SolverTruncated, WorkerFault, CacheFault):
        assert issubclass(sub, CompileError)
    assert issubclass(CompileError, Exception)


# ---------------------------------------------------------------------------
# Solver: injected timeouts produce honest anytime statuses
# ---------------------------------------------------------------------------


def test_injected_solver_timeout_truncates_any_problem():
    # fault-free: a trivially optimal problem
    r = solve_ilp([1.0, 1.0], bounds=[(0, 3), (0, 3)])
    assert r.status == "optimal"
    with faults.inject(seed=0, solver_timeout=1.0):
        r = solve_ilp([1.0, 1.0], bounds=[(0, 3), (0, 3)])
    assert r.status == "timeout" and r.truncated and not r.ok
    # deadline struck right after the relaxation: a bound, no incumbent
    assert r.x is None
    assert r.bound is not None and r.bound <= 0.0 + 1e-9


def test_injected_timeout_is_deterministic_per_problem():
    probs = [([float(i), 1.0], [(0, i + 1), (0, 3)]) for i in range(20)]
    with faults.inject(seed=5, solver_timeout=0.5):
        a = [solve_ilp(c, bounds=b).status for c, b in probs]
    with faults.inject(seed=5, solver_timeout=0.5):
        b_ = [solve_ilp(c, bounds=b).status for c, b in reversed(probs)]
    assert a == list(reversed(b_))
    assert set(a) == {"optimal", "timeout"}  # rate 0.5 splits


# ---------------------------------------------------------------------------
# Dependence analysis + scheduler: sound conservative degradation
# ---------------------------------------------------------------------------


def test_deps_degrade_conservative_and_sound():
    p = blur_chain(8, storage="bram")
    dep = DepAnalysis(p, fastpath=False)
    iis = autotune_mod.autotune(p, dep)
    s_exact = schedule(p, iis, dep)
    assert s_exact.feasible and s_exact.provenance == "exact"

    with faults.inject(seed=3, solver_timeout=1.0):
        p2 = blur_chain(8, storage="bram")
        dep_d = DepAnalysis(p2, fastpath=False)
        # truncated slacks may over-serialize: let the autotuner re-find
        # feasible IIs under the degraded bounds, as compile_program would
        iis_d = autotune_mod.autotune(p2, dep_d)
        s_d = schedule(p2, iis_d, dep_d)
        assert dep_d.degradations, "full truncation must degrade some slack"
        assert s_d.provenance == "degraded"
        assert s_d.feasible, "degraded bounds must stay schedulable"
        # soundness: the over-serialized schedule still honors every real
        # dependence and port constraint
        assert sim.validate_schedule(p2, s_d) == []
        # conservatism: degraded bounds can only slow the design down
        assert s_d.completion_time() >= s_exact.completion_time()


def test_degradation_recorded_once_per_case():
    with faults.inject(seed=3, solver_timeout=1.0):
        p = blur_chain(8, storage="bram")
        dep = DepAnalysis(p, fastpath=False)
        autotune_mod.autotune(p, dep)  # many probes over the same cases
        keys = [(d["src"], d["snk"], d["carry"]) for d in dep.degradations]
        assert len(keys) == len(set(keys))
        for d in dep.degradations:
            assert d["status"] in ("feasible", "timeout")


def test_fusion_under_truncation_stays_correct():
    p = blur_chain(8, storage="bram")
    with faults.inject(seed=2, solver_timeout=1.0):
        q = FuseProducerConsumer().apply(blur_chain(8, storage="bram"))
        # whatever the conservative legality checks decided, the transformed
        # program must still compute the same function
        differential_check(p, q, seeds=(0,))


# ---------------------------------------------------------------------------
# Cache: torn writes and corrupt reads are detected and repaired
# ---------------------------------------------------------------------------


def test_cache_torn_put_detected_on_next_get(tmp_path):
    store = CacheStore(str(tmp_path))
    with faults.inject(seed=0, cache_corrupt=1.0):
        store.put("deadbeef", {"v": 1})  # writer "dies" mid-write
    fresh = CacheStore(str(tmp_path))
    assert fresh.get("deadbeef") is None
    assert fresh.repairs == 1
    # the poisoned entry was unlinked: the next get is a clean miss
    assert fresh.get("deadbeef") is None and fresh.repairs == 1
    fresh.put("deadbeef", {"v": 2})
    assert CacheStore(str(tmp_path)).get("deadbeef") == {"v": 2}


def test_cache_corrupt_get_repairs_and_recovers(tmp_path):
    store = CacheStore(str(tmp_path))
    store.put("cafebabe", {"v": [1, 2, 3]})
    fresh = CacheStore(str(tmp_path))
    with faults.inject(seed=0, cache_corrupt=1.0):
        assert fresh.get("cafebabe") is None  # torn read detected
    assert fresh.repairs == 1
    assert fresh.stats()["repairs"] == 1
    # entry was discarded; a clean re-put round-trips again
    fresh.put("cafebabe", {"v": 4})
    assert CacheStore(str(tmp_path)).get("cafebabe") == {"v": 4}


def test_cache_checksum_catches_bit_flip(tmp_path):
    store = CacheStore(str(tmp_path))
    store.put("abcd1234", {"latency": 100})
    path = store._path("abcd1234")
    raw = open(path).read()
    flipped = raw.replace("100", "999")
    assert flipped != raw
    with open(path, "w") as f:
        f.write(flipped)
    fresh = CacheStore(str(tmp_path))
    assert fresh.get("abcd1234") is None  # checksum mismatch -> repair
    assert fresh.repairs == 1


def test_cache_wrapper_carries_checksum(tmp_path):
    store = CacheStore(str(tmp_path))
    store.put("0123abcd", {"x": 1.5})
    wrapper = json.load(open(store._path("0123abcd")))
    assert set(wrapper) >= {"salt", "sum", "data"}
    assert wrapper["sum"] == CacheStore._checksum(
        json.dumps(wrapper["data"], separators=(",", ":")))


# ---------------------------------------------------------------------------
# Supervised parallel DSE: crash / hang / hard-crash / quarantine
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def clean_frontier():
    r = hls.compile(blur_chain(), search=_search())
    assert r.provenance == "exact"
    return _frontier_sig(r)


def test_worker_crash_once_recovers_identically(clean_frontier):
    with faults.inject(seed=0, worker_crash=1.0, crash_attempts=(0,)):
        r = hls.compile(blur_chain(), search=_search(jobs=2))
    # every first attempt crashed, every retry succeeded: recovered faults
    # must not move the frontier or taint provenance
    assert _frontier_sig(r) == clean_frontier
    assert r.provenance == "exact" and not r.degraded
    kinds = {d["kind"] for d in r.diagnostics}
    assert "worker-retry" in kinds


def test_worker_always_crashing_quarantines(clean_frontier):
    with faults.inject(seed=0, worker_crash=1.0):
        r = hls.compile(blur_chain(), search=_search(jobs=2))
    assert any("worker-fault" in reason for _, reason in r.rejected)
    assert r.degraded  # quarantine may have hidden frontier points
    assert any(d["kind"] == "worker-quarantine" for d in r.diagnostics)


def test_worker_hang_deadline_then_recovery(clean_frontier):
    with faults.inject(seed=0, worker_hang=1.0, hang_attempts=(0,),
                       hang_seconds=20.0):
        r = hls.compile(blur_chain(),
                        search=_search(jobs=2, worker_deadline_s=0.75))
    assert _frontier_sig(r) == clean_frontier
    assert r.provenance == "exact"
    assert any(d["kind"] == "worker-hang" for d in r.diagnostics)


def test_worker_hard_crash_pool_rebuild(clean_frontier):
    with faults.inject(seed=0, worker_crash_hard=1.0, crash_attempts=(0,)):
        r = hls.compile(blur_chain(), search=_search(jobs=2))
    assert _frontier_sig(r) == clean_frontier
    assert r.provenance == "exact"
    assert any(d["kind"] == "pool-broken" for d in r.diagnostics)


# ---------------------------------------------------------------------------
# End-to-end chaos acceptance: identical-or-labeled, deterministic
# ---------------------------------------------------------------------------

_CHAOS_PLANS = [
    dict(seed=0, solver_timeout=0.4),
    dict(seed=1, solver_timeout=0.2, cache_corrupt=0.3),
    dict(seed=2, solver_timeout=1.0),
]


def _chaos_once(make_program, plan, **search_kw):
    with faults.inject(**plan):
        return hls.compile(make_program(), search=_search(**search_kw))


@pytest.mark.parametrize("plan", _CHAOS_PLANS,
                         ids=[f"seed{p['seed']}" for p in _CHAOS_PLANS])
def test_chaos_identical_or_labeled(clean_frontier, plan):
    r = _chaos_once(blur_chain, plan)
    if _frontier_sig(r) != clean_frontier:
        assert r.degraded, \
            "divergent frontier without degraded provenance is unsound"
        assert any(d["kind"] in faults.DEGRADING_KINDS
                   for d in r.diagnostics), r.diagnostics
        for c in r.frontier:
            assert c.schedule.feasible
    else:
        # byte-identical results need no degraded label even if recovered
        # faults fired along the way
        pass


def test_chaos_deterministic_for_fixed_seed():
    plan = dict(seed=1, solver_timeout=0.4)
    a = _chaos_once(blur_chain, plan)
    b = _chaos_once(blur_chain, plan)
    assert _frontier_sig(a) == _frontier_sig(b)
    assert a.provenance == b.provenance
    assert a.rejected == b.rejected
    assert [d["kind"] for d in a.diagnostics] == \
        [d["kind"] for d in b.diagnostics]


def test_chaos_with_persistent_cache(tmp_path, monkeypatch, clean_frontier):
    monkeypatch.setenv("REPRO_HLS_CACHE", "1")
    monkeypatch.setenv("REPRO_HLS_CACHE_DIR", str(tmp_path))
    # degraded run first: whatever it computed must NOT poison the store
    r_fault = _chaos_once(blur_chain, dict(seed=2, solver_timeout=1.0),
                          cache=True)
    assert r_fault.degraded
    r_clean = hls.compile(blur_chain(), search=_search(cache=True))
    assert r_clean.provenance == "exact"
    assert _frontier_sig(r_clean) == clean_frontier


def test_chaos_cache_disabled_still_completes():
    # conftest pins REPRO_HLS_CACHE=0; faults must not reintroduce a need
    # for the store
    assert os.environ.get("REPRO_HLS_CACHE") == "0"
    r = _chaos_once(blur_chain, dict(seed=0, solver_timeout=0.5,
                                     cache_corrupt=0.5))
    assert r.frontier or r.degraded


def test_explain_reports_diagnostics():
    r = _chaos_once(blur_chain, dict(seed=2, solver_timeout=1.0))
    assert r.degraded
    text = r.explain()
    assert "diagnostics (degraded)" in text
    assert "solver-degraded" in text or "fusion-hazard-degraded" in text


def _generalized_shape_corpus():
    """Imperfect and scan-style multi-loop tasks (the generalized nest
    contract) — the chaos acceptance property must hold beyond perfect
    nests."""
    from test_deps_fastpath import (_random_imperfect_program,
                                    _random_multiloop_program)

    return [("imperfect", lambda: _random_imperfect_program(3)),
            ("multi_loop", lambda: _random_multiloop_program(3))]


@pytest.mark.parametrize("kind,mk", _generalized_shape_corpus(),
                         ids=lambda v: v if isinstance(v, str) else "")
def test_chaos_identical_or_labeled_generalized_shapes(kind, mk):
    clean = hls.compile(mk(), search=_search())
    assert clean.provenance == "exact"
    ref = _frontier_sig(clean)
    for plan in _CHAOS_PLANS:
        r = _chaos_once(mk, plan)
        if _frontier_sig(r) != ref:
            assert r.degraded, (kind, plan)
            for c in r.frontier:
                assert c.schedule.feasible


@pytest.mark.slow
@pytest.mark.timeout(1800)
@pytest.mark.parametrize("name", sorted(CHAIN_BENCHMARKS))
def test_chaos_sweep_chain_benchmarks(name):
    mk = CHAIN_BENCHMARKS[name]
    clean = hls.compile(mk(), search=_search())
    ref = _frontier_sig(clean)
    for seed in range(4):
        for plan in (dict(seed=seed, solver_timeout=0.3),
                     dict(seed=seed, solver_timeout=0.7, cache_corrupt=0.5)):
            r = _chaos_once(mk, plan)
            if _frontier_sig(r) != ref:
                assert r.degraded, (name, plan)


@pytest.mark.slow
@pytest.mark.timeout(1800)
@pytest.mark.parametrize("seed", range(4))
def test_chaos_sweep_generalized_shapes(seed):
    from test_deps_fastpath import (_random_imperfect_program,
                                    _random_multiloop_program)

    for mk_seeded in (_random_imperfect_program, _random_multiloop_program):
        mk = lambda: mk_seeded(seed)  # noqa: E731
        clean = hls.compile(mk(), search=_search())
        ref = _frontier_sig(clean)
        for plan in (dict(seed=seed, solver_timeout=0.3),
                     dict(seed=seed, solver_timeout=0.7, cache_corrupt=0.5)):
            r = _chaos_once(mk, plan)
            if _frontier_sig(r) != ref:
                assert r.degraded, (mk_seeded.__name__, plan)

"""The HLO-text cost analyzer vs ground truth on while-free modules, and
trip-count recovery on scanned modules."""
import jax
import jax.numpy as jnp

from repro.launch import hlo_analysis as ha


def _compiled(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_dot_flops_exact_matmul():
    a = jnp.zeros((64, 128), jnp.float32)
    b = jnp.zeros((128, 32), jnp.float32)
    c = _compiled(lambda x, y: x @ y, a, b)
    res = ha.analyze(c.as_text())
    assert res["flops"] == 2 * 64 * 128 * 32


def test_scan_trip_count_multiplies():
    w = jnp.zeros((8, 16, 16), jnp.float32)
    x = jnp.zeros((4, 16), jnp.float32)

    def fn(w, x):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    c = _compiled(fn, w, x)
    res = ha.analyze(c.as_text())
    assert 8 in res["trips"].values()
    assert res["flops"] == 8 * 2 * 4 * 16 * 16


def test_batched_dot_contraction():
    a = jnp.zeros((2, 8, 32), jnp.float32)
    b = jnp.zeros((2, 32, 4), jnp.float32)
    c = _compiled(lambda x, y: jnp.einsum("bij,bjk->bik", x, y), a, b)
    res = ha.analyze(c.as_text())
    assert res["flops"] == 2 * 2 * 8 * 32 * 4


def test_bytes_counted_for_copies():
    x = jnp.zeros((1024,), jnp.float32)
    c = _compiled(lambda v: v * 2.0 + 1.0, x)
    res = ha.analyze(c.as_text())
    assert res["bytes"] >= 2 * 1024 * 4  # at least read + write

"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(the kernel body executes in Python on CPU; BlockSpecs and grids are real)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("B,H,S,hd", [(1, 2, 128, 64), (2, 1, 256, 128),
                                      (1, 4, 384, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, H, S, hd, dtype, causal):
    ks = jax.random.split(jax.random.key(0), 3)
    q = _rand(ks[0], (B, H, S, hd), dtype)
    k = _rand(ks[1], (B, H, S, hd), dtype)
    v = _rand(ks[2], (B, H, S, hd), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, block_q=128, block_k=128,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("H,W,br", [(18, 32, 8), (34, 130, 4), (10, 16, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stencil_pipeline_sweep(H, W, br, dtype):
    key = jax.random.key(1)
    img = _rand(key, (H, W), dtype)
    wx = jnp.asarray([0.25, 0.5, 0.25], dtype)
    wy = jnp.asarray([0.25, 0.5, 0.25], dtype)
    got = ops.stencil_pipeline(img, wx, wy, block_rows=br, interpret=True)
    want = ref.stencil_pipeline_ref(img.astype(jnp.float32),
                                    wx.astype(jnp.float32),
                                    wy.astype(jnp.float32))
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=tol, atol=tol)


def test_stencil_pipeline_single_implementation():
    """ops.stencil_pipeline and stencil_pipeline.stencil_pipeline used to be
    two diverging definitions; both import paths must now resolve to the
    same function object."""
    from repro.kernels import stencil_pipeline as spmod
    assert ops.stencil_pipeline is spmod.stencil_pipeline
    assert ops.ilp_halo_rows is spmod.ilp_halo_rows
    assert ops.stencil_dse_config is spmod.stencil_dse_config


def test_stencil_config_from_dse_sweep():
    """The kernel's block/halo config is read off the generated kernel of
    the DSE knee point (emit_pallas): the winning fusion's row shift is the
    halo.  It must agree with the (demoted, fallback-only) fixed probe for
    the 3-tap chain — and must actually have COME from the sweep, not from
    the fallback quietly returning the same values.  The old entry point
    survives as a deprecated wrapper with the same values."""
    from repro.kernels.stencil_pipeline import (_stencil_codegen_config,
                                                stencil_config_source)
    block_rows, halo = _stencil_codegen_config()
    assert stencil_config_source() == "dse"
    assert halo == 2 == ops.ilp_halo_rows(3)
    assert block_rows >= 1
    with pytest.warns(DeprecationWarning, match="emit_pallas"):
        assert ops.stencil_dse_config() == (block_rows, halo)


def test_stencil_pipeline_dse_default_config():
    """Calling the kernel without an explicit block/halo must route through
    the DSE-derived config and still match the oracle."""
    key = jax.random.key(4)
    img = _rand(key, (18, 34), jnp.float32)
    wx = jnp.asarray([0.25, 0.5, 0.25])
    wy = jnp.asarray([0.2, 0.6, 0.2])
    got = ops.stencil_pipeline(img, wx, wy, interpret=True)
    want = ref.stencil_pipeline_ref(img, wx, wy)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,H,S,hd,chunk", [(1, 2, 128, 64, 64),
                                            (2, 1, 256, 32, 32),
                                            (1, 1, 64, 16, 16)])
def test_wkv6_sweep(B, H, S, hd, chunk):
    ks = jax.random.split(jax.random.key(2), 4)
    r = _rand(ks[0], (B, H, S, hd), jnp.float32)
    k = _rand(ks[1], (B, H, S, hd), jnp.float32)
    v = _rand(ks[2], (B, H, S, hd), jnp.float32)
    w = jax.nn.sigmoid(_rand(ks[3], (B, H, S, hd), jnp.float32)) * 0.5 + 0.45
    u = _rand(jax.random.key(3), (H, hd), jnp.float32) * 0.1
    got = ops.wkv6(r, k, v, w, u, chunk=chunk, interpret=True)
    want, _ = ref.wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_wkv6_matches_model_layer():
    """The kernel must agree with the chunked jnp implementation used by the
    rwkv6 model layer (same math, different engine)."""
    import dataclasses
    from repro.config import get_config
    from repro.models import layers as L

    cfg = dataclasses.replace(get_config("rwkv6_3b", reduced=True),
                              dtype="float32")
    B, S = 1, 64
    D = cfg.d_model
    Hh = D // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    ks = jax.random.split(jax.random.key(5), 4)
    r, k, v = (jax.random.normal(ks[i], (B, Hh, S, hd)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, Hh, S, hd))) * 0.5 + 0.45
    u = jnp.zeros((Hh, hd))
    s0 = jnp.zeros((B, Hh, hd, hd))
    out_model, _ = L._wkv_chunk(r, k, v, w, u, s0)
    out_kernel = ops.wkv6(r, k, v, w, u, chunk=S, interpret=True)
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_model),
                               rtol=1e-4, atol=1e-4)

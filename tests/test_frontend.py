"""Tracing frontend (DESIGN.md §11): jaxpr -> Program IR, differentially
validated against the source kernel, and searchable by the DSE.

The three bundled traced kernels are the acceptance gate for the
generalized loop-nest contract: the wkv6 scan traces to a ``multi_loop``
task (time loop carrying a 2-D state), and all three must both match their
source function bit-tightly under ``sequential_exec`` and yield a
multi-point Pareto frontier from ``hls.compile``.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

import jax.numpy as jnp  # noqa: E402

from repro.core import hls  # noqa: E402
from repro.core.errors import UntraceableFunction  # noqa: E402
from repro.core.frontend import (TracedProgram, attention_program,  # noqa: E402
                                 conv_block_program, trace, wkv6_program)
from repro.core.ir import nest_shape  # noqa: E402

TRACED = {
    "wkv6": wkv6_program,
    "conv_block": conv_block_program,
    "attention": attention_program,
}


@pytest.fixture(scope="module")
def traced():
    return {name: mk() for name, mk in TRACED.items()}


# ---------------------------------------------------------------------------
# tracing basics
# ---------------------------------------------------------------------------


def test_trace_returns_traced_program(traced):
    for name, tp in traced.items():
        assert isinstance(tp, TracedProgram), name
        assert tp.program.body, name
        assert all(n in tp.program.arrays for n in tp.in_names), name
        assert all(n in tp.program.arrays for n in tp.out_names), name
        # inputs and outputs are visible kernel arguments
        for n in tp.in_names + tp.out_names:
            assert tp.program.arrays[n].is_arg, (name, n)


def test_wkv6_traces_to_multi_loop_task(traced):
    """The scan's time loop carries a 2-D state nest -> a multi_loop task,
    the shape the generalized contract exists for."""
    kinds = nest_shape(traced["wkv6"].program).kinds
    assert "multi_loop" in kinds, kinds


def test_conv_and_attention_trace_to_perfect_nests(traced):
    for name in ("conv_block", "attention"):
        sh = nest_shape(traced[name].program)
        assert sh.all_perfect, (name, sh.kinds)


def test_scalar_constant_folding():
    """Pure-constant subexpressions fold at trace time, not into nests."""
    def f(x):
        return x * (2.0 * 3.0)

    tp = trace(f, np.zeros((4,), np.float32))
    assert tp.validate() <= 1e-12


def test_untraceable_primitive_raises():
    def f(x):
        return jnp.sin(x)

    with pytest.raises(UntraceableFunction, match="sin"):
        trace(f, np.zeros((4,), np.float32))


def test_untraceable_reshape_raises():
    def f(x):
        return x.reshape(2, 2)

    with pytest.raises(UntraceableFunction, match="reshape"):
        trace(f, np.zeros((4,), np.float32))


def test_lazy_core_exports():
    from repro.core import TracedProgram as TP2
    from repro.core import trace as trace2
    assert trace2 is trace and TP2 is TracedProgram


# ---------------------------------------------------------------------------
# differential validation: traced Program == source kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(TRACED))
def test_traced_program_matches_source_kernel(name, traced):
    err = traced[name].validate(seed=0, rtol=1e-12)
    assert err <= 1e-12


@pytest.mark.parametrize("seed", [1, 2])
def test_wkv6_validation_across_seeds(seed, traced):
    assert traced["wkv6"].validate(seed=seed, rtol=1e-12) <= 1e-12


# ---------------------------------------------------------------------------
# DSE acceptance: every traced kernel yields a multi-point frontier
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(TRACED))
def test_traced_program_compiles_to_multipoint_frontier(name, traced):
    res = hls.compile(traced[name].program, objectives=("latency", "bram"))
    assert len(res.frontier) >= 2, \
        f"{name}: single-point frontier {res.frontier}"

"""Differential tests: closed-form affine slack fast path ≡ branch-and-bound ILP.

``DepAnalysis(p, crosscheck=True)`` re-solves EVERY case the fast path takes
with the reference ILP and raises on any mismatch, so driving a full
autotune+schedule under crosscheck exercises the equivalence across all the
II assignments the binary search probes.  We additionally check that the
fast and ILP analyses agree on which pairs/cases are feasible at all (an
II-independent property the fast path must also get right).
"""
import numpy as np
import pytest

from repro.core.autotune import autotune
from repro.core.deps import DepAnalysis
from repro.core.programs import (BENCHMARKS, fig1_conv_chain, fig3_conv1d)
from repro.core.scheduler import schedule


def _differential(p, require_no_fallback=False):
    # crosscheck=True re-solves EVERY fast-path case with the ILP and raises
    # on mismatch — including the None (case-infeasible) decisions made
    # during pair enumeration, so feasibility agreement is covered too.
    dep = DepAnalysis(p, crosscheck=True)
    iis = autotune(p, dep)
    s = schedule(p, iis, dep)
    assert s.feasible
    assert dep.fast_cases > 0
    if require_no_fallback:
        assert dep.fallback_cases == 0, \
            "corpus dependence ILPs must all be closed-form solvable"
    return dep


def _corpus(n):
    progs = [("fig3", fig3_conv1d()), ("fig1", fig1_conv_chain(n=n))]
    for name, mk in BENCHMARKS.items():
        for storage in ("reg", "bram"):
            arg = max(4, n // 2) if name == "two_mm" else n
            progs.append((f"{name}[{arg},{storage}]", mk(arg, storage)))
    return progs


@pytest.mark.parametrize("name,p", _corpus(6), ids=lambda v: v if isinstance(v, str) else "")
def test_corpus_fastpath_matches_ilp(name, p):
    _differential(p, require_no_fallback=True)


@pytest.mark.slow
@pytest.mark.parametrize("name,p", _corpus(32), ids=lambda v: v if isinstance(v, str) else "")
def test_corpus_fastpath_matches_ilp_fullsize(name, p):
    _differential(p, require_no_fallback=True)


# ---------------------------------------------------------------------------
# randomized affine programs: strides, diagonals, constants, carried deps
# ---------------------------------------------------------------------------


def _random_affine_program(seed: int):
    from repro.core.ir import ProgramBuilder

    rng = np.random.default_rng(2000 + seed)
    b = ProgramBuilder(f"aff{seed}")
    size = int(rng.integers(3, 6))
    n_arrays = int(rng.integers(2, 4))
    names = []
    for a in range(n_arrays):
        full = bool(rng.integers(0, 2))
        b.array(f"A{a}", (2 * size + 3, 2 * size + 3),
                partition=(0, 1) if full else (0,),
                ports=("w", "r") if full else ("w", "r", "r"))
        names.append(f"A{a}")

    def rnd_index(ivs):
        """Random affine expr over the loop ivs: strided, diagonal, shifted,
        or constant — the index shapes the closed form must cover."""
        kind = int(rng.integers(0, 5))
        if kind == 0:            # plain shifted iv
            return ivs[int(rng.integers(0, len(ivs)))] + int(rng.integers(0, 3))
        if kind == 1:            # strided (the DUS decimation pattern)
            return ivs[int(rng.integers(0, len(ivs)))] * 2 + int(rng.integers(0, 2))
        if kind == 2 and len(ivs) > 1:   # diagonal coupling
            return ivs[0] + ivs[1]
        if kind == 3:            # constant address
            return int(rng.integers(0, size))
        return ivs[int(rng.integers(0, len(ivs)))]

    n_nests = int(rng.integers(2, 4))
    for t in range(n_nests):
        src = names[int(rng.integers(0, len(names)))]
        dst = names[int(rng.integers(0, len(names)))]
        depth = int(rng.integers(1, 4))
        ivnames = [f"t{t}l{d}" for d in range(depth)]

        def body(ivs):
            x = b.load(src, rnd_index(ivs), rnd_index(ivs))
            y = b.load(src, rnd_index(ivs), rnd_index(ivs))
            v = b.arith(["add", "mul", "sub"][int(rng.integers(0, 3))], x, y)
            b.store(dst, v, rnd_index(ivs), rnd_index(ivs))

        def nest(d, ivs):
            if d == depth:
                body(ivs)
                return
            with b.loop(ivnames[d], 0, size) as iv_:
                nest(d + 1, ivs + [iv_])

        nest(0, [])
    return b.build()


@pytest.mark.parametrize("seed", range(50))
def test_random_affine_fastpath_matches_ilp(seed):
    p = _random_affine_program(seed)
    _differential(p)


# ---------------------------------------------------------------------------
# randomized imperfect / multi-loop tasks (the generalized nest contract):
# loop-adjacent ops and scan-style recurrences must hit the same closed forms
# ---------------------------------------------------------------------------


def _random_imperfect_program(seed: int):
    """Outer loop holding a loose scalar prologue (load+arith) feeding an
    inner nest — the shape ``ir.nest_shape`` classifies as ``imperfect``."""
    from repro.core.ir import ProgramBuilder

    rng = np.random.default_rng(7000 + seed)
    T, N = int(rng.integers(3, 6)), int(rng.integers(3, 6))
    b = ProgramBuilder(f"imp{seed}")
    b.array("X", (T + 1, N + 2), partition=(0,), ports=("w", "r", "r"))
    b.array("Y", (T + 1, N + 2), partition=(0,), ports=("w", "r", "r"))
    with b.loop("t", 0, T) as t:
        m = b.load("X", t, int(rng.integers(0, N)))
        if rng.integers(0, 2):
            m = b.mul(m, b.const(float(rng.integers(1, 4))))
        with b.loop("j", 0, N) as j:
            v = b.add(b.load("X", t + int(rng.integers(0, 2)), j), m)
            b.store("Y", v, t + int(rng.integers(0, 2)), j)
        if rng.integers(0, 2):  # loose epilogue store after the nest
            b.store("Y", m, t, N + 1)
    return b.build()


def _random_multiloop_program(seed: int):
    """Scan-style task: a time loop whose body holds 2-3 sibling inner
    nests coupled through a carried state array (``multi_loop`` kind)."""
    from repro.core.ir import ProgramBuilder

    rng = np.random.default_rng(8000 + seed)
    T, N = int(rng.integers(3, 5)), int(rng.integers(3, 6))
    b = ProgramBuilder(f"ml{seed}")
    b.array("S", (T + 1, N), partition=(0,), ports=("w", "r", "r"))
    b.array("X", (T, N), partition=(0,), ports=("w", "r", "r"))
    b.array("Y", (T, N), partition=(0,), ports=("w", "r", "r"))
    with b.loop("j0", 0, N) as j:
        b.store("S", b.load("X", 0, j), 0, j)
    with b.loop("t", 0, T) as t:
        with b.loop("j1", 0, N) as j:
            up = b.arith(["add", "mul"][int(rng.integers(0, 2))],
                         b.load("S", t, j), b.load("X", t, j))
            b.store("S", up, t + 1, j)
        with b.loop("j2", 0, N) as j:
            rd = t + 1 if rng.integers(0, 2) else t
            b.store("Y", b.mul(b.load("S", rd, j), b.load("X", t, j)), t, j)
        if rng.integers(0, 2):  # third sibling nest reading the output back
            with b.loop("j3", 0, N) as j:
                b.store("Y", b.add(b.load("Y", t, j), b.const(1.0)), t, j)
    return b.build()


@pytest.mark.parametrize("seed", range(25))
def test_random_imperfect_fastpath_matches_ilp(seed):
    from repro.core.ir import nest_shape

    p = _random_imperfect_program(seed)
    assert nest_shape(p).kinds == ("imperfect",)
    _differential(p)


@pytest.mark.parametrize("seed", range(25))
def test_random_multiloop_fastpath_matches_ilp(seed):
    from repro.core.ir import nest_shape

    p = _random_multiloop_program(seed)
    assert "multi_loop" in nest_shape(p).kinds
    _differential(p)

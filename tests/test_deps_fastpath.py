"""Differential tests: closed-form affine slack fast path ≡ branch-and-bound ILP.

``DepAnalysis(p, crosscheck=True)`` re-solves EVERY case the fast path takes
with the reference ILP and raises on any mismatch, so driving a full
autotune+schedule under crosscheck exercises the equivalence across all the
II assignments the binary search probes.  We additionally check that the
fast and ILP analyses agree on which pairs/cases are feasible at all (an
II-independent property the fast path must also get right).
"""
import numpy as np
import pytest

from repro.core.autotune import autotune
from repro.core.deps import DepAnalysis
from repro.core.programs import (BENCHMARKS, fig1_conv_chain, fig3_conv1d)
from repro.core.scheduler import schedule


def _differential(p, require_no_fallback=False):
    # crosscheck=True re-solves EVERY fast-path case with the ILP and raises
    # on mismatch — including the None (case-infeasible) decisions made
    # during pair enumeration, so feasibility agreement is covered too.
    dep = DepAnalysis(p, crosscheck=True)
    iis = autotune(p, dep)
    s = schedule(p, iis, dep)
    assert s.feasible
    assert dep.fast_cases > 0
    if require_no_fallback:
        assert dep.fallback_cases == 0, \
            "corpus dependence ILPs must all be closed-form solvable"
    return dep


def _corpus(n):
    progs = [("fig3", fig3_conv1d()), ("fig1", fig1_conv_chain(n=n))]
    for name, mk in BENCHMARKS.items():
        for storage in ("reg", "bram"):
            arg = max(4, n // 2) if name == "two_mm" else n
            progs.append((f"{name}[{arg},{storage}]", mk(arg, storage)))
    return progs


@pytest.mark.parametrize("name,p", _corpus(6), ids=lambda v: v if isinstance(v, str) else "")
def test_corpus_fastpath_matches_ilp(name, p):
    _differential(p, require_no_fallback=True)


@pytest.mark.slow
@pytest.mark.parametrize("name,p", _corpus(32), ids=lambda v: v if isinstance(v, str) else "")
def test_corpus_fastpath_matches_ilp_fullsize(name, p):
    _differential(p, require_no_fallback=True)


# ---------------------------------------------------------------------------
# randomized affine programs: strides, diagonals, constants, carried deps
# ---------------------------------------------------------------------------


def _random_affine_program(seed: int):
    from repro.core.ir import ProgramBuilder

    rng = np.random.default_rng(2000 + seed)
    b = ProgramBuilder(f"aff{seed}")
    size = int(rng.integers(3, 6))
    n_arrays = int(rng.integers(2, 4))
    names = []
    for a in range(n_arrays):
        full = bool(rng.integers(0, 2))
        b.array(f"A{a}", (2 * size + 3, 2 * size + 3),
                partition=(0, 1) if full else (0,),
                ports=("w", "r") if full else ("w", "r", "r"))
        names.append(f"A{a}")

    def rnd_index(ivs):
        """Random affine expr over the loop ivs: strided, diagonal, shifted,
        or constant — the index shapes the closed form must cover."""
        kind = int(rng.integers(0, 5))
        if kind == 0:            # plain shifted iv
            return ivs[int(rng.integers(0, len(ivs)))] + int(rng.integers(0, 3))
        if kind == 1:            # strided (the DUS decimation pattern)
            return ivs[int(rng.integers(0, len(ivs)))] * 2 + int(rng.integers(0, 2))
        if kind == 2 and len(ivs) > 1:   # diagonal coupling
            return ivs[0] + ivs[1]
        if kind == 3:            # constant address
            return int(rng.integers(0, size))
        return ivs[int(rng.integers(0, len(ivs)))]

    n_nests = int(rng.integers(2, 4))
    for t in range(n_nests):
        src = names[int(rng.integers(0, len(names)))]
        dst = names[int(rng.integers(0, len(names)))]
        depth = int(rng.integers(1, 4))
        ivnames = [f"t{t}l{d}" for d in range(depth)]

        def body(ivs):
            x = b.load(src, rnd_index(ivs), rnd_index(ivs))
            y = b.load(src, rnd_index(ivs), rnd_index(ivs))
            v = b.arith(["add", "mul", "sub"][int(rng.integers(0, 3))], x, y)
            b.store(dst, v, rnd_index(ivs), rnd_index(ivs))

        def nest(d, ivs):
            if d == depth:
                body(ivs)
                return
            with b.loop(ivnames[d], 0, size) as iv_:
                nest(d + 1, ivs + [iv_])

        nest(0, [])
    return b.build()


@pytest.mark.parametrize("seed", range(50))
def test_random_affine_fastpath_matches_ilp(seed):
    p = _random_affine_program(seed)
    _differential(p)

"""Schedule-equality regression: the fast-path/incremental compilation must
produce EXACTLY the schedules the original per-pair-ILP implementation did.

The expected values below were captured by running the pre-optimization
(seed) implementation; any drift means the rewrite changed a computed
schedule, which the perf work must never do.
"""
from repro.core import pipeline_ilp as pp
from repro.core.autotune import compile_program
from repro.core.programs import fig3_conv1d, unsharp


# Captured from the seed implementation (per-pair branch-and-bound ILPs).
SEED_PP = {
    (4, 8): dict(ii=3, latency=43, fwd_start=[0, 2, 4, 6],
                 bwd_start=[19, 16, 13, 10], peak=18),
    (8, 16): dict(ii=3, latency=87,
                  fwd_start=[0, 2, 4, 6, 8, 10, 12, 14],
                  bwd_start=[39, 36, 33, 30, 27, 24, 21, 18], peak=63),
}

SEED_FIG3 = dict(iis={"i": 14, "j": 7}, theta=[0, 0, 4, 0, 0, 1, 5, 10])

SEED_UNSHARP8 = dict(
    iis={"bxi": 8, "bxj": 1, "byi": 8, "byj": 1,
         "shi": 8, "shj": 1, "mki": 8, "mkj": 1},
    theta=[0, 0, 0, 1, 1, 0, 1, 1, 0, 1, 1, 5, 10, 15, 0, 0, 18, 19, 19,
           25, 26, 26, 32, 33, 33, 30, 37, 42, 0, 0, 10, 43, 11, 11, 44,
           44, 48, 53, 0, 0, 54, 54, 55, 60, 60, 64, 69])


def test_pipeline_schedules_unchanged():
    for (S, M), want in SEED_PP.items():
        s = pp.synthesize(S, M, t_f=1, t_b=2)
        assert s.ii == want["ii"], (S, M)
        assert s.latency == want["latency"], (S, M)
        assert s.fwd_start == want["fwd_start"], (S, M)
        assert s.bwd_start == want["bwd_start"], (S, M)
        assert s.peak_live_activations == want["peak"], (S, M)


def test_fig3_schedule_unchanged():
    p = fig3_conv1d()
    s = compile_program(p)
    assert {l.ivname: s.iis[l.uid] for l in p.loops()} == SEED_FIG3["iis"]
    assert [s.theta[n.uid] for n, _ in p.walk()] == SEED_FIG3["theta"]


def test_unsharp_stencil_schedule_unchanged():
    p = unsharp(8)
    s = compile_program(p)
    assert {l.ivname: s.iis[l.uid] for l in p.loops()} == SEED_UNSHARP8["iis"]
    assert [s.theta[n.uid] for n, _ in p.walk()] == SEED_UNSHARP8["theta"]

"""Unit tests for the numpy simplex / branch-and-bound ILP solver."""
import numpy as np
import pytest

from repro.core.ilp import brute_force_ilp, solve_ilp, solve_lp


def test_lp_basic():
    # min -x-y st x+y<=4, x<=3  -> x=3,y=1
    r = solve_lp([-1, -1], A_ub=[[1, 1], [1, 0]], b_ub=[4, 3])
    assert r.ok
    assert abs(r.fun + 4.0) < 1e-6


def test_lp_infeasible():
    r = solve_lp([1], A_ub=[[1], [-1]], b_ub=[1, -2])  # x<=1 and x>=2
    assert r.status == "infeasible"


def test_lp_unbounded():
    r = solve_lp([-1], A_ub=[[-1]], b_ub=[0])
    assert r.status == "unbounded"


def test_lp_equality():
    # min x+y st x+2y==4, x>=0,y>=0 -> y=2
    r = solve_lp([1, 1], A_eq=[[1, 2]], b_eq=[4])
    assert r.ok and abs(r.fun - 2.0) < 1e-6


def test_ilp_matches_brute_force_random():
    rng = np.random.default_rng(7)
    for trial in range(60):
        n = int(rng.integers(2, 5))
        m = int(rng.integers(1, 4))
        c = rng.integers(-4, 5, size=n).astype(float)
        A = rng.integers(-3, 4, size=(m, n)).astype(float)
        b = rng.integers(-4, 12, size=m).astype(float)
        bounds = [(0, int(rng.integers(1, 6))) for _ in range(n)]
        got = solve_ilp(c, A_ub=A, b_ub=b, bounds=bounds)
        want = brute_force_ilp(c, A_ub=A, b_ub=b, bounds=bounds)
        assert got.status == want.status, (trial, got.status, want.status)
        if got.ok:
            assert abs(got.fun - want.fun) < 1e-6, (trial, got.fun, want.fun)


def test_ilp_with_equalities_random():
    rng = np.random.default_rng(11)
    for trial in range(40):
        n = int(rng.integers(2, 5))
        c = rng.integers(-3, 4, size=n).astype(float)
        Ae = rng.integers(-2, 3, size=(1, n)).astype(float)
        be = rng.integers(0, 6, size=1).astype(float)
        bounds = [(int(rng.integers(-2, 1)), int(rng.integers(2, 5)))
                  for _ in range(n)]
        got = solve_ilp(c, A_eq=Ae, b_eq=be, bounds=bounds)
        want = brute_force_ilp(c, A_eq=Ae, b_eq=be, bounds=bounds)
        assert got.status == want.status, trial
        if got.ok:
            assert abs(got.fun - want.fun) < 1e-6, (trial, got.fun, want.fun)


def test_ilp_negative_bounds_shift():
    # min x st x >= -3  -> -3
    r = solve_ilp([1.0], bounds=[(-3, 3)])
    assert r.ok and r.fun == -3 and r.x[0] == -3

"""Unit tests for the numpy simplex / branch-and-bound ILP solver."""
import numpy as np

from repro.core.ilp import brute_force_ilp, solve_ilp, solve_lp


def test_lp_basic():
    # min -x-y st x+y<=4, x<=3  -> x=3,y=1
    r = solve_lp([-1, -1], A_ub=[[1, 1], [1, 0]], b_ub=[4, 3])
    assert r.ok
    assert abs(r.fun + 4.0) < 1e-6


def test_lp_infeasible():
    r = solve_lp([1], A_ub=[[1], [-1]], b_ub=[1, -2])  # x<=1 and x>=2
    assert r.status == "infeasible"


def test_lp_unbounded():
    r = solve_lp([-1], A_ub=[[-1]], b_ub=[0])
    assert r.status == "unbounded"


def test_lp_equality():
    # min x+y st x+2y==4, x>=0,y>=0 -> y=2
    r = solve_lp([1, 1], A_eq=[[1, 2]], b_eq=[4])
    assert r.ok and abs(r.fun - 2.0) < 1e-6


def test_ilp_matches_brute_force_random():
    rng = np.random.default_rng(7)
    for trial in range(60):
        n = int(rng.integers(2, 5))
        m = int(rng.integers(1, 4))
        c = rng.integers(-4, 5, size=n).astype(float)
        A = rng.integers(-3, 4, size=(m, n)).astype(float)
        b = rng.integers(-4, 12, size=m).astype(float)
        bounds = [(0, int(rng.integers(1, 6))) for _ in range(n)]
        got = solve_ilp(c, A_ub=A, b_ub=b, bounds=bounds)
        want = brute_force_ilp(c, A_ub=A, b_ub=b, bounds=bounds)
        assert got.status == want.status, (trial, got.status, want.status)
        if got.ok:
            assert abs(got.fun - want.fun) < 1e-6, (trial, got.fun, want.fun)


def test_ilp_with_equalities_random():
    rng = np.random.default_rng(11)
    for trial in range(40):
        n = int(rng.integers(2, 5))
        c = rng.integers(-3, 4, size=n).astype(float)
        Ae = rng.integers(-2, 3, size=(1, n)).astype(float)
        be = rng.integers(0, 6, size=1).astype(float)
        bounds = [(int(rng.integers(-2, 1)), int(rng.integers(2, 5)))
                  for _ in range(n)]
        got = solve_ilp(c, A_eq=Ae, b_eq=be, bounds=bounds)
        want = brute_force_ilp(c, A_eq=Ae, b_eq=be, bounds=bounds)
        assert got.status == want.status, trial
        if got.ok:
            assert abs(got.fun - want.fun) < 1e-6, (trial, got.fun, want.fun)


def test_ilp_negative_bounds_shift():
    # min x st x >= -3  -> -3
    r = solve_ilp([1.0], bounds=[(-3, 3)])
    assert r.ok and r.fun == -3 and r.x[0] == -3


# ---------------------------------------------------------------------------
# Anytime behaviour: truncated searches must report honest statuses
# ---------------------------------------------------------------------------

# A 0/1 knapsack whose incumbent after 3 branch-and-bound nodes is NOT the
# optimum (value 21 vs 33): the old solver reported "optimal" whenever an
# incumbent existed at the node cap, silently returning a suboptimal point
# as the truth.
_KNAP_V = [13.0, 16.0, 1.0, 4.0, 4.0, 8.0]
_KNAP_W = [10.0, 4.0, 6.0, 3.0, 5.0, 8.0]
_KNAP_CAP = 18.0


def _knapsack(max_nodes=4000, deadline_s=None):
    return solve_ilp([-v for v in _KNAP_V],
                     A_ub=np.array([_KNAP_W]), b_ub=np.array([_KNAP_CAP]),
                     bounds=[(0, 1)] * len(_KNAP_V),
                     max_nodes=max_nodes, deadline_s=deadline_s)


def test_ilp_truncated_incumbent_is_feasible_not_optimal():
    full = _knapsack()
    assert full.status == "optimal" and full.fun == -33.0
    trunc = _knapsack(max_nodes=3)
    assert trunc.status == "feasible"          # honest: search was cut short
    assert trunc.truncated and not trunc.ok
    assert trunc.fun > full.fun                # incumbent is NOT the optimum
    assert trunc.bound is not None and trunc.bound <= full.fun + 1e-9
    assert trunc.gap is not None and trunc.gap >= trunc.fun - full.fun - 1e-9
    assert trunc.nodes == 3


def test_ilp_deadline_truncates_with_bound():
    # deadline hit after the root: either we still prove optimality at the
    # root (not here: fractional LP relaxation) or we report the truncation.
    r = _knapsack(deadline_s=0.0)
    assert r.status in ("feasible", "timeout")
    assert r.truncated
    assert r.bound is not None and r.bound <= -33.0 + 1e-9
    if r.status == "feasible":
        assert r.x is not None
        assert float(np.dot(_KNAP_W, r.x)) <= _KNAP_CAP + 1e-9  # sound point


def test_ilp_optimal_has_zero_gap_and_node_count():
    r = _knapsack()
    assert r.status == "optimal" and r.gap == 0.0 and r.nodes >= 1
    assert r.bound is not None and r.bound <= r.fun + 1e-9


def test_ilp_integral_root_is_proven_even_under_deadline():
    # The root LP is integral -> provenly optimal on the very first node,
    # deadline notwithstanding (the root is always expanded).
    r = solve_ilp([1.0, 1.0], A_ub=np.array([[-1.0, 0.0]]),
                  b_ub=np.array([-2.0]), bounds=[(0, 5), (0, 5)],
                  deadline_s=0.0)
    assert r.status == "optimal" and r.fun == 2.0

"""Failure injection: the training loop must restore and converge to the
same result as an uninterrupted run (determinism through restarts)."""
import numpy as np
import pytest

from repro.runtime import FaultTolerantLoop, StepWatchdog


def _mk(counter):
    def make_state():
        return {"x": np.zeros(4), "step_sum": np.zeros(())}

    def step_fn(state, step):
        counter.append(step)
        return {"x": state["x"] + step, "step_sum": state["step_sum"] + 1}

    return make_state, step_fn


def test_restart_recovers_and_is_deterministic(tmp_path):
    seen = []
    mk, st = _mk(seen)
    loop = FaultTolerantLoop(str(tmp_path / "a"), mk, st, ckpt_every=5,
                             inject={7: RuntimeError("node lost"),
                                     13: RuntimeError("link down")})
    state, log = loop.run(20)
    assert log["restarts"] == 2

    clean = FaultTolerantLoop(str(tmp_path / "b"), *(_mk([])), ckpt_every=5)
    state2, log2 = clean.run(20)
    np.testing.assert_allclose(state["x"], state2["x"])


def test_restart_limit(tmp_path):
    mk, st = _mk([])
    loop = FaultTolerantLoop(
        str(tmp_path / "c"), mk, st, ckpt_every=100, max_restarts=1,
        inject={1: RuntimeError("a"), 2: RuntimeError("b"),
                3: RuntimeError("c")})
    # injections at 1 and 2 both replay from step 0 (no checkpoint yet);
    # the loop keeps re-running steps and must eventually give up only if
    # more than max_restarts failures occur
    with pytest.raises(RuntimeError):
        loop.run(10)


def test_watchdog_flags_stragglers():
    fired = []
    wd = StepWatchdog(100.0, lambda: fired.append(1))
    for _ in range(8):
        wd.start_step()
        wd.end_step()
    wd.step_times.append(10.0)  # synthetic straggler
    assert wd.straggling(slack=2.0)

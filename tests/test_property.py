"""Property tests over random affine programs.

``hypothesis`` is not installed in this container, so we use the same
pattern with hand-rolled seeded generators: for every random program the
invariants are

  1. the autotuned schedule passes the brute-force dependence/port validator,
  2. executing the *scheduled* program (timed interpreter) produces exactly
     the arrays of the *sequential* interpreter,
  3. every loop II is at least 1 and occupancy (II_outer >= trip*II_inner)
     holds.
"""
import numpy as np
import pytest

from repro.core.autotune import compile_program
from repro.core.ir import ProgramBuilder
from repro.core.scheduler import check_loop_occupancy
from repro.core.sim import (make_inputs, sequential_exec, timed_exec,
                            validate_schedule)


def random_program(seed: int):
    rng = np.random.default_rng(seed)
    b = ProgramBuilder(f"rand{seed}")
    n_arrays = int(rng.integers(2, 4))
    size = int(rng.integers(3, 6))
    names = []
    for a in range(n_arrays):
        full = bool(rng.integers(0, 2))
        b.array(f"A{a}", (size + 2, size + 2),
                partition=(0, 1) if full else (0,),
                ports=("w", "r") if full else ("w", "r", "r"))
        names.append(f"A{a}")
    n_nests = int(rng.integers(2, 4))
    for t in range(n_nests):
        src = names[int(rng.integers(0, len(names)))]
        dst = names[int(rng.integers(0, len(names)))]
        du, dv = int(rng.integers(0, 3)), int(rng.integers(0, 3))
        fn = ["add", "mul", "sub"][int(rng.integers(0, 3))]
        with b.loop(f"t{t}i", 0, size) as i:
            with b.loop(f"t{t}j", 0, size) as j:
                x = b.load(src, i + du, j + dv)
                y = b.load(src, i, j)
                v = b.arith(fn, x, y)
                if rng.integers(0, 2):
                    v = b.mul(v, b.const(0.5))
                b.store(dst, v, i, j)
    return b.build()


@pytest.mark.parametrize("seed", range(14))
def test_random_program_schedule_is_valid_and_exact(seed):
    p = random_program(seed)
    s = compile_program(p)
    assert s.feasible
    assert check_loop_occupancy(p, s.iis)
    assert all(ii >= 1 for ii in s.iis.values())
    violations = validate_schedule(p, s)
    assert violations == [], violations[:5]
    inp = make_inputs(p, seed)
    got = timed_exec(p, s, inp)
    want = sequential_exec(p, inp)
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-12, err_msg=k)


@pytest.mark.parametrize("seed", range(6))
def test_random_accumulator_programs(seed):
    """Loop-carried memory recurrences (the Fig.4 pattern) at random depths."""
    rng = np.random.default_rng(100 + seed)
    b = ProgramBuilder(f"acc{seed}")
    m = int(rng.integers(3, 6))
    b.array("C", (m, m), ports=("w", "r"))
    b.array("X", (m, m), ports=("r", "r"))
    with b.loop("i", 0, m) as i:
        with b.loop("j", 0, m) as j:
            with b.loop("k", 0, m) as k:
                acc = b.load("C", i, j)
                x = b.load("X", i, k)
                b.store("C", b.add(acc, x), i, j)
    p = b.build()
    s = compile_program(p)
    assert s.feasible
    assert validate_schedule(p, s) == []
    inp = make_inputs(p, seed)
    got, want = timed_exec(p, s, inp), sequential_exec(p, inp)
    np.testing.assert_allclose(got["C"], want["C"], rtol=1e-12)
    # the k-loop II must respect the load->add->store recurrence (7 cycles)
    k_loop = [l for l in p.loops() if l.ivname == "k"][0]
    assert s.iis[k_loop.uid] == 7


def random_deep_program(seed: int):
    """3-deep nests with unrolled inner taps and optional accumulators."""
    rng = np.random.default_rng(1000 + seed)
    b = ProgramBuilder(f"deep{seed}")
    n = int(rng.integers(3, 5))
    b.array("A", (n + 2, n + 2), partition=(0, 1), ports=("w", "r"))
    b.array("B", (n + 2, n + 2), partition=(0, 1), ports=("w", "r"))
    b.array("Cc", (n, n), ports=("w", "r"))
    # nest 1: unrolled 2x2 stencil A -> B
    with b.loop("i", 0, n) as i:
        with b.loop("j", 0, n) as j:
            terms = []
            for u in range(2):
                with b.loop(f"u{u}", 0, 1, unroll=True):
                    pass
            for u in range(2):
                for v in range(2):
                    terms.append(b.mul(b.load("A", i + u, j + v),
                                       b.const(0.25)))
            b.store("B", b.sum_tree(terms), i, j)
    # nest 2: 3-deep accumulation B -> Cc (Fig.4 pattern)
    with b.loop("x", 0, n) as x:
        with b.loop("y", 0, n) as y:
            with b.loop("z", 0, int(rng.integers(2, 4))) as z:
                acc = b.load("Cc", x, y)
                t = b.mul(b.load("B", x, y), b.const(0.5))
                b.store("Cc", b.add(acc, t), x, y)
    return b.build()


@pytest.mark.parametrize("seed", range(6))
def test_random_deep_programs(seed):
    p = random_deep_program(seed)
    s = compile_program(p)
    assert s.feasible
    assert validate_schedule(p, s) == []
    inp = make_inputs(p, seed)
    got, want = timed_exec(p, s, inp), sequential_exec(p, inp)
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-12, err_msg=k)

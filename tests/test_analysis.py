"""The independent static verifier (DESIGN.md §12): IR linter goldens,
schedule translation validation, and the validator mutation-kill property.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import api as hls
from repro.core import programs as P
from repro.core.analysis import (EXPECTED_LINT, LINT_CODES, VALIDATE_CODES,
                                 corpus_programs, corrupt_schedule, lint,
                                 main as analysis_main, validate_static)
from repro.core.errors import Diagnostic, StaticValidationError
from repro.core.ir import (AffExpr, ArithOp, ArrayDecl, LoadOp, Loop, Program,
                           ProgramBuilder, StoreOp, iv)


def codes(diags):
    return {d.code for d in diags}


# ---------------------------------------------------------------------------
# Level 1: linter negative-case goldens (exact Diagnostic.code matches)
# ---------------------------------------------------------------------------


def _simple(name="t", shape=(8,), **kw):
    b = ProgramBuilder(name)
    b.array("A", shape, is_arg=True, **kw)
    b.array("C", shape, is_arg=True)
    return b


def test_lint_clean_program():
    b = _simple()
    with b.loop("i", 0, 8) as i:
        x = b.load("A", i)
        b.store("C", b.add(x, x), i)
    assert lint(b.build()) == []


def test_lint_oob_read_and_write():
    b = _simple(shape=(8,))
    with b.loop("i", 0, 8) as i:
        x = b.load("A", i + 1)        # reaches 8
        b.store("C", b.add(x, x), i - 1)  # reaches -1
    got = lint(b.build())
    assert codes(got) == {"oob-read", "oob-write"}
    assert all(d.severity == "error" for d in got)


def test_lint_oob_shifted_core():
    # the fusion-shift idiom: a shifted core reading a halo that is not there
    b = _simple(shape=(8, 8))
    with b.loop("i", 0, 8) as i:
        with b.loop("j", 0, 8) as j:
            x = b.load("A", i + 1, j)  # row halo missing: i+1 reaches 8
            b.store("C", b.add(x, x), i, j)
    assert codes(lint(b.build())) == {"oob-read"}


def test_lint_rank_mismatch_and_unknown_array():
    p = Program("t", arrays={"A": ArrayDecl("A", (4, 4), is_arg=True)})
    lp = Loop(ivname="i", lb=0, ub=4)
    lp.body = [LoadOp(result="x", array="A", index=(iv("i"),)),
               LoadOp(result="y", array="nope", index=(iv("i"),))]
    p.body = [lp]
    assert codes(lint(p)) >= {"rank-mismatch", "unknown-array"}


def test_lint_unbound_iv():
    p = Program("t", arrays={"A": ArrayDecl("A", (4,), is_arg=True)})
    lp = Loop(ivname="i", lb=0, ub=4)
    lp.body = [LoadOp(result="x", array="A", index=(iv("k"),))]
    p.body = [lp]
    assert codes(lint(p)) == {"unbound-iv"}


def test_lint_liveness_codes():
    b = ProgramBuilder("t")
    b.array("src", (8,), is_arg=True)
    b.array("ghost", (8,))      # read, never written
    b.array("sink", (8,))       # written, never read
    b.array("idle", (8,))       # never touched
    with b.loop("i", 0, 8) as i:
        x = b.load("src", i)
        g = b.load("ghost", i)
        b.store("sink", b.add(x, g), i)
    got = lint(b.build())
    by = {d.code: d for d in got}
    assert set(by) == {"read-uninitialized", "never-read", "unused-array"}
    assert by["read-uninitialized"].severity == "error"
    assert by["never-read"].severity == "warning"


def test_lint_use_before_def_across_tasks():
    b = ProgramBuilder("t")
    b.array("out", (8,), is_arg=True)
    b.array("tmp", (8,))
    with b.loop("i", 0, 8) as i:       # consumer first...
        x = b.load("tmp", i)
        b.store("out", b.add(x, x), i)
    with b.loop("j", 0, 8) as j:       # ...producer second
        y = b.load("out", j)
        b.store("tmp", b.add(y, y), j)
    assert "use-before-def" in codes(lint(b.build()))


def test_lint_multi_writer():
    b = ProgramBuilder("t")
    b.array("src", (8,), is_arg=True)
    b.array("dst", (8,), is_arg=True)
    for ivn in ("i", "j"):
        with b.loop(ivn, 0, 8) as k:
            x = b.load("src", k)
            b.store("dst", b.add(x, x), k)
    assert "multi-writer" in codes(lint(b.build()))


def test_lint_recurrence_writer_is_not_multi_writer():
    # init nest + scan nest both write the carry — the scan also reads it,
    # which is a recurrence, not a dataflow multi-producer hazard
    b = ProgramBuilder("t")
    b.array("src", (8,), is_arg=True)
    b.array("carry", (8,), is_arg=True)
    with b.loop("i", 0, 8) as i:
        z = b.load("src", i)
        b.store("carry", b.add(z, z), i)
    with b.loop("j", 0, 8) as j:
        c = b.load("carry", j)
        s = b.load("src", j)
        b.store("carry", b.add(c, s), j)
    assert "multi-writer" not in codes(lint(b.build()))


def test_lint_pragma_codes():
    p = Program("t", arrays={
        "A": ArrayDecl("A", (4,), is_arg=True, partition=(1,))})
    bad_ii = Loop(ivname="i", lb=0, ub=4, ii=0)
    bad_ii.body = [LoadOp(result="x", array="A", index=(iv("i"),))]
    nz = Loop(ivname="j", lb=2, ub=6)
    nz.body = [LoadOp(result="y", array="A", index=(AffExpr({"j": 1}, -2),))]
    tile = Loop(ivname="k_t", lb=0, ub=2, tile_block=3)  # inner trip != 3
    inner = Loop(ivname="k_b", lb=0, ub=2)
    inner.body = [LoadOp(result="z", array="A", index=(iv("k_b"),))]
    tile.body = [inner]
    peel = Loop(ivname="m", lb=0, ub=1, peel=True)
    peel.body = [LoadOp(result="w", array="A", index=(iv("m"),))]
    p.body = [bad_ii, nz, tile, peel]
    got = codes(lint(p))
    assert {"bad-ii", "nonzero-base", "tile-marker", "orphan-peel",
            "partition-dim"} <= got


def test_lint_ssa_scope_and_unknown_fn():
    # a sibling loop's def is invisible (sim's env copy semantics)
    p = Program("t", arrays={"A": ArrayDecl("A", (4,), is_arg=True)})
    l1 = Loop(ivname="i", lb=0, ub=4)
    l1.body = [LoadOp(result="x", array="A", index=(iv("i"),))]
    l2 = Loop(ivname="j", lb=0, ub=4)
    l2.body = [ArithOp(result="y", fn="add", args=("x", "x")),
               ArithOp(result="z", fn="sqrt", args=("y", "y")),
               StoreOp(array="A", index=(iv("j"),), value="z")]
    p.body = [l1, l2]
    got = codes(lint(p))
    assert {"undef-ssa", "unknown-fn"} <= got


def test_lint_missing_port():
    b = ProgramBuilder("t")
    b.array("ro", (8,), is_arg=True, ports=("r",))
    with b.loop("i", 0, 8) as i:
        x = b.load("ro", i)
        b.store("ro", b.add(x, x), i)
    assert "missing-port" in codes(lint(b.build()))


def test_lint_is_stable_sorted():
    b = _simple(shape=(8,))
    b.array("dead", (8,))
    with b.loop("i", 0, 8) as i:
        x = b.load("A", i + 1)
        b.store("C", b.add(x, x), i)
        b.store("dead", x, i)
    got = lint(b.build())
    assert got == sorted(got, key=Diagnostic.sort_key)
    assert [d.severity for d in got] == sorted(
        [d.severity for d in got], key=lambda s: s != "error")


def test_every_emitted_code_is_documented():
    assert set(LINT_CODES) >= {
        "oob-read", "oob-write", "use-before-def", "never-read",
        "multi-writer", "tile-marker", "partition-dim", "undef-ssa"}
    assert set(VALIDATE_CODES) >= {
        "dep-violated", "port-conflict", "occupancy", "ssa-order",
        "unresolved"}


# ---------------------------------------------------------------------------
# Corpus: the linter runs clean (or matches pinned goldens)
# ---------------------------------------------------------------------------


def test_corpus_lints_clean():
    for name, ctor in corpus_programs(include_traced=False).items():
        errors = {d.code for d in lint(ctor())
                  if d.severity == "error"} - EXPECTED_LINT.get(name, set())
        assert not errors, f"{name}: unexpected lint errors {errors}"


def test_cli_smoke(capsys):
    assert analysis_main(["fig3_conv1d", "blur_chain", "--no-traced"]) == 0
    out = capsys.readouterr().out
    assert "fig3_conv1d: ok" in out and "blur_chain: ok" in out
    assert analysis_main(["--codes"]) == 0


# ---------------------------------------------------------------------------
# Level 2: schedule translation validation
# ---------------------------------------------------------------------------

GOLDEN = {
    "blur_chain": lambda: P.blur_chain(n=8),
    "conv_pool": lambda: P.conv_pool(n=8),
    "gradient_harris": lambda: P.gradient_harris(n=8),
    "correlated_chain": lambda: P.correlated_chain(n=8),
    "harris": lambda: P.harris(n=8),
    "optical_flow": lambda: P.optical_flow(n=8),
    "two_mm": lambda: P.two_mm(m=6),
}


@pytest.mark.parametrize("name", sorted(GOLDEN))
def test_golden_schedules_accepted(name):
    p = GOLDEN[name]()
    s = hls.compile(p, pipeline=()).best.schedule
    v = validate_static(s.program, s)
    assert v.ok, f"{name}: {[str(d) for d in v.diagnostics]}"
    assert v.pairs > 0


@pytest.mark.parametrize("pipeline", ["fuse", "fuse,partition"])
def test_transformed_golden_accepted(pipeline):
    p = P.blur_chain(n=8)
    r = hls.compile(p, pipeline=pipeline)
    s = r.best.schedule
    v = validate_static(s.program, s)
    assert v.ok, [str(d) for d in v.diagnostics]


@pytest.mark.slow
@pytest.mark.timeout(1200)
def test_dse_winners_accepted_full():
    for name, ctor in GOLDEN.items():
        r = hls.compile(ctor())
        s = r.best.schedule
        v = validate_static(s.program, s)
        assert v.ok, f"{name}: {[str(d) for d in v.diagnostics]}"


def test_validator_catches_theta_violation():
    p = P.blur_chain(n=8)
    s = hls.compile(p, pipeline=()).best.schedule
    e = next(e for e in s.edges if e.kind == "RAW")
    theta = dict(s.theta)
    theta[e.snk] = theta[e.src] + e.lower - 1
    mut = dataclasses.replace(s, theta=theta)
    v = validate_static(mut.program, mut)
    assert not v.ok
    assert codes(v.diagnostics) & {"dep-violated", "ssa-order",
                                   "struct-order"}


def test_validator_catches_occupancy():
    p = P.two_mm(m=6)
    s = hls.compile(p, pipeline=()).best.schedule
    nested = next(l for l in s.program.loops() if l.sub_loops())
    iis = dict(s.iis)
    iis[nested.uid] = 1  # below trip(inner) * II(inner)
    mut = dataclasses.replace(s, iis=iis)
    v = validate_static(mut.program, mut, fail_fast=True)
    assert not v.ok
    assert "occupancy" in codes(v.diagnostics)


def test_validator_catches_port_conflict():
    b = ProgramBuilder("t")
    b.array("B", (16,), is_arg=True)           # one read port
    b.array("C", (16,), is_arg=True)
    with b.loop("i", 0, 16) as i:
        x = b.load("B", i)
        y = b.load("B", i)
        b.store("C", b.add(x, y), i)
    p = b.build()
    s = hls.compile(p, pipeline=()).best.schedule
    assert validate_static(s.program, s).ok     # real schedule staggers them
    ld = [op for op, _ in s.program.walk() if isinstance(op, LoadOp)]
    theta = dict(s.theta)
    theta[ld[1].uid] = theta[ld[0].uid]         # same port, same cycle
    mut = dataclasses.replace(s, theta=theta)
    v = validate_static(mut.program, mut)
    assert "port-conflict" in codes(v.diagnostics)


def test_validator_missing_keys():
    p = P.two_mm(m=6)
    s = hls.compile(p, pipeline=()).best.schedule
    iis = dict(s.iis)
    iis.pop(next(iter(iis)))
    v = validate_static(p, dataclasses.replace(s, iis=iis))
    assert "missing-ii" in codes(v.diagnostics)
    theta = dict(s.theta)
    theta.pop(next(iter(theta)))
    v = validate_static(p, dataclasses.replace(s, theta=theta))
    assert "missing-theta" in codes(v.diagnostics)


# ---------------------------------------------------------------------------
# The mutation-kill property: >= 50 seeded corruptions per chain, all
# rejected; the uncorrupted schedule always accepted.
# ---------------------------------------------------------------------------

CHAINS = ["blur_chain", "conv_pool", "gradient_harris", "correlated_chain"]


@pytest.mark.parametrize("name", CHAINS)
def test_mutation_kill(name):
    p = GOLDEN[name]()
    s = hls.compile(p, pipeline=()).best.schedule
    assert s.provenance == "exact"
    assert validate_static(s.program, s).ok
    rng = np.random.default_rng(0xC0FFEE + CHAINS.index(name))
    killed = tries = 0
    while killed < 50:
        tries += 1
        assert tries < 500, f"mutator starved after {killed} mutants"
        made = corrupt_schedule(s, rng)
        if made is None:
            continue
        mut, info = made
        v = validate_static(mut.program, mut, fail_fast=True)
        assert not v.ok, f"{name}: validator accepted mutant {info}"
        killed += 1


def test_corrupt_schedule_requires_exact_provenance():
    p = P.blur_chain(n=8)
    s = hls.compile(p, pipeline=()).best.schedule
    degraded = dataclasses.replace(s, provenance="degraded")
    with pytest.raises(ValueError):
        corrupt_schedule(degraded, np.random.default_rng(0))


# ---------------------------------------------------------------------------
# Independence: the validator must not lean on deps.py's analysis
# ---------------------------------------------------------------------------


def test_validator_is_independent_of_deps():
    import ast
    import inspect

    from repro.core import analysis
    src = inspect.getsource(analysis)
    tree = ast.parse(src)
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            assert (node.module or "").split(".")[-1] != "deps", \
                "analysis.py imports deps.py"
        if isinstance(node, ast.Import):
            assert all("deps" not in (a.name or "") for a in node.names)
    for forbidden in ("_fast_slack_case", "_solve_separable",
                      "_min_diophantine_2var", "DepAnalysis",
                      "collect_accesses"):
        assert forbidden not in src


# ---------------------------------------------------------------------------
# hls.compile wiring
# ---------------------------------------------------------------------------


def test_compile_reports_lint_diagnostics():
    b = ProgramBuilder("t")
    b.array("src", (8,), is_arg=True)
    b.array("dead", (8,))
    with b.loop("i", 0, 8) as i:
        x = b.load("src", i)
        b.store("dead", x, i)
    r = hls.compile(b.build(), pipeline=())
    lints = [d for d in r.diagnostics if d.get("kind") == "lint"]
    assert any(d["code"] == "never-read" for d in lints)
    assert not r.degraded  # warnings do not degrade provenance


def test_compile_lint_opt_out():
    b = ProgramBuilder("t")
    b.array("src", (8,), is_arg=True)
    b.array("dead", (8,))
    with b.loop("i", 0, 8) as i:
        b.store("dead", b.load("src", i), i)
    r = hls.compile(b.build(), pipeline=(),
                    search=hls.SearchConfig(lint=False))
    assert not any(d.get("kind") == "lint" for d in r.diagnostics)


def test_compile_winner_is_validated(monkeypatch):
    calls = []
    from repro.core import analysis

    real = analysis.validate_static

    def spy(p, s, **kw):
        calls.append(p.name)
        return real(p, s, **kw)

    monkeypatch.setattr(analysis, "validate_static", spy)
    hls.compile(P.blur_chain(n=8), pipeline=())
    assert calls == ["blur_chain"]
    calls.clear()
    hls.compile(P.blur_chain(n=8), pipeline=(),
                search=hls.SearchConfig(static_check=False))
    assert calls == []


def test_compile_raises_on_proven_violation(monkeypatch):
    from repro.core import analysis, scheduler

    real = scheduler.schedule

    def sabotage(p, iis, dep, minimize_registers=True):
        s = real(p, iis, dep, minimize_registers=minimize_registers)
        if s.feasible and s.edges:
            e = max(s.edges, key=lambda e: e.lower)
            theta = dict(s.theta)
            theta[e.snk] = theta[e.src] + e.lower - 1
            s = dataclasses.replace(s, theta=theta)
        return s

    import sys
    # the package re-exports the autotune *function*, shadowing the module
    # attribute — go through sys.modules for the module itself
    monkeypatch.setattr(sys.modules["repro.core.autotune"], "schedule",
                        sabotage)
    with pytest.raises(StaticValidationError) as ei:
        hls.compile(P.blur_chain(n=8), pipeline=())
    assert ei.value.verdict.violations


# ---------------------------------------------------------------------------
# Diagnostics dedupe + stable explain() (the aggregation bugfix)
# ---------------------------------------------------------------------------


def test_dedupe_diagnostics():
    from repro.core.autotune import dedupe_diagnostics
    a = {"kind": "solver-degraded", "src": 1, "snk": 2, "carry": 0,
         "candidate": "tile(4)"}
    b = {"kind": "solver-degraded", "src": 1, "snk": 2, "carry": 0,
         "candidate": "fuse"}
    c = {"kind": "worker-retry", "attempt": 1}
    got = dedupe_diagnostics([a, b, c, dict(c)])
    assert len(got) == 2
    assert got[0]["count"] == 2 and got[0]["candidate"] == "tile(4)"
    assert got[1]["kind"] == "worker-retry" and got[1]["count"] == 2


def test_explain_stable_order():
    r = hls.compile(P.blur_chain(n=8), pipeline=())
    extra = [{"kind": "solver-degraded", "src": 9, "snk": 10, "carry": 1,
              "slack_bound": 0},
             {"kind": "solver-degraded", "src": 3, "snk": 4, "carry": 0,
              "slack_bound": 1}]
    r.diagnostics.extend(extra)
    text1 = r.explain()
    r.diagnostics[-2:] = [extra[1], extra[0]]  # reversed arrival order
    assert r.explain() == text1
    assert text1.index("(3, 4)") < text1.index("(9, 10)")

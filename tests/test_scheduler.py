"""Scheduler behaviour tests, anchored on the paper's own worked examples."""
import numpy as np
import pytest

from repro.core import emit_hir, schedule
from repro.core.autotune import compile_program
from repro.core.deps import DepAnalysis
from repro.core.programs import fig1_conv_chain, fig3_conv1d
from repro.core.sim import (make_inputs, sequential_exec, timed_exec,
                            validate_schedule)


@pytest.fixture(scope="module")
def fig3():
    p = fig3_conv1d()
    return p, compile_program(p)


def test_fig3_ii_matches_paper(fig3):
    """The paper derives II=7 for the j-loop (load->add 6 cycles + 1 store)
    and II=14 for the i-loop (§3.1)."""
    p, s = fig3
    iis = {l.ivname: s.iis[l.uid] for l in p.loops()}
    assert iis == {"j": 7, "i": 14}


def test_fig3_op_offsets_match_paper(fig3):
    """Fig 3b: load A at +4, mul at +1, add at +5, store at +10."""
    p, s = fig3
    j_loop = [l for l in p.loops() if l.ivname == "j"][0]
    offs = {}
    for op, anc in p.walk():
        if anc and anc[-1] is j_loop:
            offs[type(op).__name__ + (getattr(op, "fn", "") or
                                      getattr(op, "array", ""))] = \
                s.theta[op.uid] - s.theta[j_loop.uid]
    assert offs["LoadOpA"] == 4
    assert offs["ArithOpmul"] == 1
    assert offs["ArithOpadd"] == 5
    assert offs["StoreOpA"] == 10


def test_fig3_functional_and_valid(fig3):
    p, s = fig3
    inp = make_inputs(p, 3)
    np.testing.assert_allclose(timed_exec(p, s, inp)["A"],
                               sequential_exec(p, inp)["A"], rtol=1e-12)
    assert validate_schedule(p, s) == []


def test_fig3_hir_emission(fig3):
    p, s = fig3
    txt = emit_hir(s)
    assert "II = 7" in txt and "II = 14" in txt


def test_fig1_producer_consumer_overlap():
    """The consumer convolution must start before the producer finishes
    (Fig. 1b) while preserving exact semantics."""
    p = fig1_conv_chain(n=6)
    s = compile_program(p)
    assert s.completion_time() < s.sequential_nests_latency()
    prod, cons = [it for it in p.body]
    # consumer starts before producer's last write
    assert s.theta[cons.uid] < s.nest_latency(prod)
    inp = make_inputs(p, 1)
    got, want = timed_exec(p, s, inp), sequential_exec(p, inp)
    np.testing.assert_allclose(got["convY"], want["convY"], rtol=1e-12)
    assert validate_schedule(p, s) == []


def test_infeasible_ii_detected():
    """A user-forced II below the recurrence bound must be rejected."""
    from repro.core.ir import ProgramBuilder, iv

    b = ProgramBuilder("bad_ii")
    b.array("A", (16,), ports=("w", "r"))
    with b.loop("i", 0, 16):
        with b.loop("j", 0, 4, ii=2):  # II=2 < 7 violates the RAW recurrence
            acc = b.load("A", iv("i"))  # same address across j iterations
            s_ = b.add(acc, b.const(1.0))
            b.store("A", s_, iv("i"))
    p = b.build()
    dep = DepAnalysis(p)
    iis = {l.uid: (l.ii or 8) for l in p.loops()}  # i: 8 = 4*2 (occupancy)
    s = schedule(p, iis, dep)
    assert not s.feasible
    # and the recurrence-respecting II must be accepted
    iis2 = {l.uid: (7 if l.ivname == "j" else 28) for l in p.loops()}
    assert schedule(p, iis2, dep).feasible


def test_delay_register_minimization():
    """§4.3: the scheduler must not leave gratuitous delay registers."""
    p = fig3_conv1d()
    s = compile_program(p)
    # every SSA value is consumed as soon as its producer latency allows
    assert s.delay_register_bits() == 0

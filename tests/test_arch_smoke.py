"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step + one decode step on CPU, asserting shapes and finiteness.
(The FULL configs are exercised only via the dry-run.)"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.config import ARCH_IDS, ShapeConfig, get_config
from repro.models import api, lm

SMOKE_SHAPE = ShapeConfig("smoke_train", "train", 32, 2)
SMOKE_DECODE = ShapeConfig("smoke_decode", "decode", 32, 2)


@pytest.fixture(scope="module", params=[
    pytest.param(a, marks=pytest.mark.slow)
    if a == "jamba_1_5_large_398b" else a
    for a in ARCH_IDS])
def arch(request):
    cfg = get_config(request.param, reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32")  # CPU-precision smoke
    params = lm.init_params(cfg, jax.random.key(0))
    return cfg, params


def test_param_structure(arch):
    cfg, params = arch
    n = sum(x.size for x in jax.tree.leaves(params))
    assert n > 0
    # stacked period axis present
    flat = jax.tree.leaves(params["blocks"])
    assert all(l.shape[0] == lm.n_periods(cfg) for l in flat)


def test_train_step_shapes_and_finite(arch):
    cfg, params = arch
    batch = api.make_batch(cfg, SMOKE_SHAPE, seed=1)
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: lm.loss_fn(cfg, p, batch)))(params)
    assert jnp.isfinite(loss), cfg.name
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm), cfg.name


def test_forward_logits_shape(arch):
    cfg, params = arch
    batch = api.make_batch(cfg, SMOKE_SHAPE, seed=2)
    logits = jax.jit(lambda p, b: lm.forward(cfg, p, b))(params, batch)
    n_txt = SMOKE_SHAPE.seq_len - (cfg.n_img_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (SMOKE_SHAPE.global_batch, n_txt, cfg.vocab)
    assert jnp.isfinite(logits).all()


def test_decode_step(arch):
    cfg, params = arch
    B, Smax = SMOKE_DECODE.global_batch, SMOKE_DECODE.seq_len
    cache = lm.init_cache(cfg, B, Smax)
    batch = api.make_batch(cfg, SMOKE_DECODE, seed=3)
    logits, new_cache = jax.jit(
        lambda p, c, b: lm.decode_step(cfg, p, c, b))(params, cache, batch)
    assert logits.shape == (B, 1, cfg.vocab)
    assert jnp.isfinite(logits).all(), cfg.name
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.slow
def test_decode_matches_prefill_last_token():
    """Decode-with-cache must agree with a full forward (teacher forcing) for
    an architecture of each mixer family that supports exact comparison."""
    for aid in ("llama3_8b", "deepseek_v2_236b", "rwkv6_3b"):
        cfg = get_config(aid, reduced=True)
        cfg = dataclasses.replace(cfg, dtype="float32")
        if cfg.moe:
            # capacity dropping is batch-size dependent; give the router
            # unbounded capacity so the MLA cache math is tested exactly
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        params = lm.init_params(cfg, jax.random.key(1))
        B, S = 2, 8
        tokens = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab)
        full = lm.forward(cfg, params, {"tokens": tokens})
        cache = lm.init_cache(cfg, B, S)
        logits = None
        for t in range(S):
            batch = {"token": tokens[:, t:t + 1],
                     "pos": jnp.full((B,), t, jnp.int32)}
            logits, cache = lm.decode_step(cfg, params, cache, batch)
        import numpy as np
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, -1]),
                                   rtol=2e-3, atol=2e-3, err_msg=aid)
